"""Training step: loss + grads (+ microbatch accumulation) + AdamW.

The step function is pure and jit/AOT-lowerable: ``train_step(params,
opt_state, batch) -> (params, opt_state, metrics)``. Distribution comes from
the Runtime injected by the sharding plan; gradient accumulation splits the
global batch into ``microbatches`` sequential chunks (activation-memory
control — with PP the same chunks become the pipeline's microbatches).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import model as MDL
from repro.training import optimizer as OPT


def make_loss_fn(cfg, rt):
    def loss_fn(params, batch):
        return MDL.train_loss(cfg, params, batch, rt=rt)
    return loss_fn


def make_train_step(cfg, rt, opt_cfg: OPT.AdamWConfig, *, microbatches: int = 1):
    loss_fn = make_loss_fn(cfg, rt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(key, x):
                if key == "positions" and x.ndim == 3:   # mrope [3, B, S]
                    return x.reshape(3, microbatches, -1, x.shape[-1]) \
                            .transpose(1, 0, 2, 3)
                return x.reshape(microbatches, -1, *x.shape[1:])

            mb = {k: split(k, v) for k, v in batch.items()}
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)

            def body(carry, mbatch):
                acc, loss_acc = carry
                (loss, _), grads = grad_fn(params, mbatch)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return (acc, loss_acc + loss), None

            (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"nll": loss, "tokens": jnp.float32(0)}
        params, opt_state, om = OPT.apply(opt_cfg, params, grads, opt_state)
        metrics = {**metrics, **om, "loss": loss}
        return params, opt_state, metrics

    return train_step
