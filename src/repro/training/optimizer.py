"""AdamW with global-norm clipping and schedules — no external deps.

Moments are fp32 and shard exactly like their parameters (ZeRO-style when the
plan FSDPs the params). Params may be bf16; the update happens in fp32 and is
cast back.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:                       # decoupled wd on matrices only
            u = u + cfg.weight_decay * pf
        return (pf - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
