"""Host-memory KV tier: async page swap between the device pool and DRAM.

The device page pool caps the admissible batch long before host DRAM is
exhausted (the capacity argument of L3/PAM: a KV-centric hierarchy below the
accelerator). This tier holds evicted radix-tree payloads as host numpy
arrays, keyed by the tree node, and double-buffers the transfers against the
decode loop in DCS ping-pong style:

* **swap-out** dispatches one jitted page-gather against the current pool
  and immediately releases the device pages — the gather result is a
  functional snapshot, so the freed pages can be rewritten by the very next
  prefill without corrupting the in-flight copy. The jax arrays are kept as
  the host payload and *drained* to numpy at the next tick boundary
  (``drain``), off the critical path.
* **swap-in** allocates fresh device pages and queues a jitted page-scatter;
  the cache facade applies all queued scatters in one batch before the
  tick's prefill reads them.

Transfer shapes are padded to powers of two (pad slots route to the
out-of-range page and are dropped by the scatter) so the jit cache stays
O(log pool) instead of one compile per transfer size.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_kv import copy_page, gather_pages, scatter_pages


def _pad_ids(page_ids: list[int], n_pool: int) -> np.ndarray:
    """Pad to the next power of two; pads point one past the pool (gathers
    read garbage that the host side slices off; scatters drop them)."""
    n = max(1, len(page_ids))
    p = 1
    while p < n:
        p *= 2
    out = np.full((p,), n_pool, np.int32)
    out[:len(page_ids)] = page_ids
    return out


@jax.jit
def _gather(pool_k, pool_v, ids):
    return gather_pages(pool_k, pool_v, ids)


@jax.jit
def _scatter(pool_k, pool_v, ids, k, v):
    return scatter_pages(pool_k, pool_v, ids, k, v)


@jax.jit
def _copy(pool_k, pool_v, src, dst):
    return copy_page(pool_k, pool_v, src, dst)


@dataclass
class TierStats:
    swapped_out_pages: int = 0
    swapped_in_pages: int = 0
    dropped_pages: int = 0          # evicted without a host copy
    peak_host_pages: int = 0
    swap_retries: int = 0           # failed swap-ins absorbed by the
                                    # retry/backoff budget (not the ladder)

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


class HostTier:
    """Bounded host-DRAM store for offloaded radix-node payloads."""

    def __init__(self, capacity_pages: int):
        self.capacity = capacity_pages
        self.used = 0
        self.stats = TierStats()
        self._pending: list  # nodes whose payload is still a jax array
        self._pending = []

    def has_space(self, n_pages: int) -> bool:
        return self.used + n_pages <= self.capacity

    # ------------------------------------------------------------------
    def swap_out(self, node, pool: dict) -> None:
        """Dispatch the gather for ``node``'s device pages and take ownership
        of the (still in-flight) result. The caller releases the device
        pages right after — see module docstring for why that is safe."""
        ids = node.pages
        pad = _pad_ids(ids, pool["k"].shape[1])
        k, v = _gather(pool["k"], pool["v"], jnp.asarray(pad))
        node.host = {"k": k[:, :len(ids)], "v": v[:, :len(ids)]}
        node.pages = None
        self.used += len(ids)
        self.stats.swapped_out_pages += len(ids)
        self.stats.peak_host_pages = max(self.stats.peak_host_pages,
                                         self.used)
        self._pending.append(node)

    def drain(self) -> None:
        """Materialize pending swap-outs to host numpy (ping-pong: issued
        last tick, collected this tick). Nodes already re-materialized to
        device (host=None) or split (narrowed arrays) convert just the same."""
        for node in self._pending:
            if node.host is not None:
                node.host = {"k": np.asarray(node.host["k"]),
                             "v": np.asarray(node.host["v"])}
        self._pending.clear()

    def take(self, node) -> dict:
        """Claim a node's host payload for swap-in (device side re-owns it)."""
        data = node.host
        n = int(data["k"].shape[1])
        self.used -= n
        self.stats.swapped_in_pages += n
        node.host = None
        return data

    def discard(self, node) -> None:
        """Drop a host-resident node's payload (tier eviction)."""
        n = node.n_pages
        self.used -= n
        self.stats.dropped_pages += n
        node.host = None


class DeviceOpQueue:
    """Pending device-side page ops (CoW copies, swap-in scatters) queued by
    host bookkeeping and applied to the functional pool in one place, before
    the tick's prefill — the cache's half of the ping-pong."""

    def __init__(self):
        self._scatters: list[tuple[np.ndarray, object, object]] = []
        self._copies: list[tuple[object, int, int]] = []   # (tag, src, dst)
        self._host_writes: list[tuple[object, int, dict]] = []

    @property
    def empty(self) -> bool:
        return not (self._scatters or self._copies or self._host_writes)

    def queue_scatter(self, page_ids: list[int], k, v) -> None:
        self._scatters.append((list(page_ids), k, v))

    def queue_copy(self, tag, src_page: int, dst_page: int) -> None:
        self._copies.append((tag, src_page, dst_page))

    def queue_host_write(self, tag, dst_page: int, data: dict) -> None:
        """Write one host-resident page into ``dst_page`` (host-side CoW)."""
        self._host_writes.append((tag, dst_page, data))

    def cancel(self, tag) -> None:
        """Drop queued request-tagged ops (the request was preempted before
        they applied; its target pages are being released)."""
        self._copies = [c for c in self._copies if c[0] != tag]
        self._host_writes = [w for w in self._host_writes if w[0] != tag]

    def inflight_pages(self) -> set[int]:
        """Pages with a queued write — protected from eviction until applied."""
        out: set[int] = set()
        for ids, _, _ in self._scatters:
            out.update(ids)
        for _, src, dst in self._copies:
            out.update((src, dst))
        for _, dst, _ in self._host_writes:
            out.add(dst)
        return out

    def apply(self, pool: dict) -> dict:
        """Apply every queued op to the (functional) pool; returns the new
        pool. Order: scatters (swap-ins) first, then copies — a CoW source
        may itself be a page that just swapped in."""
        pk, pv = pool["k"], pool["v"]
        n_pool = pk.shape[1]
        for ids, k, v in self._scatters:
            n = len(ids)
            pad = _pad_ids(ids, n_pool)
            kz = jnp.zeros((pk.shape[0], len(pad)) + pk.shape[2:], pk.dtype)
            kz = kz.at[:, :n].set(jnp.asarray(k).astype(pk.dtype))
            vz = jnp.zeros_like(kz)
            vz = vz.at[:, :n].set(jnp.asarray(v).astype(pv.dtype))
            pk, pv = _scatter(pk, pv, jnp.asarray(pad), kz, vz)
        for _, dst, data in self._host_writes:
            pad = _pad_ids([dst], n_pool)
            kz = jnp.asarray(data["k"]).astype(pk.dtype)
            vz = jnp.asarray(data["v"]).astype(pv.dtype)
            pk, pv = _scatter(pk, pv, jnp.asarray(pad), kz, vz)
        for _, src, dst in self._copies:
            pk, pv = _copy(pk, pv, jnp.int32(src), jnp.int32(dst))
        self._scatters.clear()
        self._copies.clear()
        self._host_writes.clear()
        return {"k": pk, "v": pv}
