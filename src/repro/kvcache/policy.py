"""Placement / eviction policy for the KV-cache hierarchy.

Decides *which* cold radix nodes leave the device pool, *when* (watermark
pressure or on-demand reclaim), and *where to* (host tier vs dropped). The
invariants the cache facade enforces regardless of policy:

* pin-while-running — nodes on a running request's matched path have
  ref > 0 and are never victims;
* pages with an in-flight device op (queued swap-in scatter / CoW copy) are
  never victims until the op applies;
* only leaves may be *dropped* (structure stays a tree); any unpinned node
  may be *offloaded* (payload moves, structure stays).

The price of counting offloaded pages as capacity — one swap-in over the
host link — is the analytic ``core.pim_model.swap_latency`` term, which
memory-aware admission (``serving/policies.py``) adds to a candidate's
modelled cost (the placement/migration trade-off of the L3/PAM line of
work).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class WatermarkConfig:
    """Device-pool occupancy thresholds driving background offload."""
    high: float = 0.85          # start offloading above this fill fraction
    low: float = 0.60           # ...until the pool drops back to this


class EvictionPolicy:
    """Base: picks victims and decides offload-vs-drop."""
    name = "base"

    def __init__(self, watermark: WatermarkConfig | None = None):
        self.watermark = watermark or WatermarkConfig()

    def next_victim(self, tree, *, inflight: set[int], host_tier=None):
        """The next node to evict from the device pool, or None. Must be
        device-resident, unpinned, not in-flight, and *evictable*: either a
        leaf (can be dropped) or offloadable to a host tier with space."""
        raise NotImplementedError

    def should_offload(self, node, host_tier) -> bool:
        """Offload to host (True) vs drop (False) for an evicted node.
        Non-leaves MUST offload (the facade only drops leaves)."""
        return host_tier is not None and host_tier.has_space(node.n_pages)

    # ---- watermark driver --------------------------------------------
    def pressure_pages(self, alloc) -> int:
        """Pages to shed under watermark pressure (0 = below high mark)."""
        if alloc.pages_in_use <= self.watermark.high * alloc.n_pages:
            return 0
        return int(alloc.pages_in_use - self.watermark.low * alloc.n_pages)


class LRUPolicy(EvictionPolicy):
    """Least-recently-touched first. Offload victims may be internal nodes
    (deep cold prefixes leave as a unit); drop victims must be leaves, so a
    cold branch peels bottom-up."""
    name = "lru"

    def _eligible(self, node, inflight: set[int], host_tier) -> bool:
        if node.on_host or node.ref > 0 or node.pages is None:
            return False
        if inflight and set(node.pages) & inflight:
            return False
        return node.is_leaf or self.should_offload(node, host_tier)

    def next_victim(self, tree, *, inflight: set[int], host_tier=None):
        cands = [n for n in tree.nodes()
                 if self._eligible(n, inflight, host_tier)]
        if not cands:
            return None
        # prefer leaves among equally-cold nodes so structure erodes from
        # the bottom; ticks are unique (tree clock) so this is a stable
        # total order
        return min(cands, key=lambda n: (n.tick, not n.is_leaf))


def make_cache_policy(name: str = "lru", *,
                      watermark: WatermarkConfig | None = None
                      ) -> EvictionPolicy:
    if isinstance(name, EvictionPolicy):
        return name
    return {"lru": LRUPolicy}[name](watermark)
