"""KV-cache hierarchy: radix prefix sharing + host offload tier.

Layered under the serving engine (see docs/kvcache.md):

* ``radix``   — token-prefix radix tree over allocator pages (refcounted
  sharing, page-boundary splits, copy-on-write on mid-page divergence);
* ``offload`` — host-DRAM capacity tier with ping-pong-style async swaps;
* ``policy``  — pluggable placement/eviction (LRU, watermarks, swap cost);
* ``cache``   — the ``PrefixCache`` facade the engine and scheduler use;
* ``handoff`` — versioned, checksummed cross-engine KV transfer blobs for
  disaggregated serving (``serving/cluster.py``).
"""
from repro.kvcache.cache import CacheHit, CacheStats, PrefixCache
from repro.kvcache.handoff import Handoff, HandoffError
from repro.kvcache.offload import DeviceOpQueue, HostTier, TierStats
from repro.kvcache.policy import (EvictionPolicy, LRUPolicy, WatermarkConfig,
                                  make_cache_policy)
from repro.kvcache.radix import MatchResult, RadixNode, RadixTree

__all__ = [
    "PrefixCache", "CacheHit", "CacheStats",
    "HostTier", "TierStats", "DeviceOpQueue",
    "EvictionPolicy", "LRUPolicy", "WatermarkConfig", "make_cache_policy",
    "RadixTree", "RadixNode", "MatchResult",
    "Handoff", "HandoffError",
]
