"""PrefixCache — the facade tying radix tree, host tier, policy and
allocator into one KV-cache hierarchy for the serving engine.

Responsibilities and the tick choreography:

* ``lookup(req_id, tokens)`` at admission: longest-prefix match, pin the
  matched path, swap host-resident path nodes back in (allocating
  tree-owned device pages, queueing the scatters), and stage a CoW source
  when the walk diverges mid-page. Returns a ``CacheHit`` whose ``pages``
  the scheduler hands to ``PageAllocator.admit_shared``.
* ``commit(req_id, table)`` right after admission binds the CoW copy to the
  request's first private page.
* ``apply_pending(pool)`` (engine, before the tick's prefill) replays all
  queued device ops against the functional pool — swap-in scatters first,
  then CoW copies.
* ``insert(req_id, tokens)`` after a prefill completes / a request finishes
  or is preempted: record the written full pages under the tree (the tree
  increfs them, so they outlive the request).
* ``release(req_id)`` unpins; ``maintain()`` once per tick drains last
  tick's swap-outs (ping-pong) and enforces the occupancy watermarks.
* reclaimer protocol (``reclaimable`` / ``reclaim``): the allocator calls
  back under exhaustion, so cold cached pages count as admission capacity
  and are evicted/offloaded exactly on demand.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.kvcache.offload import DeviceOpQueue, HostTier
from repro.kvcache.policy import EvictionPolicy, make_cache_policy
from repro.kvcache.radix import RadixNode, RadixTree
from repro.runtime.faults import NULL_FAULTS


@dataclass
class CacheHit:
    """What admission gets back from a lookup."""
    req_id: int
    pages: list[int]                    # device pages to borrow, in order
    matched: int                        # tokens of KV reused (incl. CoW run)
    deepest: RadixNode | None           # pinned path handle
    cow_node: RadixNode | None = None   # pinned while the copy is queued
    cow_tokens: int = 0
    cow_src: int | None = None          # device page id (None: host payload)
    cow_host: dict | None = None        # host page payload when src offloaded
    cow_applied: bool = False

    @property
    def n_shared_pages(self) -> int:
        return len(self.pages)


@dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0
    hit_tokens: int = 0                 # prefill tokens skipped
    cow_copies: int = 0
    inserted_pages: int = 0
    evicted_pages: int = 0              # dropped from device (incl. offloads)
    reclaims: int = 0                   # on-demand reclaim calls
    swap_in_fails: int = 0              # refused swap-ins (real or injected)

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


class PrefixCache:
    def __init__(self, alloc, *, policy: EvictionPolicy | str = "lru",
                 host_pages: int = 0,
                 pool_ref: Callable[[], dict] | None = None,
                 swap_retry_limit: int = 3, swap_backoff_cap: int = 8):
        self.alloc = alloc
        alloc.reclaimer = self              # cold cached pages = capacity
        self.tree = RadixTree(alloc.page_size)
        self.policy = make_cache_policy(policy)
        self.host = HostTier(host_pages) if host_pages > 0 else None
        # transient-failure absorption BEFORE the degrade_after ladder: up
        # to swap_retry_limit consecutive failed swap-ins are retried after
        # a capped-exponential backoff (1, 2, 4, ... maintain() ticks) and
        # counted as TierStats.swap_retries; only failures past the budget
        # advance stats.swap_in_fails toward dropping the tier
        self.swap_retry_limit = swap_retry_limit
        self.swap_backoff_cap = swap_backoff_cap
        self._swap_streak = 0               # consecutive failed swap-ins
        self._swap_retry_at = 0             # maintain-tick backoff gate
        self._mtick = 0                     # maintain() call counter
        self._dropped_stats: "TierStats | None" = None
        self.ops = DeviceOpQueue()
        # pool_ref: () -> {"k","v"} pool arrays — swap-out gathers read the
        # engine's *current* functional pool at dispatch time
        self.pool_ref = pool_ref
        self.stats = CacheStats()
        # fault injection (repro.runtime.faults): the engine threads its
        # injector here so swap-tier refusal is deterministically replayable
        self.faults = NULL_FAULTS
        self._hits: dict[int, CacheHit] = {}
        # reclaimable() is consulted by every can_admit (once per queued
        # candidate per tick): memoize the tree walk and invalidate on any
        # mutation that can change eligibility (pins, structure, device ops)
        self._reclaimable_memo: int | None = None

    # ------------------------------------------------------------------
    # admission path
    # ------------------------------------------------------------------
    def lookup(self, req_id: int, tokens: np.ndarray) -> CacheHit:
        """Match, pin, swap in. Caps the match at len(tokens) - 1 so at
        least one suffix token runs through prefill (first-token logits)."""
        assert req_id not in self._hits, req_id
        self._mutated()
        tokens = np.asarray(tokens, np.int32)
        self.stats.lookups += 1
        res = self.tree.match(tokens, max_tokens=len(tokens) - 1)
        # materialize host-resident path nodes (swap-in); on pool pressure
        # the match truncates at the last materializable node. The pin is
        # extended node-by-node BEFORE each materialization: _swap_in's
        # allocation may reclaim, and an unpinned not-yet-collected path
        # node would be fair game for eviction — the walk would then read
        # freed pages (or a discarded host payload) into the hit.
        pages: list[int] = []
        matched = 0
        deepest = self.tree.root
        self.tree.pin(self.tree.root)
        for node in res.path:
            node.ref += 1               # ancestors already hold the pin
            if node.on_host and not self._swap_in(node):
                node.ref -= 1
                res.cow_node, res.cow_tokens = None, 0
                break
            pages += node.pages
            matched += len(node.tokens)
            deepest = node
        hit = CacheHit(req_id, pages, matched, deepest)
        if res.cow_node is not None and not res.cow_node.on_host \
                and res.cow_node.pages is None:
            res.cow_node = None             # dropped by a reclaim mid-lookup
        if res.cow_node is not None and res.cow_tokens > 0:
            hit.cow_node, hit.cow_tokens = res.cow_node, res.cow_tokens
            hit.matched += res.cow_tokens
            if res.cow_node.on_host:
                host = res.cow_node.host
                hit.cow_host = {"k": np.asarray(host["k"][:, :1]),
                                "v": np.asarray(host["v"][:, :1])}
                hit.cow_node = None         # payload captured; no pin needed
            else:
                hit.cow_src = res.cow_node.pages[0]
                self.tree.pin(res.cow_node)  # keep the source page resident
        if hit.matched < self.tree.page_size:
            # trivial sub-page match (e.g. one accidentally-equal leading
            # token): the CoW copy + suffix-path prefill would cost more
            # than the tokens it saves, and a lone hit fragments the
            # admission tick's batched prefill — treat as a miss
            self.tree.unpin(hit.deepest)
            if hit.cow_node is not None:
                self.tree.unpin(hit.cow_node)
            hit = CacheHit(req_id, [], 0, self.tree.root)
            self.tree.pin(self.tree.root)
        if hit.matched > 0:
            self.stats.hits += 1
            self.stats.hit_tokens += hit.matched
        self._hits[req_id] = hit
        return hit

    def commit(self, req_id: int, table: list[int]) -> None:
        """Bind post-admission state: the CoW copy lands in the request's
        first page after the shared prefix."""
        hit = self._hits[req_id]
        self._mutated()
        if hit.cow_tokens > 0:
            dst = table[hit.n_shared_pages]
            if hit.cow_host is not None:
                self.ops.queue_host_write(req_id, dst, hit.cow_host)
            else:
                self.ops.queue_copy(req_id, hit.cow_src, dst)
            self.stats.cow_copies += 1

    def cached_len(self, req_id: int) -> int:
        hit = self._hits.get(req_id)
        return hit.matched if hit is not None else 0

    def release(self, req_id: int) -> None:
        """Unpin a request's matched path (finish / preemption). Cancels any
        not-yet-applied request-tagged ops (their target pages are being
        released with the request)."""
        hit = self._hits.pop(req_id, None)
        if hit is None:
            return
        self._mutated()
        self.tree.unpin(hit.deepest)
        if hit.cow_node is not None and not hit.cow_applied:
            self.tree.unpin(hit.cow_node)
        self.ops.cancel(req_id)

    def peek(self, tokens: np.ndarray) -> tuple[int, int]:
        """(device_pages, host_pages) an admission would reuse — estimate
        for admission policies, no side effects."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) <= 1:
            return 0, 0
        return self.tree.peek(tokens, max_tokens=len(tokens) - 1)

    # ------------------------------------------------------------------
    # insert path
    # ------------------------------------------------------------------
    def insert(self, req_id: int, tokens: np.ndarray) -> int:
        """Record the request's written KV (full pages only) under the
        tree. Newly adopted pages gain a tree reference so they survive the
        request's ``free``. Returns the number of pages adopted."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) < self.tree.page_size:
            return 0
        self._mutated()
        table = self.alloc.pages_of(req_id)
        adopted = self.tree.insert(tokens, table)
        n = 0
        for _node, pages in adopted:
            for p in pages:
                self.alloc.incref(p)
                n += 1
        self.stats.inserted_pages += n
        return n

    # ------------------------------------------------------------------
    # device-op application (engine-side, once per tick before prefill)
    # ------------------------------------------------------------------
    @property
    def has_pending(self) -> bool:
        return not self.ops.empty

    def apply_pending(self, pool: dict) -> dict:
        self._mutated()
        pool = self.ops.apply(pool)
        for hit in self._hits.values():
            if hit.cow_node is not None and not hit.cow_applied:
                self.tree.unpin(hit.cow_node)
                hit.cow_applied = True
        return pool

    # ------------------------------------------------------------------
    # capacity tier: eviction / offload / reclaim
    # ------------------------------------------------------------------
    def _swap_in(self, node: RadixNode) -> bool:
        """Bring an offloaded node's payload back onto device pages. A
        refusal (real pool exhaustion, injected swap failure, or a dropped
        tier) truncates the caller's match at the last materializable node
        — prefill covers the rest, so refusal costs recompute, never
        correctness."""
        if self.host is None:               # tier dropped (degradation)
            return False
        if self._mtick < self._swap_retry_at:
            return False                    # backing off after a failure
        if self.faults.enabled and self.faults.fire(
                "swap_fail", key=self.stats.lookups):
            self._swap_failed()
            return False
        try:
            pages = self.alloc.alloc_pages(node.n_pages)
        except MemoryError:
            self._swap_failed()
            return False
        self._swap_streak = 0
        data = self.host.take(node)
        node.pages = pages
        self.ops.queue_scatter(pages, data["k"], data["v"])
        return True

    def _swap_failed(self) -> None:
        """Account one failed swap-in. The first ``swap_retry_limit``
        consecutive failures are treated as transient: counted in
        ``TierStats.swap_retries`` and gated behind a capped exponential
        backoff window so the tier is not hammered while unhealthy. Only a
        failure past the retry budget advances ``stats.swap_in_fails`` —
        the counter the engine's degrade_after ladder watches — so one
        pressure blip no longer walks the cache toward dropping the tier."""
        self._swap_streak += 1
        if self._swap_streak <= self.swap_retry_limit:
            self.host.stats.swap_retries += 1
            self._swap_retry_at = self._mtick + min(
                self.swap_backoff_cap, 1 << (self._swap_streak - 1))
            return
        self.stats.swap_in_fails += 1

    def drop_host_tier(self) -> int:
        """Degradation: abandon the host offload tier after repeated swap
        failures. Unpinned host-resident nodes are discarded and removed
        from the tree (their payloads were cold copies — the engine can
        always recompute them from tokens); the tier handle goes to None so
        ``maintain()`` stops offloading and ``_swap_in`` refuses, turning
        every future host hit into a plain miss. Still-pinned or inner
        host-resident nodes stay in the tree with their dead payload; a
        walk that reaches one refuses to materialize it and truncates
        there (the lookup's existing fallback). Returns nodes dropped."""
        if self.host is None:
            return 0
        self._mutated()
        self._dropped_stats = self.host.stats   # keep the tier's counters
        self.host.drain()                       # visible post-degradation
        n = 0
        while True:                         # removal is leaf-only; peel
            cands = [c for c in self.tree.nodes()
                     if c.on_host and c.ref == 0 and c.is_leaf]
            if not cands:
                break
            for node in cands:
                self.host.discard(node)
                self.tree.remove(node)
                n += 1
        self.host = None
        return n

    def _make_host_room(self, n_pages: int) -> None:
        """Tier eviction: discard the coldest unpinned host-resident leaves
        until ``n_pages`` fit (LRU within the tier, like the device side)."""
        while not self.host.has_space(n_pages):
            cands = [c for c in self.tree.leaves()
                     if c.on_host and c.ref == 0]
            if not cands:
                return
            victim = min(cands, key=lambda c: c.tick)
            self.host.discard(victim)
            self.tree.remove(victim)

    def _evict_node(self, node: RadixNode) -> int:
        """Take a victim off the device pool. Returns pages actually freed
        (a page survives if a running request still owns a reference)."""
        pages = node.pages
        if self.host is not None and not self.host.has_space(len(pages)):
            self._make_host_room(len(pages))
        if self.policy.should_offload(node, self.host):
            self.host.swap_out(node, self.pool_ref())
            freed = sum(1 for p in pages if self.alloc.decref(p))
        else:                               # drop (leaves only)
            freed = sum(1 for p in pages if self.alloc.decref(p))
            self.tree.remove(node)
            node.pages = None               # anyone still holding the node
        self.stats.evicted_pages += len(pages)  # (e.g. a CoW source picked
        return freed                            # mid-lookup) sees it's gone

    def _mutated(self) -> None:
        self._reclaimable_memo = None

    def reclaimable(self) -> int:
        """Device pages the cache could give back on demand (unpinned tree
        payload) — counted by the allocator as admission capacity. Memoized
        between mutations (see __init__)."""
        if self._reclaimable_memo is None:
            inflight = self.ops.inflight_pages()
            # count pages, not nodes: a page a running request still
            # references (tree ref + request ref => ref_of > 1) would
            # survive eviction, so advertising it as capacity lets
            # admission overcommit and walk straight into mid-decode
            # preemptions the count was supposed to prevent
            self._reclaimable_memo = sum(
                sum(1 for p in n.pages if self.alloc.ref_of(p) == 1)
                for n in self.tree.nodes()
                if not n.on_host and n.ref == 0
                and not (inflight and set(n.pages) & inflight))
        return self._reclaimable_memo

    def reclaim(self, n_pages: int, *, offload_only: bool = False) -> int:
        """Allocator exhaustion callback: free >= n_pages if possible.
        ``offload_only`` restricts eviction to host-tier offloads (watermark
        maintenance must not destroy cold state that on-demand reclaim
        could still have dropped lazily)."""
        self.stats.reclaims += 1
        self._mutated()
        freed = 0
        inflight = self.ops.inflight_pages()
        while freed < n_pages:
            victim = self.policy.next_victim(self.tree, inflight=inflight,
                                             host_tier=self.host)
            if victim is None:
                break
            if offload_only and not self.policy.should_offload(victim,
                                                               self.host):
                break
            freed += self._evict_node(victim)
        return freed

    def maintain(self) -> None:
        """Once-per-tick background work: drain last tick's swap-outs
        (ping-pong double buffer) and enforce the occupancy watermarks.
        Watermark pressure only moves cold payload to the host tier
        (proactive: later demand becomes a swap instead of a recompute);
        with no tier — or a full one — pages stay put for the allocator's
        on-demand reclaim, and running requests' own occupancy never
        triggers a pointless tree flush."""
        self._mtick += 1                    # backoff windows are measured
        if self.host is None:               # in maintain() ticks
            return
        self.host.drain()
        need = self.policy.pressure_pages(self.alloc)
        if need > 0:
            self.reclaim(need, offload_only=True)
            self.stats.reclaims -= 1        # watermark, not on-demand

    # ------------------------------------------------------------------
    def stats_dict(self) -> dict:
        out = self.stats.as_dict()
        out["tree_device_pages"] = self.tree.device_pages()
        out["tree_host_pages"] = self.tree.host_pages()
        if self.host is not None:
            out.update(self.host.stats.as_dict())
        elif self._dropped_stats is not None:
            out.update(self._dropped_stats.as_dict())
        return out
