"""Crash-safe cross-engine KV handoff: the wire format.

Disaggregated serving (``serving/cluster.py``) moves a finished-prefill
request from a prefill-role engine to a decode-role engine: its KV pages
(gathered contiguously by the engine's snapshot path), its recurrent
carry rows, and the scalar admission frame. The transfer crosses an
unreliable boundary — it can be torn mid-stream or corrupted in flight —
so the payload travels as one self-validating byte blob, mirroring
``runtime/checkpoint.py``'s manifest-gated layout:

    magic "KVH1" | u64 manifest length | manifest json | npz payload

The manifest is the commit gate: it records the payload's byte length,
a crc32 over the payload bytes, and a per-array crc32
(``checkpoint.array_crc``) for every flattened tensor. ``decode`` checks
ALL of them before returning anything, so

* a **torn** transfer (truncation anywhere) fails the magic, length, or
  manifest-parse check;
* a **corrupt** transfer (any flipped byte) fails the payload or
  per-array crc — or the manifest parse, if the flip landed there;

and either way raises ``HandoffError`` with nothing applied. The router
keeps the pristine in-memory ``Handoff`` and simply re-encodes on retry —
a handoff is re-driven, never half-applied.

``tear``/``flip`` are the deterministic damage models the fault injector
drives (``handoff_torn`` / ``handoff_corrupt`` kinds in
``runtime/faults.py``); they live here so tests and the chaos soak share
one definition of "torn" and "corrupt".
"""
from __future__ import annotations

import io
import json
import zlib
from dataclasses import dataclass

import numpy as np

from repro.runtime.checkpoint import array_crc

HANDOFF_VERSION = 1
_MAGIC = b"KVH1"
_HDR = len(_MAGIC) + 8


class HandoffError(RuntimeError):
    """A handoff blob failed validation (torn or corrupt) — nothing from
    it may be applied; the router must retry or re-drive."""


@dataclass
class Handoff:
    """One request's transferable state: the scalar admission frame
    (``entry``, from the engine's snapshot path) plus the flattened
    arrays — ``prompt``, ``out``, optionally ``kv_k``/``kv_v`` and
    ``rows/<path>`` recurrent-carry leaves."""
    req_id: int
    entry: dict
    arrays: dict[str, np.ndarray]

    @property
    def kv_pages(self) -> int:
        k = self.arrays.get("kv_k")
        return 0 if k is None else int(k.shape[1])


def pack(req_id: int, entry: dict, arrays: dict) -> Handoff:
    """Build a Handoff from an engine ``extract_request`` result. Nested
    values (the recurrent carry) are flattened to "/"-joined keys, the
    checkpoint module's path convention."""
    flat: dict[str, np.ndarray] = {}

    def walk(prefix, val):
        if isinstance(val, dict):
            for k, v in val.items():
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(val, (tuple, list)):
            # carry pytrees contain tuples; string indices match the jax
            # tree-path convention _rows_from_nested unflattens against
            for i, v in enumerate(val):
                walk(f"{prefix}/{i}" if prefix else str(i), v)
        else:
            flat[prefix] = np.asarray(val)

    for name, val in arrays.items():
        walk(name, val)
    return Handoff(int(req_id), dict(entry), flat)


def nested_arrays(h: Handoff) -> dict:
    """Re-nest the "/"-joined array keys back into dicts (inverse of
    ``pack``'s flattening) — what the adopting engine consumes."""
    out: dict = {}
    for key, arr in h.arrays.items():
        parts = key.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return out


def encode(h: Handoff) -> bytes:
    """Serialize to the self-validating wire blob (see module docstring)."""
    buf = io.BytesIO()
    np.savez(buf, **h.arrays)
    payload = buf.getvalue()
    manifest = {
        "version": HANDOFF_VERSION,
        "req_id": h.req_id,
        "entry": h.entry,
        "payload_len": len(payload),
        "payload_crc": zlib.crc32(payload),
        "crc": {k: array_crc(v) for k, v in h.arrays.items()},
    }
    mjson = json.dumps(manifest).encode()
    return (_MAGIC + len(mjson).to_bytes(8, "little") + mjson + payload)


def decode(blob: bytes) -> Handoff:
    """Validate and deserialize a wire blob. Raises ``HandoffError`` on
    ANY defect — truncation, flipped bytes, version or length mismatch —
    before constructing the result, so a bad transfer yields nothing."""
    if len(blob) < _HDR or blob[:len(_MAGIC)] != _MAGIC:
        raise HandoffError("torn or foreign handoff header")
    mlen = int.from_bytes(blob[len(_MAGIC):_HDR], "little")
    if len(blob) < _HDR + mlen:
        raise HandoffError("torn handoff: manifest truncated")
    try:
        manifest = json.loads(blob[_HDR:_HDR + mlen])
    except ValueError as e:
        raise HandoffError(f"corrupt handoff manifest: {e}") from e
    if manifest.get("version") != HANDOFF_VERSION:
        raise HandoffError(f"handoff version {manifest.get('version')!r} "
                           f"!= {HANDOFF_VERSION}")
    payload = blob[_HDR + mlen:]
    try:
        # a flipped byte INSIDE the manifest can still parse as JSON with
        # a mangled key/value — any missing or mistyped field is the same
        # defect as a failed checksum
        p_len = int(manifest["payload_len"])
        p_crc = int(manifest["payload_crc"])
        crcs = {k: int(v) for k, v in manifest["crc"].items()}
        req_id = int(manifest["req_id"])
        entry = dict(manifest["entry"])
    except (KeyError, TypeError, ValueError, AttributeError) as e:
        raise HandoffError(f"corrupt handoff manifest: {e!r}") from e
    if len(payload) != p_len:
        raise HandoffError(f"torn handoff payload: {len(payload)} != "
                           f"{p_len} bytes")
    if zlib.crc32(payload) != p_crc:
        raise HandoffError("corrupt handoff payload (crc mismatch)")
    try:
        with np.load(io.BytesIO(payload)) as data:
            arrays = {k: data[k] for k in data.files}
    except Exception as e:
        raise HandoffError(f"corrupt handoff payload: {e}") from e
    if set(arrays) != set(crcs):
        raise HandoffError("handoff array set != manifest")
    for key, arr in arrays.items():
        if array_crc(arr) != crcs[key]:
            raise HandoffError(f"corrupt handoff array {key!r}")
    return Handoff(req_id, entry, arrays)


def tear(blob: bytes, salt: int) -> bytes:
    """Deterministic truncation damage: cut the blob at a salt-derived
    point (always strictly shorter, never empty)."""
    cut = 1 + (salt * 0x9E3779B9 + 7) % max(1, len(blob) - 1)
    return blob[:cut]


def flip(blob: bytes, salt: int) -> bytes:
    """Deterministic single-byte corruption at a salt-derived offset,
    biased into the payload region when one exists (the interesting case:
    header damage is caught trivially, payload damage needs the crcs)."""
    lo = min(_HDR, len(blob) - 1)
    pos = lo + (salt * 0x9E3779B9 + 13) % max(1, len(blob) - lo)
    out = bytearray(blob)
    out[pos] ^= 0x40
    return bytes(out)
