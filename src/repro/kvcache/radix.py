"""Token-prefix radix tree over allocator pages — cross-request KV sharing.

Every node covers a run of tokens that is a whole number of pages (its
``pages`` list holds the physical page ids, in order); the root is an empty
sentinel. Requests whose prompts share a token prefix share the *physical*
pages of that prefix (the allocator refcounts owners), so admission borrows
the matched pages and prefill starts at the matched depth — the O(ctx) ->
O(suffix) win of radix prefix caching (SGLang-style), layered on top of the
paper's DPA lazy paging.

Structural sharing is page-granular: nodes split only at page boundaries.
When a request's tokens diverge *inside* a page (or its prompt ends inside
one), the partially-matching page is served **copy-on-write**: the cache
copies that one physical page and the request keeps writing its own tokens
into the copy, reusing the matched head of the page without recomputing it.

Pinning follows the SGLang lock-ref discipline: a running request pins the
whole path of its deepest matched node (ref++ on each ancestor); ``split``
makes the new upper node inherit the lower node's ref so an unpin walk from
any stored node still decrements every ancestor exactly once. Nodes with
ref == 0 are eviction candidates (``repro.kvcache.policy``); a node whose
payload was swapped to the host tier (``repro.kvcache.offload``) keeps its
place in the tree with ``pages=None`` and its data in ``host``.

Host/numpy bookkeeping only — device copies are queued by the cache facade
(``repro.kvcache.cache``) and applied by the engine between steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterator

import numpy as np


def _match_len(a: np.ndarray, b: np.ndarray) -> int:
    """Length of the common prefix of two int token arrays."""
    n = min(len(a), len(b))
    if n == 0:
        return 0
    eq = a[:n] == b[:n]
    return int(np.argmin(eq)) if not eq.all() else n


class RadixNode:
    __slots__ = ("tokens", "pages", "host", "children", "parent", "ref",
                 "tick")

    def __init__(self, tokens: np.ndarray, pages: list[int] | None,
                 parent: "RadixNode | None"):
        self.tokens = np.asarray(tokens, np.int32)
        self.pages = pages                  # device page ids, or None when
        self.host: dict[str, Any] | None = None   # ...payload lives in host
        self.children: dict[int, RadixNode] = {}
        self.parent = parent
        self.ref = 0                        # running requests pinning via path
        self.tick = 0                       # last access (tree clock)

    @property
    def n_pages(self) -> int:
        return len(self.pages) if self.pages is not None else \
            (0 if self.host is None else int(self.host["k"].shape[1]))

    @property
    def on_host(self) -> bool:
        return self.host is not None

    @property
    def is_leaf(self) -> bool:
        return not self.children

    def __repr__(self):  # pragma: no cover - debug aid
        loc = "host" if self.on_host else "dev"
        return (f"RadixNode(tok={len(self.tokens)}, pages={self.n_pages} "
                f"{loc}, ref={self.ref}, kids={len(self.children)})")


@dataclass
class MatchResult:
    """Outcome of a prefix walk: the fully matched node path (root
    excluded, each node a whole-pages unit thanks to boundary splits). The
    caller walks ``path`` itself — materializing host nodes as it goes may
    truncate the usable prefix, so derived values (pages, matched depth)
    belong to the consumer, not here."""
    path: list[RadixNode] = field(default_factory=list)
    # copy-on-write: the next child matches ``cow_tokens`` more tokens inside
    # its first page; the request should copy that page and resume there.
    cow_node: RadixNode | None = None
    cow_tokens: int = 0


class RadixTree:
    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = RadixNode(np.empty(0, np.int32), [], None)
        self.root.ref = 1                   # the root is never evictable
        self._tick = 0

    # ---- pin management ----------------------------------------------
    def touch(self, node: RadixNode) -> None:
        self._tick += 1
        node.tick = self._tick

    def pin(self, node: RadixNode) -> None:
        while node is not None:
            node.ref += 1
            node = node.parent

    def unpin(self, node: RadixNode) -> None:
        while node is not None:
            assert node.ref > 0, "unpin underflow"
            node.ref -= 1
            node = node.parent

    # ---- structural ops ----------------------------------------------
    def split(self, node: RadixNode, n_tokens: int) -> RadixNode:
        """Split ``node`` at a page boundary: the first ``n_tokens`` move to
        a new parent ("upper") inserted between node and its parent; returns
        the upper node. The upper inherits the lower's ref so existing unpin
        walks stay balanced."""
        ps = self.page_size
        assert 0 < n_tokens < len(node.tokens) and n_tokens % ps == 0
        k = n_tokens // ps
        upper = RadixNode(node.tokens[:n_tokens],
                          None if node.on_host else list(node.pages[:k]),
                          node.parent)
        if node.on_host:
            upper.host = {"k": node.host["k"][:, :k],
                          "v": node.host["v"][:, :k]}
            node.host = {"k": node.host["k"][:, k:],
                         "v": node.host["v"][:, k:]}
        else:
            node.pages = list(node.pages[k:])
        upper.ref = node.ref
        upper.tick = node.tick
        upper.children = {int(node.tokens[n_tokens]): node}
        node.parent.children[int(node.tokens[0])] = upper
        node.tokens = node.tokens[n_tokens:]
        node.parent = upper
        return upper

    def remove(self, node: RadixNode) -> None:
        """Unlink an (evicted) leaf from the tree."""
        assert node.is_leaf and node.ref == 0 and node.parent is not None
        del node.parent.children[int(node.tokens[0])]
        node.parent = None

    # ---- walks --------------------------------------------------------
    def match(self, tokens: np.ndarray, *, max_tokens: int | None = None
              ) -> MatchResult:
        """Longest-prefix walk. ``max_tokens`` caps the match (admission caps
        at prompt_len - 1 so at least one token goes through prefill and
        produces first-token logits). Splits nodes at page boundaries when a
        walk ends inside one, so the returned path nodes are fully matched
        units. Touches matched nodes (LRU clock); does NOT pin."""
        tokens = np.asarray(tokens, np.int32)
        budget = len(tokens) if max_tokens is None else min(len(tokens),
                                                            max_tokens)
        res = MatchResult()
        node, pos = self.root, 0
        while pos < budget:
            child = node.children.get(int(tokens[pos]))
            if child is None:
                break
            m = _match_len(child.tokens, tokens[pos:budget])
            full = (m // self.page_size) * self.page_size
            if full == len(child.tokens):           # whole node matched
                self.touch(child)
                res.path.append(child)
                node, pos = child, pos + full
                continue
            if full > 0:                            # ends inside the node:
                upper = self.split(child, full)     # carve the matched pages
                self.touch(upper)
                res.path.append(upper)
                child = upper.children[int(child.tokens[0])]
                pos += full
            rem = m - full
            if rem > 0:                             # mid-page divergence: CoW
                res.cow_node, res.cow_tokens = child, rem
                self.touch(child)
            break
        return res

    def peek(self, tokens: np.ndarray, *, max_tokens: int | None = None
             ) -> tuple[int, int]:
        """(device_pages, host_pages) a match would reuse — admission-policy
        estimate, no splits / touches / side effects."""
        tokens = np.asarray(tokens, np.int32)
        budget = len(tokens) if max_tokens is None else min(len(tokens),
                                                            max_tokens)
        dev = host = 0
        node, pos = self.root, 0
        while pos < budget:
            child = node.children.get(int(tokens[pos]))
            if child is None:
                break
            m = _match_len(child.tokens, tokens[pos:budget])
            # full pages only: a partial (CoW) match still allocates its
            # page fresh, so it must not count as reusable capacity
            full_pages = m // self.page_size
            if child.on_host:
                host += full_pages
            else:
                dev += full_pages
            if m < len(child.tokens):
                break
            node, pos = child, pos + m
        return dev, host

    def insert(self, tokens: np.ndarray, pages: list[int]) -> list[
            tuple[RadixNode, list[int]]]:
        """Record a request's written KV under the tree. Only whole pages are
        inserted (``len(tokens)`` floored to a page multiple). Where the tree
        already covers the tokens, the existing pages win (the request's
        duplicates simply lose an owner when it frees). Returns
        [(node, adopted_pages)] for the newly created nodes — the caller
        (cache facade) increfs those pages to give the tree its ownership."""
        tokens = np.asarray(tokens, np.int32)
        ps = self.page_size
        n_full = (len(tokens) // ps) * ps
        tokens = tokens[:n_full]
        adopted: list[tuple[RadixNode, list[int]]] = []
        node, pos = self.root, 0
        while pos < n_full:
            child = node.children.get(int(tokens[pos]))
            if child is None:
                new = RadixNode(tokens[pos:],
                                list(pages[pos // ps: n_full // ps]), node)
                node.children[int(tokens[pos])] = new
                self.touch(new)
                adopted.append((new, list(new.pages)))
                break
            m = _match_len(child.tokens, tokens[pos:])
            full = (m // ps) * ps
            if full < len(child.tokens):
                if full == 0:
                    break                   # diverges inside the first page
                child = self.split(child, full)
            self.touch(child)
            node, pos = child, pos + full
        return adopted

    # ---- iteration / stats -------------------------------------------
    def nodes(self) -> Iterator[RadixNode]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                yield n
            stack.extend(n.children.values())

    def leaves(self) -> Iterator[RadixNode]:
        return (n for n in self.nodes() if n.is_leaf)

    def device_pages(self) -> int:
        return sum(n.n_pages for n in self.nodes() if not n.on_host)

    def host_pages(self) -> int:
        return sum(n.n_pages for n in self.nodes() if n.on_host)

    def total_tokens(self) -> int:
        return sum(len(n.tokens) for n in self.nodes())
