"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from repro.core.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (host) devices exist — tests only."""
    return make_mesh(shape, axes)
