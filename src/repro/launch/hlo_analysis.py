"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
10-iteration scan reports 10% of the true FLOPs), so every roofline term here
is derived from our own parse of ``compiled.as_text()``:

* computations are parsed into op lists with output shapes;
* ``while`` ops get a trip count from the max s32 constant in their condition
  computation (scan lowering emits ``compare(i, constant(N)), direction=LT``);
* costs propagate through fusion ``calls=``/``body=`` edges with multipliers.

Per-device metrics returned:
  flops            — 2*prod(out)*prod(contracting) over every dot (matmul
                     FLOPs, the standard MFU convention; elementwise excluded)
  hbm_bytes        — Σ output bytes of materialized top-level ops (+ entry
                     params once): a traffic proxy — each buffer written once
                     and read ~once; fusion internals excluded.
  collective_bytes — per collective kind, bytes moved on the interconnect
                     (all-gather: output; all-reduce: 2x input; reduce-scatter
                     /all-to-all/collective-permute: input).

The HLO is the per-device partitioned program, so all numbers are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%(\S+?)\s*=\s*(.+?)\s+([\w-]+)\(")
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%([^,\s)]+)")
_COND_RE = re.compile(r"condition=%([^,\s)]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES = {"tuple", "get-tuple-element", "bitcast", "constant",
               "parameter", "after-all", "partition-id", "replica-id",
               "get-dimension-size"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string. Tuples return 0 (their
    elements are produced elsewhere)."""
    if type_str.lstrip().startswith("("):
        return 0
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    el = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return el * n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    out_type: str
    line: str
    bytes_: float = 0.0
    fusion_target: str | None = None


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    # local (unweighted) costs
    flops: float = 0.0
    bytes_: float = 0.0
    coll: dict = field(default_factory=dict)
    # edges: (callee, multiplier_kind) multiplier resolved later for while
    fusion_calls: list[str] = field(default_factory=list)
    whiles: list[tuple[str, str]] = field(default_factory=list)  # (body, cond)
    conditionals: list[list[str]] = field(default_factory=list)  # branch comps
    max_const: int = 1
    # in-place root (dynamic-update-slice): real traffic = update bytes, not
    # the aliased full-buffer output
    dus_update_bytes: float | None = None


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    symtab: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{", line)
        if header and not line.startswith(" "):
            cur = Computation(name=header.group(1))
            if line.startswith("ENTRY"):
                cur.is_entry = True  # type: ignore[attr-defined]
            comps[cur.name] = cur
            symtab = {}
            for pdecl in header.group(2).split(","):
                if ":" in pdecl:
                    pname, ptype = pdecl.split(":", 1)
                    symtab[pname.strip().lstrip("%")] = ptype.strip()
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_type, kind = m.group(1), m.group(2), m.group(3)
        symtab[name] = out_type
        op = Op(name, kind, out_type, line)
        cur.ops.append(op)
        if kind in ("dynamic-update-slice", "scatter"):
            # in-place on real hardware (XLA aliases operand 0):
            # traffic = update operand bytes (x2: read slice + write)
            ub = 0.0
            args = re.search(rf"{kind}\(([^)]*)\)", line)
            if args:
                parts = args.group(1).split(",")
                idx = 1 if kind == "dynamic-update-slice" else 2
                if len(parts) > idx:
                    t = symtab.get(parts[idx].strip().lstrip("%"))
                    if t:
                        ub = 2.0 * _shape_bytes(t)
            op.bytes_ = ub
            cur.dus_update_bytes = (cur.dus_update_bytes or 0.0) + ub
        elif kind == "fusion":
            op.bytes_ = _shape_bytes(out_type)
            cm0 = _CALL_RE.search(line)
            if cm0:
                op.fusion_target = cm0.group(1)
        elif kind == "convert":
            # bf16<->f32 converts of large buffers exist only because the
            # CPU backend lacks bf16 dots; the TPU target computes on bf16
            # directly. Count small converts, zero out whole-tensor ones.
            b = _shape_bytes(out_type)
            op.bytes_ = 0.0 if b >= (32 << 20) else b
        else:
            op.bytes_ = _shape_bytes(out_type)
        cm = _CONST_RE.search(line)
        if cm:
            cur.max_const = max(cur.max_const, int(cm.group(1)))
        if kind == "while":
            body = _CALL_RE.search(line)
            cond = _COND_RE.search(line)
            if body and cond:
                cur.whiles.append((body.group(1), cond.group(1)))
        elif kind == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation|branch_computations=\{)"
                r"[^%]*%([\w.\-]+)", line)
            if not branches:
                branches = re.findall(r"%([\w.\-]+)", line.split("),", 1)[-1])
            if branches:
                cur.conditionals.append(branches)
        elif kind == "fusion":
            cm2 = _CALL_RE.search(line)
            if cm2:
                cur.fusion_calls.append(cm2.group(1))
        if kind == "dot":
            out_dims = _shape_dims(out_type)
            # resolve lhs operand shape from the symbol table
            args = re.search(r"dot\(([^)]*)\)", line)
            flops = 0.0
            if args:
                first = args.group(1).split(",")[0].strip().lstrip("%")
                # operand may carry an inline type: "f32[a,b] %x"
                inline = _SHAPE_RE.search(args.group(1).split(",")[0])
                lhs_type = symtab.get(first) or (
                    inline.group(0) if inline else None)
                con = _CONTRACT_RE.search(line)
                if lhs_type and con:
                    lhs_dims = _shape_dims(lhs_type)
                    cdims = [int(d) for d in con.group(1).split(",") if d]
                    k = 1
                    for d in cdims:
                        if d < len(lhs_dims):
                            k *= lhs_dims[d]
                    n = 1
                    for d in out_dims:
                        n *= d
                    flops = 2.0 * n * k
            cur.flops += flops
        for c in COLLECTIVES:
            if kind == c or kind == c + "-start":
                b = _shape_bytes(out_type)
                if c == "all-reduce":
                    b *= 2                     # ring: reduce-scatter+all-gather
                elif c == "reduce-scatter":
                    gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
                    if gm:                     # output is input/groupsize
                        b *= int(gm.group(2))
                    else:
                        gm = re.search(r"replica_groups=\{\{([^}]*)\}", line)
                        if gm:
                            gm2 = gm.group(1)
                            b *= len(gm2.split(","))
                # CPU artifact: the cpu backend upcasts bf16 dot operands to
                # f32 BEFORE the SPMD gather; on the TPU target the gather
                # moves bf16 and converts never exist. Halve f32 collectives
                # whose operand is a convert(-fusion) of a bf16 value.
                if out_type.lstrip().startswith("f32"):
                    argm = re.search(r"\(([^),]*)", line.split("=", 1)[1])
                    if argm:
                        src = argm.group(1).strip().lstrip("%")
                        prod = next((o for o in cur.ops if o.name == src),
                                    None)
                        seen_hops = 0
                        while prod is not None and prod.kind == "copy" \
                                and seen_hops < 3:
                            am = re.search(r"\(([^),]*)",
                                           prod.line.split("=", 1)[1])
                            if not am:
                                break
                            src = am.group(1).strip().lstrip("%")
                            prod = next((o for o in cur.ops
                                         if o.name == src), None)
                            seen_hops += 1
                        if prod is not None and (
                                prod.kind == "convert"
                                or (prod.kind == "fusion"
                                    and "convert" in prod.line)):
                            b *= 0.5
                cur.coll[c] = cur.coll.get(c, 0.0) + b
                break
    return comps


def _entry(comps: dict[str, Computation]) -> str:
    for name, c in comps.items():
        if getattr(c, "is_entry", False):
            return name
    return next(iter(comps))


def analyze(text: str) -> dict:
    comps = parse_computations(text)

    # second pass: per-computation local bytes, resolving fusion targets
    # whose root is an in-place dynamic-update-slice / scatter, and zeroing
    # pure whole-buffer convert fusions (CPU-backend-only; see `convert`
    # handling in parse_computations)
    pure_convert_kinds = {"parameter", "convert", "copy", "bitcast",
                          "constant"}
    for c in comps.values():
        b = 0.0
        for op in c.ops:
            if op.kind in _SKIP_BYTES:
                continue
            if op.fusion_target and op.fusion_target in comps:
                t = comps[op.fusion_target]
                if t.dus_update_bytes is not None:
                    b += t.dus_update_bytes
                    continue
                if (all(o.kind in pure_convert_kinds for o in t.ops)
                        and any(o.kind == "convert" for o in t.ops)
                        and _shape_bytes(op.out_type) >= (32 << 20)):
                    continue
            b += op.bytes_
        c.bytes_ = b

    memo: dict[str, tuple] = {}

    def total(name: str, depth=0) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 50:
            return (0.0, 0.0, {})
        fl, by, co = c.flops, c.bytes_, dict(c.coll)
        for callee in c.fusion_calls:
            f2, b2, c2 = total(callee, depth + 1)
            fl += f2
            # fusion internals are not HBM traffic; only flops/collectives
            for k, v in c2.items():
                co[k] = co.get(k, 0.0) + v
        for body, cond in c.whiles:
            trips = comps[cond].max_const if cond in comps else 1
            f2, b2, c2 = total(body, depth + 1)
            fl += f2 * trips
            by += b2 * trips
            for k, v in c2.items():
                co[k] = co.get(k, 0.0) + v * trips
        for branches in c.conditionals:
            # one branch executes per invocation; weight uniformly
            w = 1.0 / max(len(branches), 1)
            for br in branches:
                if br not in comps:
                    continue
                f2, b2, c2 = total(br, depth + 1)
                fl += f2 * w
                by += b2 * w
                for k, v in c2.items():
                    co[k] = co.get(k, 0.0) + v * w
        memo[name] = (fl, by, co)
        return memo[name]

    entry = _entry(comps)
    fl, by, co = total(entry)
    return {"flops": fl, "hbm_bytes": by,
            "collectives": co,
            "collective_bytes": sum(co.values()),
            "cpu_upcast_bytes": cpu_upcast_bytes(comps),
            "n_computations": len(comps)}


def cpu_upcast_bytes(comps: dict[str, Computation]) -> float:
    """Bytes of hoisted bf16->f32 parameter upcasts — a CPU-backend artifact.

    The CPU lowering converts bf16 dot operands to f32 and LICM hoists the
    loop-invariant converts of whole stacked weight tensors out of the layer
    scan, inflating temp memory ~1.5-3x vs the TPU target (whose MXU consumes
    bf16 natively). The dry-run reports peak both raw and with these converts
    removed ("tpu-adjusted"). Detected as top-level f32 convert(-fusions) of
    >=64 MiB applied directly to entry parameters.
    """
    entry = comps.get(_entry(comps))
    if entry is None:
        return 0.0
    # map param names in the entry: ops of kind parameter
    params = {op.name for op in entry.ops if op.kind == "parameter"}
    total = 0.0
    for op in entry.ops:
        if op.kind not in ("convert", "fusion"):
            continue
        out_b = _shape_bytes(op.out_type)
        if out_b < (64 << 20) or not op.out_type.lstrip().startswith("f32"):
            continue
        if op.kind == "fusion":
            tgt = comps.get(op.fusion_target or "")
            if not tgt or not any(o.kind == "convert" for o in tgt.ops):
                continue
        args = re.search(r"\(([^)]*)\)", op.line.split("=", 1)[1])
        if not args:
            continue
        names = [a.strip().lstrip("%") for a in args.group(1).split(",")]
        if any(n in params or n.startswith("param") for n in names):
            total += out_b
    return total


def roofline_terms(metrics: dict, *, peak_flops=197e12, hbm_bw=819e9,
                   ici_bw=50e9, n_links=1) -> dict:
    """Per-chip roofline terms in seconds (TPU v5e-class constants)."""
    t_comp = metrics["flops"] / peak_flops
    t_mem = metrics["hbm_bytes"] / hbm_bw
    t_coll = metrics["collective_bytes"] / (ici_bw * n_links)
    dom = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
              key=lambda kv: kv[1])
    return {"t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
            "bottleneck": dom[0], "t_bound": dom[1]}
