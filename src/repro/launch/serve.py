"""End-to-end serving driver: continuous-batching decode with the DPA paged
cache over a LongBench-like request trace.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --requests 16 --task musique --max-context 256

Reports achieved average batch (the paper's Fig. 4(b) metric), token
throughput, host overhead, preemptions, and page-pool balance. ``--static``
switches to baseline-PIM static allocation for the comparison;
``--prefill-mode`` picks slot / batched / chunked prefill (every arch
family, including recurrent hybrids like xlstm/zamba2 via state-carrying
chunk prefill) and ``--sched-policy`` the admission policy (see
repro.serving). Recurrent/enc-dec archs snapshot their carry on preemption
and restore on resume (``--no-state-resume`` reverts to full recompute).
``--decode-horizon K`` fuses K decode steps (decode + on-device sampling)
under one jit per tick — the host syncs once per horizon; greedy outputs
are identical for every K.

``--shared-frac f`` makes every request start with a common system prompt
covering fraction ``f`` of its tokens (multi-tenant shared-prefix traffic);
``--prefix-cache`` turns on the radix KV sharing and ``--host-pages N``
adds the host offload tier below the device pool (see repro.kvcache /
docs/kvcache.md). Cache hit/swap counters are reported alongside.

``--disagg`` serves through a disaggregated prefill/decode fleet
(``--engines P+D``, see docs/serving.md): prefill-role engines hand each
finished-prefill request's KV + recurrent carry to a decode-role engine
over a checksummed handoff blob, with router-owned retry/backoff,
timeouts, engine-death recovery (``--snapshot-dir`` enables warm
restores) and backpressure (``--max-queue`` bounds the decode backlog).
``--fault-engine-death`` / ``--fault-handoff-corrupt`` /
``--fault-handoff-torn`` drive the cluster's seeded fault injector.

Telemetry (repro.telemetry, docs/observability.md): ``--metrics-port N``
serves Prometheus text on ``http://127.0.0.1:N/metrics`` (0 = pick an
ephemeral port, printed at startup), ``--trace-out trace.json`` writes a
Perfetto/chrome://tracing timeline of the tick pipeline, ``--request-log
records.jsonl`` exports one JSON record per finished request, and
``--stats-every S`` prints a one-line summary every S seconds while
serving. With none of these flags the telemetry layer is the shared no-op:
zero extra work, zero extra device syncs.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import request_trace
from repro.serving import DecodeEngine, EngineConfig, Request
from repro.serving.policies import available_policies


def make_serve_tel_cfg(args):
    """TelemetryConfig from the CLI flags, or None when every telemetry
    flag is off."""
    from repro.telemetry import TelemetryConfig
    want_metrics = args.metrics_port >= 0 or args.stats_every > 0
    if not (want_metrics or args.trace_out or args.request_log):
        return None
    return TelemetryConfig(
        metrics=True, trace_path=args.trace_out or None,
        request_log=args.request_log or None)


def make_serve_telemetry(args):
    """Build the Telemetry facade from the CLI flags — the shared no-op
    when every telemetry flag is off (EngineConfig.telemetry=None path)."""
    from repro.telemetry import make_telemetry
    return make_telemetry(make_serve_tel_cfg(args))


def build_engine(args, telemetry=None) -> DecodeEngine:
    cfg = replace(reduced(get_config(args.arch)), dtype="float32")
    draft_cfg = None
    if args.draft:
        # reduced() drafts share the reduced target's 256-token vocab, so
        # any attention-only arch pairs with any other; full-size cross-arch
        # pairs are vetted by validate_draft_pair at engine construction
        draft_cfg = replace(reduced(get_config(args.draft), layers=1),
                            dtype="float32")
    ecfg = EngineConfig(n_slots=args.slots, page_size=args.page,
                        n_pages=args.pages, max_context=args.max_context,
                        static_alloc=args.static, eos_token=-1,
                        prefill_mode=args.prefill_mode,
                        prefill_chunk=args.chunk,
                        sched_policy=args.sched_policy,
                        prefix_cache=args.prefix_cache,
                        host_pages=args.host_pages,
                        use_pallas={"auto": None, "on": True,
                                    "off": False}[args.kernel],
                        kernel_splits=args.kernel_splits,
                        decode_bucket=not args.no_decode_bucket,
                        decode_horizon=args.decode_horizon,
                        draft_config=draft_cfg,
                        spec_horizon=args.spec_horizon,
                        reserve_gentle=args.reserve_gentle,
                        state_resume=not args.no_state_resume,
                        telemetry=telemetry,
                        faults=make_serve_faults(args),
                        max_queue=args.max_queue,
                        default_deadline_s=args.deadline,
                        degrade_after=args.degrade_after,
                        nan_guard=True if args.nan_guard else None,
                        snapshot_dir=args.snapshot_dir or None,
                        snapshot_every=args.snapshot_every)
    return DecodeEngine(cfg, ecfg)


def make_serve_faults(args):
    """FaultConfig from the --fault-* flags; None when no probability is
    set (the engine keeps the shared no-op injector)."""
    ps = dict(alloc_exhaust_p=args.fault_alloc, swap_fail_p=args.fault_swap,
              row_death_p=args.fault_row_death, nan_logits_p=args.fault_nan,
              slow_tick_p=args.fault_slow_tick,
              client_abort_p=args.fault_abort)
    if not any(ps.values()):
        return None
    from repro.runtime.faults import FaultConfig
    return FaultConfig(seed=args.fault_seed, **ps)


def make_cluster_faults(args):
    """Cluster-level FaultConfig (engine death + handoff damage) from the
    --fault-engine-death / --fault-handoff-* flags; None when all are 0."""
    ps = dict(engine_death_p=args.fault_engine_death,
              handoff_corrupt_p=args.fault_handoff_corrupt,
              handoff_torn_p=args.fault_handoff_torn)
    if not any(ps.values()):
        return None
    from repro.runtime.faults import FaultConfig
    return FaultConfig(seed=args.fault_seed, **ps)


def serve_cluster(args) -> int:
    """--disagg path: route the trace through an EngineCluster fleet
    (``--engines P+D`` prefill/decode members) instead of one engine.
    Greedy outputs are token-identical to the single-engine run; the
    summary reports the router's handoff/recovery counters."""
    from repro.serving import ClusterConfig, EngineCluster
    try:
        n_p, n_d = (int(x) for x in args.engines.split("+"))
    except ValueError:
        raise SystemExit(f"--engines wants P+D (e.g. 1+1), "
                         f"got {args.engines!r}")
    tel_cfg = make_serve_tel_cfg(args)
    cfg = replace(reduced(get_config(args.arch)), dtype="float32")
    ecfg = EngineConfig(n_slots=args.slots, page_size=args.page,
                        n_pages=args.pages, max_context=args.max_context,
                        static_alloc=args.static, eos_token=-1,
                        prefill_mode=args.prefill_mode,
                        prefill_chunk=args.chunk,
                        sched_policy=args.sched_policy,
                        decode_horizon=args.decode_horizon,
                        state_resume=not args.no_state_resume,
                        telemetry=tel_cfg,
                        faults=make_serve_faults(args),
                        degrade_after=args.degrade_after)
    ccfg = ClusterConfig(n_prefill=n_p, n_decode=n_d,
                         max_backlog=args.max_queue,
                         snapshot_dir=args.snapshot_dir or None,
                         snapshot_every=args.snapshot_every,
                         faults=make_cluster_faults(args),
                         telemetry=tel_cfg)
    cl = EngineCluster(cfg, ecfg, ccfg)
    if cl.tel.enabled and args.metrics_port >= 0:
        from repro.telemetry.prom import MetricsServer
        srv = MetricsServer(cl.tel.registry, args.metrics_port)
        print(f"[serve] metrics: {srv.url}", flush=True)
    submit_trace(cl, args)
    t0 = time.time()
    cl.run(100_000)
    dt = time.time() - t0
    toks = sum(len(v) for v in cl.outputs.values())
    done = sum(1 for r in cl.reqs.values() if r["state"] == "done")
    c = cl.counters
    print(f"[serve] mode=disagg engines={n_p}p+{n_d}d "
          f"prefill={args.prefill_mode} "
          f"completed={done}/{args.requests} aborted={len(cl.aborted)} "
          f"tokens={toks} tok/s={toks / max(dt, 1e-9):.1f}", flush=True)
    print(f"[serve] cluster: handoffs={c['handoffs']} ok={c['handoff_ok']} "
          f"retries={c['handoff_retries']} timeouts={c['handoff_timeouts']} "
          f"redispatches={c['handoff_redispatches']} "
          f"redrives={c['handoff_redrives']} deaths={c['engine_deaths']} "
          f"restores={c['engine_restores']} "
          f"redispatched_requests={c['redispatched_requests']} "
          f"shed={c['shed']} degraded_mode={cl.degraded_mode}", flush=True)
    if cl.tel.enabled:
        print(f"[serve] {cl.tel.stats_line()}", flush=True)
        cl.tel.close()
    return done


def submit_trace(eng: DecodeEngine, args) -> None:
    rng = np.random.default_rng(0)
    # scale the LongBench length distribution into this toy max_context so
    # its VARIABILITY survives (clamping would park every prompt at the cap,
    # hiding exactly the effect DPA exploits — paper Table 2 / §5.4)
    from repro.data.pipeline import LONGBENCH_STATS
    factor = (args.max_context / 2) / LONGBENCH_STATS[args.task]["mean"]
    trace = request_trace(args.task, args.requests, seed=0,
                          mean_new_tokens=args.mean_new)
    # common system prompt: every request opens with the same token run —
    # the multi-tenant traffic shape radix prefix sharing pays off on
    system = rng.integers(0, eng.cfg.vocab_size,
                          size=args.max_context) if args.shared_frac else None
    for i, (plen, new) in enumerate(trace):
        plen = max(1, min(int(plen * factor),
                          args.max_context - new - 1))
        prompt = rng.integers(0, eng.cfg.vocab_size, size=plen)
        if system is not None:
            k = min(int(plen * args.shared_frac), plen - 1)
            prompt[:k] = system[:k]
        eng.submit(Request(i, prompt, new))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--task", default="musique")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page", type=int, default=16)
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--max-context", type=int, default=512)
    ap.add_argument("--mean-new", type=int, default=24)
    ap.add_argument("--static", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--prefill-mode", default="batched",
                    choices=["slot", "batched", "chunked"])
    ap.add_argument("--chunk", type=int, default=32)
    # choices come from the policy registry (serving.policies) — a policy
    # registered with @register_policy is immediately launchable here
    ap.add_argument("--sched-policy", "--policy", dest="sched_policy",
                    default="fcfs", choices=available_policies())
    ap.add_argument("--shared-frac", type=float, default=0.0,
                    help="fraction of each prompt drawn from a common "
                         "system prompt")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix sharing across requests")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host offload tier capacity in pages (0 = none)")
    ap.add_argument("--kernel", default="auto", choices=["auto", "on", "off"],
                    help="decode-attention pallas kernel path: auto = on "
                         "TPU only (interpret autodetected via "
                         "REPRO_KERNEL_INTERPRET)")
    ap.add_argument("--kernel-splits", type=int, default=1,
                    help="split-K partitions of the page axis per kernel "
                         "call")
    ap.add_argument("--no-decode-bucket", action="store_true",
                    help="disable pow2 live-page bucketing of the decode "
                         "block table")
    ap.add_argument("--no-state-resume", action="store_true",
                    help="recurrent/enc-dec archs: disable preemption "
                         "snapshots of the recurrent carry (+written KV), "
                         "falling back to full re-prefill on resume")
    from repro.configs.base import ParallelConfig
    ap.add_argument("--decode-horizon", type=int,
                    default=ParallelConfig().decode_horizon,
                    help="fused decode steps per engine tick (one jit, one "
                         "host sync per horizon); 1 = per-token dispatch")
    ap.add_argument("--draft", default="",
                    help="speculative decoding: arch name for a 1-layer "
                         "reduced draft model proposing tokens the target "
                         "verifies in one multi-query pass (greedy outputs "
                         "stay token-identical)")
    ap.add_argument("--spec-horizon", type=int, default=4,
                    help="max draft proposals per slot per tick (emits up "
                         "to spec-horizon+1 tokens per sync)")
    ap.add_argument("--reserve-gentle", action="store_true",
                    help="horizon reservation declines to evict radix-"
                         "cached pages, degrading the horizon instead")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus text on this port (0 = "
                         "ephemeral, printed; -1 = off)")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto/chrome-trace JSON of the tick "
                         "pipeline to this path")
    ap.add_argument("--request-log", default="",
                    help="append one JSON record per finished request to "
                         "this path")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    help="print a telemetry stats line every S seconds "
                         "while serving (0 = off)")
    # ---- disaggregation (docs/serving.md) ----
    ap.add_argument("--disagg", action="store_true",
                    help="serve through a disaggregated prefill/decode "
                         "engine fleet (EngineCluster) with crash-safe KV "
                         "handoff instead of one colocated engine")
    ap.add_argument("--engines", default="1+1",
                    help="fleet shape for --disagg: P+D prefill/decode "
                         "member counts (e.g. 2+2)")
    # ---- robustness (docs/robustness.md) ----
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission queue: load-shed beyond this "
                         "many waiting requests (0 = unbounded)")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="default per-request wall-clock deadline in "
                         "seconds; expired requests are torn down at the "
                         "next tick (0 = none)")
    ap.add_argument("--degrade-after", type=int, default=3,
                    help="fault events before the degradation ladder "
                         "downgrades a tier (spec off, horizon 1, host "
                         "tier dropped); 0 disables")
    ap.add_argument("--nan-guard", action="store_true",
                    help="quarantine requests whose logits/sampled ids go "
                         "non-finite or out of range (auto-armed when "
                         "fault injection is on)")
    ap.add_argument("--snapshot-dir", default="",
                    help="write crash-consistent serving snapshots here")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot cadence in engine ticks (0 = off)")
    ap.add_argument("--restore", action="store_true",
                    help="restore the latest snapshot from --snapshot-dir "
                         "before serving (resumes in-flight requests)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic fault injector")
    for flag, kind in (("--fault-alloc", "page-pool exhaustion"),
                       ("--fault-swap", "host swap-in failure"),
                       ("--fault-row-death", "serving-row death"),
                       ("--fault-nan", "NaN-logits quarantine"),
                       ("--fault-slow-tick", "straggler tick"),
                       ("--fault-abort", "client abort"),
                       ("--fault-engine-death", "pool engine death "
                        "(--disagg)"),
                       ("--fault-handoff-corrupt", "handoff byte flip "
                        "(--disagg)"),
                       ("--fault-handoff-torn", "handoff truncation "
                        "(--disagg)")):
        ap.add_argument(flag, type=float, default=0.0,
                        help=f"per-decision injection probability: {kind}")
    args = ap.parse_args(argv)

    if args.disagg:
        return serve_cluster(args)
    tel = make_serve_telemetry(args)
    eng = build_engine(args, telemetry=tel)
    if tel.enabled and args.metrics_port >= 0:
        from repro.telemetry.prom import MetricsServer
        srv = MetricsServer(tel.registry, args.metrics_port)
        print(f"[serve] metrics: {srv.url}", flush=True)
    stop_stats = None
    if tel.enabled and args.stats_every > 0:
        import threading
        stop_stats = threading.Event()

        def _ticker():
            while not stop_stats.wait(args.stats_every):
                print(f"[serve] {tel.stats_line()}", flush=True)

        threading.Thread(target=_ticker, name="stats-line",
                         daemon=True).start()
    if args.restore and args.snapshot_dir:
        step = eng.restore_snapshot()
        print(f"[serve] restored snapshot step={step} from "
              f"{args.snapshot_dir}" if step is not None else
              f"[serve] no snapshot in {args.snapshot_dir}; cold start",
              flush=True)
    else:
        submit_trace(eng, args)

    t0 = time.time()
    eng.run(100_000)
    dt = time.time() - t0
    if stop_stats is not None:
        stop_stats.set()
    st = eng.batcher.stats
    toks = sum(len(v) for v in eng.outputs.values())
    tm = eng.timing.as_dict()
    print(f"[serve] mode={'static' if args.static else 'lazy(DPA)'} "
          f"prefill={eng.prefiller.name} policy={eng.batcher.policy.name} "
          f"completed={st.completed}/{args.requests} "
          f"avg_batch={st.avg_batch:.2f} preempted={st.preempted} "
          f"tokens={toks} tok/s={toks / max(dt, 1e-9):.1f} "
          f"host_us/step={tm['host_us_per_step']:.0f} "
          f"horizon={args.decode_horizon} "
          f"syncs/tok={tm['syncs_per_token']:.3f}", flush=True)
    bal = eng.alloc.shard_balance()
    print(f"[serve] page balance per shard: max={bal.max()} min={bal.min()}",
          flush=True)
    if eng.has_rstate:
        print(f"[serve] rstate: snapshots={eng.rstate_snapshots} "
              f"restores={eng.rstate_restores}", flush=True)
    if eng.draft_cfg is not None:
        acc = 1 + eng.spec_accepted / max(1, eng.spec_rounds)
        print(f"[serve] spec: draft={args.draft} rounds={eng.spec_rounds} "
              f"accepted={eng.spec_accepted}/{eng.spec_proposed} "
              f"accept_len_mean={acc:.2f}", flush=True)
    if eng.faults.enabled or eng.aborted or eng.degraded_mode \
            or eng.snapshot_saves:
        print(f"[serve] robustness: aborted={len(eng.aborted)} "
              f"{dict(eng.abort_counts)} faults={eng.faults.total_fired} "
              f"migrated={st.migrated} degraded_mode={eng.degraded_mode} "
              f"snapshots={eng.snapshot_saves}", flush=True)
    if eng.cache is not None:
        cs = eng.cache.stats_dict()
        print(f"[serve] kvcache: hits={cs['hits']}/{cs['lookups']} "
              f"reused_tokens={cs['hit_tokens']} cow={cs['cow_copies']} "
              f"evicted={cs['evicted_pages']} "
              f"swap_out={cs.get('swapped_out_pages', 0)} "
              f"swap_in={cs.get('swapped_in_pages', 0)}", flush=True)
    if tel.enabled:
        print(f"[serve] {tel.stats_line()}", flush=True)
        sm = tel.summary()
        if "ttft_p50_ms" in sm:
            print(f"[serve] latency: ttft p50/p90/p99 = "
                  f"{sm['ttft_p50_ms']:.1f}/{sm['ttft_p90_ms']:.1f}/"
                  f"{sm['ttft_p99_ms']:.1f} ms  tpot p50 = "
                  f"{sm.get('tpot_p50_ms', 0):.2f} ms", flush=True)
        n = tel.save_trace()
        if n is not None:
            print(f"[serve] trace: {args.trace_out} ({n} events)",
                  flush=True)
        tel.close()
    return st.avg_batch


if __name__ == "__main__":
    main()
