"""§Roofline report: read the dry-run JSONs, emit the per-cell table.

  PYTHONPATH=src python -m repro.launch.roofline [--mesh pod1|pod2|all]

Per (arch x shape x mesh): the three roofline terms (s), dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs usefulness ratio, and the lever that would move the
dominant term. Hardware: 197 bf16 TFLOP/s, 819 GB/s HBM, 50 GB/s ICI/link.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, get_config

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(arch: str, shape_name: str, n_dev: int) -> float:
    """Useful model FLOPs per device per step (6ND train, 2ND inference)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tok = shape.global_batch * shape.seq_len
        return 6.0 * n * tok / n_dev
    if shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len
        return 2.0 * n * tok / n_dev
    # decode: one token per request + the attention KV read math
    tok = shape.global_batch
    attn = (2.0 * shape.global_batch * shape.seq_len
            * cfg.kv_bytes_per_token() / 2)
    return (2.0 * n * tok + attn) / n_dev


def lever(row: dict) -> str:
    b = row["roofline"]["bottleneck"]
    kind = SHAPES[row["shape"]].kind
    if b == "collective":
        return ("shrink KV/weight gathers: head/TP attention or bf16 "
                "collectives" if kind != "decode"
                else "reduce merge/psum traffic (fewer merge axes)")
    if b == "memory":
        return ("bound gathered KV to the window / fuse attention intermediates"
                if kind == "decode" else
                "larger attention chunks; bf16 intermediates; fewer rematerialized reads")
    return "already compute-bound: raise MXU utilization (layout/fusion)"


def load(mesh_filter: str = "all"):
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            rows.append(d)
            continue
        mesh = "pod2" if d["multi_pod"] else "pod1"
        if mesh_filter != "all" and mesh != mesh_filter:
            continue
        mf = model_flops(d["arch"], d["shape"], d["devices"])
        d["model_flops_ratio"] = mf / max(d["hlo"]["flops"], 1.0)
        rows.append(d)
    return rows


def emit_markdown(rows, *, include_levers: bool = True) -> str:
    out = ["| arch | shape | mesh | peak GiB/dev | t_comp s | t_mem s | "
           "t_coll s | bottleneck | MODEL/HLO flops |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if not d.get("ok"):
            out.append(f"| {d['arch']} | {d['shape']} | - | FAIL | | | | | |")
            continue
        r = d["roofline"]
        m = d["memory"]
        mesh = ("pod2" if d["multi_pod"] else "pod1") + \
            ("/pp" if d.get("pod_mode") == "pp" else "")
        out.append(
            f"| {d['arch']} | {d['shape']} | {mesh} | "
            f"{m.get('peak_bytes_tpu_adjusted', m['peak_bytes']) / 2**30:.2f} | "
            f"{r['t_compute']:.3f} | {r['t_memory']:.3f} | "
            f"{r['t_collective']:.3f} | {r['bottleneck']} | "
            f"{d['model_flops_ratio']:.2f} |")
    if include_levers:
        out.append("")
        out.append("Levers for the dominant term (per bottleneck class):")
        seen = set()
        for d in rows:
            if not d.get("ok"):
                continue
            key = (d["roofline"]["bottleneck"], SHAPES[d["shape"]].kind)
            if key in seen:
                continue
            seen.add(key)
            out.append(f"- {key[1]}/{key[0]}-bound: {lever(d)}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "all"])
    args = ap.parse_args()
    rows = load(args.mesh)
    print(emit_markdown(rows))
    n_fail = sum(1 for d in rows if not d.get("ok"))
    print(f"\n{len(rows) - n_fail} cells ok, {n_fail} failed")


if __name__ == "__main__":
    main()
