"""Per-op attribution for one dry-run cell — the 'profiler' of the
hypothesis->change->measure loop (§Perf). Since the runtime is CPU-only, the
profile is the lowered HLO: top contributors to bytes / flops / collectives,
with while-loop trip weighting.

  PYTHONPATH=src python -m repro.launch.profile_cell --arch gemma3-27b \
      --shape decode_32k [--multi-pod] [--top 25] [--kind bytes|coll|flops]
"""
import argparse
import re

import jax

from repro.launch import hlo_analysis as H


def profile(arch, shape, multi_pod=False, pod_mode="dp", top=25,
            parallel=None):
    from repro.launch.dryrun import build_cell
    step, args, in_sh, out_sh, plan = build_cell(
        arch, shape, multi_pod=multi_pod, pod_mode=pod_mode,
        parallel=parallel)
    compiled = jax.jit(step, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*args).compile()
    txt = compiled.as_text()
    comps = H.parse_computations(txt)
    trips = {}
    for c in comps.values():
        for body, cond in c.whiles:
            trips[body] = comps[cond].max_const if cond in comps else 1

    def weight(cname, depth=0):
        """Product of trip counts on the path from entry (approx: direct)."""
        w = trips.get(cname, 1)
        # one level of nesting is common (tick loop > layer loop)
        for c in comps.values():
            for body, cond in c.whiles:
                if body == cname and c.name in trips:
                    w *= trips[c.name]
        return w

    rows = []
    for c in comps.values():
        w = weight(c.name)
        for op in c.ops:
            if op.kind in H._SKIP_BYTES:
                continue
            b = op.bytes_ * w
            fl = 0.0
            if op.kind == "dot":
                pass
            coll = H._shape_bytes(op.out_type) * w if any(
                op.kind.startswith(k) for k in H.COLLECTIVES) else 0.0
            meta = re.search(r'op_name="([^"]*)"', op.line)
            rows.append((b, coll, op.kind, op.out_type[:40], c.name[:34],
                         (meta.group(1)[-100:] if meta else ""), w))
    return rows, H.analyze(txt), compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pod-mode", default="dp")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--kind", default="bytes", choices=["bytes", "coll"])
    args = ap.parse_args()
    rows, summary, _ = profile(args.arch, args.shape,
                               multi_pod=args.multi_pod,
                               pod_mode=args.pod_mode, top=args.top)
    key = 0 if args.kind == "bytes" else 1
    rows.sort(key=lambda r: -r[key])
    print(f"== {args.arch} {args.shape} summary: "
          f"flops={summary['flops']:.3e} hbm={summary['hbm_bytes'] / 2**30:.2f}GiB "
          f"coll={summary['collective_bytes'] / 2**30:.2f}GiB ==")
    for b, coll, kind, t, cname, meta, w in rows[:args.top]:
        v = b if args.kind == "bytes" else coll
        if v <= 0:
            continue
        print(f"{v / 2**30:8.3f}GiB x{w:4d} {kind:22s} {t:40s} {cname:34s} {meta}")


if __name__ == "__main__":
    main()
