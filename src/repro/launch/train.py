"""End-to-end training driver: config -> mesh -> sharded train loop with
checkpoint/restart.

Single-host usage (examples/train_100m.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 300 \
      --d-model 512 --layers 8 --seq 512 --batch 8

On a real cluster each host runs the same binary under jax.distributed;
device count and mesh shape come from the environment. Fault tolerance: the
loop checkpoints every --ckpt-every steps (crash-safe manifests), restores
the latest complete step on restart, and the data pipeline is seeded per
step so the token stream replays identically.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ParallelConfig, get_config
from repro.data.pipeline import TrainPipeline
from repro.models import model as MDL
from repro.runtime import checkpoint as CK
from repro.training import optimizer as OPT
from repro.training.train import make_train_step


def shrink(cfg, args):
    """Optionally shrink the arch for laptop-scale runs (~100M params)."""
    kw = {}
    if args.d_model:
        kw.update(d_model=args.d_model,
                  n_heads=max(4, args.d_model // 128),
                  n_kv_heads=max(2, min(cfg.n_kv_heads,
                                        args.d_model // 256)),
                  d_head=min(cfg.d_head, 64) if cfg.d_head else cfg.d_head)
        if cfg.d_ff:
            kw["d_ff"] = args.d_model * 4
    if args.layers:
        n = args.layers
        if len(cfg.pattern) > 1:
            n = max(len(cfg.pattern), n - n % len(cfg.pattern))
        kw["n_layers"] = n
    if args.vocab:
        kw["vocab_size"] = args.vocab
    return replace(cfg, **kw, dtype="float32")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = shrink(get_config(args.arch), args)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"seq={args.seq} batch={args.batch}", flush=True)

    opt_cfg = OPT.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                              total_steps=args.steps)
    rt = MDL.DEFAULT_RT
    step_fn = jax.jit(make_train_step(cfg, rt, opt_cfg))
    opt = OPT.init(params)
    pipe = TrainPipeline(cfg.vocab_size, args.seq, args.batch)

    start = 0
    if args.ckpt_dir:
        latest = CK.latest_step(args.ckpt_dir)
        if latest is not None:
            state = CK.restore(args.ckpt_dir, latest,
                               {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = latest + 1
            print(f"[train] restored step {latest}", flush=True)

    t0, tok = time.time(), 0
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        tok += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tok / max(dt, 1e-9):,.0f}",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            CK.save(args.ckpt_dir, step, {"params": params, "opt": opt})
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
