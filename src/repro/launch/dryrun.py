import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: the dry-run (and only the dry-run)
# builds the production mesh from 512 placeholder host devices.

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step / prefill /
decode_step) with the cell's sharding plan, lowers it against
ShapeDtypeStruct stand-ins (no allocation), compiles, and records

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    — XLA's (loop-body-once) numbers,
  * our trip-count-aware HLO analysis (flops / hbm bytes / collective bytes
    per device) — the §Roofline inputs,

into results/dryrun/<cell>.json. Failures (sharding mismatch, OOM at
compile, unsupported collective) are bugs in the system, per the assignment.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import (ASSIGNED, SHAPES, ParallelConfig, applicable_shapes,
                           get_config)
from repro.core.paged_kv import pool_spec_for
from repro.distributed.sharding import make_plan
from repro.launch import hlo_analysis as HLO
from repro.launch.mesh import make_production_mesh
from repro.models import model as MDL
from repro.training import optimizer as OPT
from repro.training.train import make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

SDS = jax.ShapeDtypeStruct


def input_specs(cfg, shape, parallel, *, mode: str):
    """ShapeDtypeStruct stand-ins for every model input of a step —
    weak-type-correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if mode in ("train", "prefill"):
        d: dict = {"tokens": SDS((B, S), i32)}
        if mode == "train":
            d["targets"] = SDS((B, S), i32)
            d["mask"] = SDS((B, S), jnp.float32)
        if cfg.rope_kind == "mrope":
            d["positions"] = SDS((3, B, S), i32)
            d["extra_embeds"] = SDS((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.family == "encdec":
            d["frames"] = SDS((B, cfg.enc_seq, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        return d
    # decode: one new token against a seq_len KV cache
    spec = pool_spec_for(cfg, shape, parallel)
    maxp = spec.max_pages_per_req
    d = {"tokens": SDS((B,), i32), "bt": SDS((B, maxp), i32),
         "ctx": SDS((B,), i32), "npage": SDS((B,), i32),
         "noff": SDS((B,), i32)}
    if cfg.rope_kind == "mrope":
        d["positions"] = SDS((3, B, 1), i32)
    return d


def batch_shardings(cfg, shape, plan, *, mode: str):
    dp, tp, b = plan.dp_spec, plan.tp_axis, plan.batch_spec
    seq = tp if plan.seq_divisible else None
    if plan.train_layout == "fsdp" and mode in ("train", "prefill"):
        dp, seq = plan.full_batch_spec, None
    if mode in ("train", "prefill"):
        d = {"tokens": P(dp, seq)}
        if mode == "train":
            d["targets"] = P(dp, seq)
            d["mask"] = P(dp, seq)
        if cfg.rope_kind == "mrope":
            d["positions"] = P(None, dp, seq)
            d["extra_embeds"] = P(dp, seq, None)
        if cfg.family == "encdec":
            d["frames"] = P(dp, None, None)
        return d
    d = {"tokens": P(b), "bt": P(b, None), "ctx": P(b), "npage": P(b),
         "noff": P(b)}
    if cfg.rope_kind == "mrope":
        d["positions"] = P(None, b, None)
    return d


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               pod_mode: str = "dp", parallel: ParallelConfig | None = None):
    """Returns (step_fn, args tuple of SDS, in_shardings, out_shardings)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    parallel = parallel or ParallelConfig(pods=2 if multi_pod else 1)
    plan = make_plan(mesh, parallel, shape, pod_mode=pod_mode)
    mode = shape.kind
    moe_virtual = parallel.tp if cfg.is_moe else 0

    def make_params():
        p = MDL.init_params(cfg, jax.random.PRNGKey(0),
                            moe_virtual=moe_virtual)
        if parallel.serve_quant == "int8" and mode == "decode":
            from repro.core.quant import quantize_params
            p = quantize_params(p)
        return p

    params_sds = jax.eval_shape(make_params)
    p_train = plan.param_specs(params_sds, mode="train")
    p_serve = plan.param_specs(params_sds, mode="serve")
    binp = input_specs(cfg, shape, parallel, mode=mode)
    bshard = batch_shardings(cfg, shape, plan, mode=mode)

    if mode == "train":
        rt = plan.make_runtime(cfg, parallel, mode="train")
        opt_cfg = OPT.AdamWConfig()
        step = make_train_step(cfg, rt, opt_cfg,
                               microbatches=parallel.microbatches)
        opt_sds = jax.eval_shape(OPT.init, params_sds)
        opt_spec = {"m": p_train, "v": p_train, "step": P()}
        args = (params_sds, opt_sds, binp)
        in_sh = (plan.named(p_train), plan.named(opt_spec), plan.named(bshard))
        out_sh = (plan.named(p_train), plan.named(opt_spec), None)
        return step, args, in_sh, out_sh, plan

    pool = pool_spec_for(cfg, shape, parallel)
    state_sds = jax.eval_shape(
        lambda: MDL.init_decode_state(cfg, pool, shape.global_batch))
    s_spec = plan.decode_state_specs(state_sds)

    if mode == "prefill":
        rt = plan.make_runtime(cfg, parallel, pool_spec=pool, mode="prefill")

        def step(params, state, batch):
            return MDL.prefill(cfg, params, state, batch["tokens"],
                               batch["bt"], positions=batch.get("positions"),
                               extra_embeds=batch.get("extra_embeds"),
                               frames=batch.get("frames"), rt=rt)

        binp = dict(binp)
        binp["bt"] = SDS((shape.global_batch, pool.max_pages_per_req),
                         jnp.int32)
        bshard = dict(bshard)
        bshard["bt"] = P(plan.dp_spec, None)
        args = (params_sds, state_sds, binp)
        in_sh = (plan.named(p_train), plan.named(s_spec), plan.named(bshard))
        out_sh = (None, plan.named(s_spec))
        return step, args, in_sh, out_sh, plan

    # decode
    if pod_mode == "pp" and multi_pod:
        # paper-faithful pipeline decode: stages over the pod axis
        from repro.distributed.pipeline import make_pp_decode_step
        assert cfg.uniform_stack or all(
            k in ("attn", "local") for k in cfg.block_kinds()), cfg.name
        mb = max(2, min(8, shape.global_batch // max(plan.dp_total, 1)))
        mb = min(mb, shape.global_batch)
        step = make_pp_decode_step(cfg, plan, parallel, pool,
                                   n_stages=2, microbatches=mb)
        s_spec = dict(s_spec)
        s_spec["pool"] = {  # layer dim stage-sharded over 'pod'
            "k": P("pod", plan.page_axes, None, None, None),
            "v": P("pod", plan.page_axes, None, None, None)}
        # layer weights stage-sharded over 'pod' too: each pod holds only
        # its pipeline stage's layers (the paper's PP capacity win)
        p_serve = dict(p_serve)
        p_serve["layers"] = jax.tree.map(
            lambda s: P("pod", *s[1:]), p_serve["layers"],
            is_leaf=lambda x: isinstance(x, P))
        args = (params_sds, state_sds, binp)
        in_sh = (plan.named(p_serve), plan.named(s_spec), plan.named(bshard))
        out_sh = (None, plan.named(s_spec))
        return step, args, in_sh, out_sh, plan

    rt = plan.make_runtime(cfg, parallel, pool_spec=pool, mode="decode")

    def step(params, state, batch):
        return MDL.decode_step(cfg, params, state, batch["tokens"],
                               batch["bt"], batch["ctx"], batch["npage"],
                               batch["noff"],
                               positions=batch.get("positions"), rt=rt)

    args = (params_sds, state_sds, binp)
    in_sh = (plan.named(p_serve), plan.named(s_spec), plan.named(bshard))
    out_sh = (None, plan.named(s_spec))
    return step, args, in_sh, out_sh, plan


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             pod_mode: str = "dp", save: bool = True, verbose: bool = True,
             parallel: ParallelConfig | None = None, tag: str = "") -> dict:
    t0 = time.time()
    cell = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if pod_mode != "dp":
        cell += f"__{pod_mode}"
    if tag:
        cell += f"__{tag}"
    try:
        step, args, in_sh, out_sh, plan = build_cell(
            arch, shape_name, multi_pod=multi_pod, pod_mode=pod_mode,
            parallel=parallel)
        # NOTE: buffer donation (donate_argnums on state/params) is standard
        # on the TPU target; on the CPU dry-run backend it perturbs buffer
        # assignment and worsens the measured proxy (§Perf H4, refuted for
        # this measurement path), so cells are lowered without it.
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = HLO.analyze(compiled.as_text())
        terms = HLO.roofline_terms(hlo)
        n_dev = int(np.prod(
            make_production_mesh(multi_pod=multi_pod).devices.shape))
        out = {
            "cell": cell, "arch": arch, "shape": shape_name,
            "multi_pod": multi_pod, "pod_mode": pod_mode, "ok": True,
            "devices": n_dev,
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "peak_bytes": int(mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes),
                # minus hoisted bf16->f32 weight upcasts (CPU-backend-only;
                # TPU MXU consumes bf16 — see hlo_analysis.cpu_upcast_bytes)
                "peak_bytes_tpu_adjusted": int(
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    - hlo.get("cpu_upcast_bytes", 0)),
            },
            "xla_cost": {k: float(cost.get(k, 0.0))
                         for k in ("flops", "bytes accessed")},
            "hlo": {k: (v if not isinstance(v, dict) else v)
                    for k, v in hlo.items()},
            "roofline": terms,
            "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
        }
    except Exception as e:  # noqa: BLE001
        out = {"cell": cell, "arch": arch, "shape": shape_name,
               "multi_pod": multi_pod, "ok": False,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc(limit=20)}
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        (RESULTS / f"{cell}.json").write_text(json.dumps(out, indent=1))
    if verbose:
        if out["ok"]:
            m = out["memory"]["peak_bytes_tpu_adjusted"] / 2**30
            r = out["roofline"]
            print(f"[dryrun] {cell}: OK peak={m:.2f}GiB/dev "
                  f"bottleneck={r['bottleneck']} "
                  f"t=(c{r['t_compute']:.3f} m{r['t_memory']:.3f} "
                  f"x{r['t_collective']:.3f})s "
                  f"compile={out['t_compile_s']}s", flush=True)
        else:
            print(f"[dryrun] {cell}: FAIL {out['error']}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pod-mode", default="dp", choices=["dp", "pp"])
    ap.add_argument("--int8", action="store_true",
                    help="weight-only int8 on the serve path (decode cells)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    parallel = None
    tag = ""
    if args.int8:
        parallel_kw = dict(serve_quant="int8")
        tag = "int8"
    archs = [args.arch] if args.arch else list(ASSIGNED)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_fail = 0
    for arch in archs:
        shapes = [args.shape] if args.shape else applicable_shapes(arch)
        for shape in shapes:
            for mp in meshes:
                cell = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                if args.skip_existing and (RESULTS / f"{cell}.json").exists():
                    prev = json.loads((RESULTS / f"{cell}.json").read_text())
                    if prev.get("ok"):
                        print(f"[dryrun] {cell}: cached OK", flush=True)
                        n_ok += 1
                        continue
                if args.int8:
                    parallel = ParallelConfig(
                        pods=2 if mp else 1, serve_quant="int8")
                res = run_cell(arch, shape, multi_pod=mp,
                               pod_mode=args.pod_mode, parallel=parallel,
                               tag=tag)
                n_ok += res["ok"]
                n_fail += not res["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
