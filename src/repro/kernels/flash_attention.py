"""Pallas TPU kernel: forward flash attention (prefill/training forward).

The jnp online-softmax path materializes the [Sq, kv_chunk] score/probability
tensors in HBM every chunk — measured as the dominant memory term of the
prefill cells (EXPERIMENTS.md §Perf P3). This kernel keeps them in VMEM:
HBM traffic collapses to Q + K + V + O.

Grid: (batch, kv_head, q_blocks, kv_blocks) — kv innermost, sequential per
q block, with (m, l, acc) accumulators in VMEM scratch; K/V tiles stream
through the Pallas pipeline (double-buffered). GQA-grouped: the q tile is
[G * q_blk, D] for one kv head, so K/V are never repeated. Causal masking
skips fully-masked kv blocks' contribution (they still stream; a block-
sparse skip via dynamic grids is a further step).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            q_blk: int, kv_blk: int, n_kv: int, g: int, causal: bool,
            window: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)             # [G, q_blk, D]
    k = k_ref[0, :, 0, :]                           # [kv_blk, D]
    v = v_ref[0, :, 0, :]
    d = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / math.sqrt(d)                            # [G, q_blk, kv_blk]
    q_pos = (q_offset + qi * q_blk
             + jax.lax.broadcasted_iota(jnp.int32, (1, q_blk, 1), 1))
    kv_pos = ki * kv_blk + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, kv_blk), 2)
    ok = jnp.full((1, q_blk, kv_blk), True)
    if causal:
        ok = ok & (kv_pos <= q_pos)
    if window:
        ok = ok & (kv_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=2))      # [G, q_blk]
    p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=2)
    acc_s[...] = acc_s[...] * corr[..., None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _done():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-30)[..., None]
                       ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, q_blk: int = 256,
                        kv_blk: int = 256, interpret: bool | None = None):
    """q [B, Sq, H, D]; k/v [B, Skv, KVH, D] -> [B, Sq, H, D].

    Static causal/window (per-layer kernels are built per window value).
    """
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Skv)
    assert Sq % q_blk == 0 and Skv % kv_blk == 0, (Sq, q_blk, Skv, kv_blk)
    grid = (B, KVH, Sq // q_blk, Skv // kv_blk)
    qg = q.reshape(B, Sq, KVH, G, D)

    def q_map(b, h, qi, ki):
        return (b, h, 0, qi, 0)

    def kv_map(b, h, qi, ki):
        return (b, ki, h, 0)

    kernel = functools.partial(_kernel, q_blk=q_blk, kv_blk=kv_blk,
                               n_kv=Skv // kv_blk, g=G, causal=causal,
                               window=window, q_offset=q_offset)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # q arranged [B, KVH, G, Sq, D] via index_map on the reshaped view
            pl.BlockSpec((1, 1, G, q_blk, D),
                         lambda b, h, qi, ki: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, kv_blk, 1, D), kv_map),
            pl.BlockSpec((1, kv_blk, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, q_blk, D),
                               lambda b, h, qi, ki: (b, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, q_blk), jnp.float32),
            pltpu.VMEM((G, q_blk), jnp.float32),
            pltpu.VMEM((G, q_blk, D), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qg.transpose(0, 2, 3, 1, 4), k, v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
