"""Pallas TPU kernel: decode attention over a paged KV pool.

The DPA Va2Pa indirection in kernel form: block tables ride in as
scalar-prefetch operands so each grid step's ``BlockSpec`` index_map resolves
the *physical* page to stream HBM->VMEM — command-stream-free dynamic paging,
exactly the paper's Dyn-Modi operand rewriting (§5.2) mapped onto Pallas.

Grid: (batch, kv_head, n_pages). The page axis is innermost and iterates
sequentially per (b, h) on TPU, so the online-softmax accumulators (m, l, o)
live in VMEM scratch across pages, and the multi-step grid gives automatic
double-buffering of the K/V page streams — the paper's ping-pong I/O
buffering (§6) realized by the Pallas pipeline rather than explicit mux logic.

Tile shapes: K/V pages are [page_size, D] per (kv-head); with page_size=256,
D=128 the MXU operands are 128-aligned. q tile is [G, D] (G = query heads per
kv head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, ctx_ref,                 # scalar prefetch
            q_ref, k_ref, v_ref,             # VMEM tiles
            o_ref,                           # output tile
            m_s, l_s, acc_s,                 # scratch
            *, page: int, n_pages: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)                   # [G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # [page, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    d = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))                      # [G, page]
    tok = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    ok = tok < ctx_ref[b]
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))            # [G]
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(i == n_pages - 1)
    def _done():
        o_ref[0, 0] = (acc_s[...]
                       / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    interpret: bool = True):
    """q [B, KVH, G, D]; k_pages/v_pages [P, page, KVH, D];
    block_tables [B, maxp] int32 (-1 padded; clamped to 0, masked by ctx);
    ctx_lens [B] int32. Returns [B, KVH, G, D] in q.dtype.
    """
    B, KVH, G, D = q.shape
    P_, page, _, _ = k_pages.shape
    maxp = block_tables.shape[1]
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid = (B, KVH, maxp)

    def q_map(b, h, i, bt_ref, ctx_ref):
        return (b, h, 0, 0)

    def kv_map(b, h, i, bt_ref, ctx_ref):
        return (bt_ref[b, i], 0, h, 0)

    kernel = functools.partial(_kernel, page=page, n_pages=maxp)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), q_map),
                pl.BlockSpec((1, page, 1, D), kv_map),
                pl.BlockSpec((1, page, 1, D), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), q_map),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),      # m
                pltpu.VMEM((G,), jnp.float32),      # l
                pltpu.VMEM((G, D), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(bt, ctx_lens.astype(jnp.int32), q, k_pages, v_pages)
