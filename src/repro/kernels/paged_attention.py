"""Pallas TPU kernel: decode attention over a paged KV pool.

The DPA Va2Pa indirection in kernel form: block tables ride in as
scalar-prefetch operands so each grid step's ``BlockSpec`` index_map resolves
the *physical* page to stream HBM->VMEM — command-stream-free dynamic paging,
exactly the paper's Dyn-Modi operand rewriting (§5.2) mapped onto Pallas.

Two entry points:

* ``paged_attention_partials`` — the decode hot path's shard-local compute
  (``core/itpp.py``). Grid ``(n_splits, B, KVH, slots_per_split)``; each
  split emits an UNNORMALIZED ``(o, l, m)`` partial, exactly the shape the
  paper's §4.3 EPU aggregation merges across token partitions — so one
  kernel serves both the cross-shard ITPP merge and flash-decoding-style
  split-K parallelism on a single chip. The split-K axis LEADS the grid and
  is declared ``parallel`` in the Mosaic ``dimension_semantics`` (parallel
  axes must prefix the arbitrary ones), so megacore partitioning fans the
  splits out across TensorCores instead of running them sequentially on
  one — each (split, batch, head) owns its own scratch accumulation over
  the trailing ``arbitrary`` slot axis, so the partition is race-free and
  numerically identical. Nothing is gathered: K/V pages stream straight out
  of the pool (the multi-step grid double-buffers the page stream — the
  paper's ping-pong I/O, §6), replacing the gather-then-dense path's
  [B, maxp, page, KVH, D] HBM materialization.
* ``paged_attention`` — convenience full attention (partials merged and
  normalized), the single-shard kernel used by ``kernels/ops.py``.

Context-adaptive: a table slot whose page holds no live token for this
request — ``-1`` padding / unowned under ITPP, beyond ``ctx_len``, fully
below a sliding window, or an unwritten ring slot — is skipped with a
``pl.when`` early-out, so per-step work tracks the LIVE context rather than
the block-table width (the bandwidth fix LoL-PIM/PAM attribute to
context-aware KV streaming). The engine buckets the table width itself
(serving/engine.py) so even the grid tracks live pages.

Feature matrix (mirrors the gather-then-dense reference semantics):
  * ``window``       traced per-layer sliding window ([B] or scalar; 0=off),
  * ``ring_width``   sliding-window ring pools — table slots recycle
                     ``mod ring_width``, slot -> virtual page resolved
                     in-kernel from ``ctx_len``,
  * ``windowed_slice`` the cond_window trick: the caller passes only the
                     table slots overlapping the window; slot ``j`` maps to
                     virtual page ``max(ctx-w,0)//page + j``,
  * GQA ``G>=1``     q tile is [G, D] per kv head; K/V never repeated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _partials_kernel(bt_ref, ctx_ref, w_ref,         # scalar prefetch
                     q_ref, k_ref, v_ref,            # VMEM tiles
                     o_ref, l_ref, m_ref,            # per-split partials
                     m_s, l_s, acc_s,                # scratch
                     *, page: int, slots_per_split: int, ring_width: int,
                     windowed_slice: bool, qpos: int = 1):
    s = pl.program_id(0)
    b = pl.program_id(1)
    j = pl.program_id(3)
    slot = s * slots_per_split + j

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    ctx = ctx_ref[b]
    w = w_ref[b]
    # slot -> virtual page (token positions), per pool policy
    if ring_width:
        cur_vp = (ctx - 1) // page
        vp = cur_vp - ((cur_vp - slot) % ring_width)   # < 0: never written
    elif windowed_slice:
        vp = jnp.maximum(ctx - w, 0) // page + slot
    else:
        vp = slot
    lo_tok = jnp.where(w > 0, ctx - w, 0)
    pid = bt_ref[b, slot]
    # context-adaptive early-out: dead pages cost neither FLOPs nor scratch.
    # qpos > 1 (multi-query verify): the deepest query row sees qpos-1 extra
    # tokens, so the liveness bound widens by that much — per-row masking
    # below keeps shallower rows exact.
    live = ((pid >= 0) & (vp >= 0) & (vp * page < ctx + qpos - 1)
            & ((vp + 1) * page > lo_tok))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # [G*qpos, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        d = q.shape[-1]
        rows = q.shape[0]
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sc = sc / jnp.sqrt(jnp.float32(d))                 # [G*qpos, page]
        tok = vp * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        # row r of the q tile is query position ctx-1 + (r % qpos): its
        # effective context is ctx + r%qpos and its window slides with it
        t_row = jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) % qpos
        hi = ctx + t_row                                   # [rows, 1]
        lo = jnp.where(w > 0, hi - w, 0)
        ok = (tok < hi) & (tok >= lo)
        sc = jnp.where(ok, sc, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=1))        # [G]
        p = jnp.where(ok, jnp.exp(sc - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == slots_per_split - 1)
    def _emit():
        o_ref[0, 0, 0] = acc_s[...]
        l_ref[0, 0, 0] = l_s[...]
        m_ref[0, 0, 0] = m_s[...]


def paged_attention_partials(q, k_pages, v_pages, block_tables, ctx_lens, *,
                             window=None, ring_width: int = 0,
                             windowed_slice: bool = False, n_splits: int = 1,
                             qpos: int = 1, interpret: bool | None = None):
    """Split-K decode-attention partials over a paged pool.

    q [B, KVH, G, D]; k_pages/v_pages [P, page, KVH, D];
    block_tables [B, W] int32 — physical page per table slot, ``-1`` = dead
    (pad / unowned shard-locally / out of window); ctx_lens [B] int32 tokens
    INCLUDING the current one; ``window`` traced [B] or scalar (0 = full);
    ``ring_width``/``windowed_slice`` per the module docstring (mutually
    exclusive). ``qpos > 1`` is the speculative-verify multi-query mode: the
    q axis ``G`` is read as ``G_real * qpos`` consecutive query rows, row
    ``r`` attending at position ``ctx - 1 + r % qpos`` (ctx_lens still counts
    tokens INCLUDING the FIRST query row's token). Returns fp32 UNNORMALIZED
    partials (o [S, B, KVH, G, D], l [S, B, KVH, G], m [S, B, KVH, G]) for
    the stable EPU merge (``ref.combine_partials`` locally, ``pl``
    collectives across shards).
    """
    assert not (ring_width and windowed_slice)
    assert qpos == 1 or not (ring_width or windowed_slice), \
        "multi-query verify runs on plain paged tables only"
    assert not (windowed_slice and window is None), \
        "windowed_slice slot mapping is defined by the window bound"
    B, KVH, G, D = q.shape
    page = k_pages.shape[1]
    W = block_tables.shape[1]
    S = max(1, min(int(n_splits), W))
    K = -(-W // S)
    if S * K != W:                      # pad tail split with dead slots
        block_tables = jnp.pad(block_tables, ((0, 0), (0, S * K - W)),
                               constant_values=-1)
    bt = block_tables.astype(jnp.int32)
    w_arr = (jnp.zeros((B,), jnp.int32) if window is None else
             jnp.broadcast_to(jnp.asarray(window, jnp.int32).reshape(-1),
                              (B,)))

    # split-K axis first and ``parallel``: Mosaic requires parallel axes to
    # prefix arbitrary ones, and megacore partitioning then spreads the
    # splits across cores (previously all splits ran sequentially per core
    # — the ROADMAP n_splits>1 note). The trailing slot axis stays
    # ``arbitrary``: it revisits the same (s, b, h) scratch accumulator.
    grid = (S, B, KVH, K)
    semantics = ("parallel", "arbitrary", "arbitrary", "arbitrary")

    def q_map(s, b, h, j, bt_ref, ctx_ref, w_ref):
        return (b, h, 0, 0)

    def kv_map(s, b, h, j, bt_ref, ctx_ref, w_ref):
        # dead slots clamp to page 0: the fetch is pipelined away when the
        # index repeats, and pl.when skips their compute either way
        return (jnp.maximum(bt_ref[b, s * K + j], 0), 0, h, 0)

    def po_map(s, b, h, j, bt_ref, ctx_ref, w_ref):
        return (s, b, h, 0, 0)

    def pl_map(s, b, h, j, bt_ref, ctx_ref, w_ref):
        return (s, b, h, 0)

    kernel = functools.partial(_partials_kernel, page=page,
                               slots_per_split=K, ring_width=ring_width,
                               windowed_slice=windowed_slice, qpos=qpos)
    return pl.pallas_call(
        kernel,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=semantics),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), q_map),
                pl.BlockSpec((1, page, 1, D), kv_map),
                pl.BlockSpec((1, page, 1, D), kv_map),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, G, D), po_map),
                pl.BlockSpec((1, 1, 1, G), pl_map),
                pl.BlockSpec((1, 1, 1, G), pl_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),      # m
                pltpu.VMEM((G,), jnp.float32),      # l
                pltpu.VMEM((G, D), jnp.float32),    # acc
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((S, B, KVH, G, D), jnp.float32),
            jax.ShapeDtypeStruct((S, B, KVH, G), jnp.float32),
            jax.ShapeDtypeStruct((S, B, KVH, G), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(bt, ctx_lens.astype(jnp.int32), w_arr, q, k_pages, v_pages)


def paged_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                    window=None, ring_width: int = 0, n_splits: int = 1,
                    interpret: bool | None = None):
    """Full (normalized) decode attention — partials merged on-device.

    q [B, KVH, G, D]; k_pages/v_pages [P, page, KVH, D];
    block_tables [B, maxp] int32 (-1 padded); ctx_lens [B] int32.
    Returns [B, KVH, G, D] in q.dtype.
    """
    from repro.kernels.ref import combine_partials
    o, l, m = paged_attention_partials(
        q, k_pages, v_pages, block_tables, ctx_lens, window=window,
        ring_width=ring_width, n_splits=n_splits, interpret=interpret)
    o, l, _ = combine_partials(o, l, m)
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def paged_attention_verify(q, k_pages, v_pages, block_tables, ctx_lens, *,
                           window=None, n_splits: int = 1,
                           interpret: bool | None = None):
    """Multi-query verify attention for speculative decode (normalized).

    q [B, KVH, G, T, D] — ``T`` consecutive query positions per slot (the
    pending token + the draft proposals), position of query t being
    ``ctx - 1 + t``; k_pages/v_pages [P, page, KVH, D]; block_tables
    [B, maxp] int32 (-1 padded); ctx_lens [B] int32 context INCLUDING the
    FIRST query token. The T axis folds into the kernel's q-row axis
    (``qpos``) so the same split-K page stream serves all T rows — one pool
    pass verifies the whole proposal window. Returns [B, KVH, G, T, D] in
    q.dtype.
    """
    from repro.kernels.ref import combine_partials
    B, KVH, G, T, D = q.shape
    o, l, m = paged_attention_partials(
        q.reshape(B, KVH, G * T, D), k_pages, v_pages, block_tables,
        ctx_lens, window=window, n_splits=n_splits, qpos=T,
        interpret=interpret)
    o, l, _ = combine_partials(o, l, m)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, KVH, G, T, D).astype(q.dtype)
