"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import NEG_INF


def paged_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens):
    """Decode attention over a paged pool.

    q [B, KVH, G, D]; k_pages/v_pages [P, page, KVH, D];
    block_tables [B, maxp]; ctx_lens [B] (valid tokens incl. current).
    Returns [B, KVH, G, D] fp32.
    """
    B, KVH, G, D = q.shape
    maxp = block_tables.shape[1]
    page = k_pages.shape[1]
    safe = jnp.maximum(block_tables, 0)
    k = k_pages[safe].reshape(B, maxp * page, KVH, D)     # [B, T, KVH, D]
    v = v_pages[safe].reshape(B, maxp * page, KVH, D)
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    tok = jnp.arange(maxp * page)[None]
    ok = tok < ctx_lens[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))


def paged_attention_verify_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                               window=None):
    """Multi-query verify attention over a paged pool (speculative decode).

    q [B, KVH, G, T, D] — T consecutive query positions per slot, query t
    sitting at position ``ctx - 1 + t``; ctx_lens [B] counts tokens
    INCLUDING the first query token, so query t attends to tok < ctx + t
    (and >= ctx + t - window when windowed). Returns [B, KVH, G, T, D] fp32.
    """
    B, KVH, G, T, D = q.shape
    maxp = block_tables.shape[1]
    page = k_pages.shape[1]
    safe = jnp.maximum(block_tables, 0)
    k = k_pages[safe].reshape(B, maxp * page, KVH, D)
    v = v_pages[safe].reshape(B, maxp * page, KVH, D)
    s = jnp.einsum("bkgqd,btkd->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    tok = jnp.arange(maxp * page)[None, None]             # [1, 1, P*page]
    hi = ctx_lens[:, None, None] + jnp.arange(T)[None, :, None]
    ok = tok < hi                                         # [B, T, P*page]
    if window is not None:
        w = jnp.broadcast_to(jnp.asarray(window, jnp.int32).reshape(-1),
                             (B,))[:, None, None]
        ok = ok & jnp.where(w > 0, tok >= hi - w, True)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,btkd->bkgqd", p, v.astype(jnp.float32))


def flash_decode_ref(q, k, v, ctx_len, n_splits: int):
    """ITPP split-K decode partials oracle.

    q [B, KVH, G, D]; k/v [B, T, KVH, D]; ctx_len [B]. ``T`` need not divide
    ``n_splits``: the tail split is zero-padded and masked (same split
    boundaries as the kernel, so partials compare elementwise).
    Returns per-split partials (o [S,B,KVH,G,D], l [S,B,KVH,G], m [S,...])
    whose stable merge equals full attention.
    """
    B, KVH, G, D = q.shape
    T = k.shape[1]
    w = -(-T // n_splits)
    if w * n_splits != T:
        pad = w * n_splits - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ctx_len = jnp.minimum(ctx_len, T)      # pad tokens are never live
    outs, ls, ms = [], [], []
    for s in range(n_splits):
        ks = k[:, s * w:(s + 1) * w].astype(jnp.float32)
        vs = v[:, s * w:(s + 1) * w].astype(jnp.float32)
        sc = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32), ks) \
            / jnp.sqrt(jnp.float32(D))
        tok = s * w + jnp.arange(w)
        ok = tok[None] < ctx_len[:, None]
        sc = jnp.where(ok[:, None, None, :], sc, NEG_INF)
        m = sc.max(-1)
        p = jnp.where(ok[:, None, None, :], jnp.exp(sc - m[..., None]), 0.0)
        l = p.sum(-1)
        o = jnp.einsum("bkgt,btkd->bkgd", p, vs)
        outs.append(o)
        ls.append(l)
        ms.append(m)
    return jnp.stack(outs), jnp.stack(ls), jnp.stack(ms)


def merge_flash_partials(o, l, m):
    """(S,...) partials -> merged attention output (log-sum-exp merge)."""
    og, lg, _ = combine_partials(o, l, m)
    return og / jnp.maximum(lg, 1e-30)[..., None]


def combine_partials(o, l, m):
    """Merge the leading split axis of (o, l, m) partials WITHOUT
    normalizing — the result is itself a valid partial (associativity of
    the EPU aggregation: intra-chip split-K merges first, the cross-shard
    ITPP merge finishes the job)."""
    mg = m.max(0)
    c = jnp.exp(m - mg[None])
    return (o * c[..., None]).sum(0), (l * c).sum(0), mg


def ssm_chunk_scan_ref(q, k, v, log_a, log_g, h0, chunk: int):
    """Chunked GLA oracle — wraps models.ssm.chunked_gla (itself validated
    against the exact sequential recurrence in tests)."""
    from repro.models.ssm import chunked_gla
    return chunked_gla(q, k, v, log_a, log_g, chunk=chunk, normalize=False,
                       state=h0)
