"""jit'd wrappers around the Pallas kernels, with reference fallbacks.

Backend selection is automatic (``kernels/backend.py``): ``use_pallas=None``
resolves to True on TPU and False elsewhere, and ``interpret=None`` resolves
to False on TPU / True elsewhere (override with ``REPRO_KERNEL_INTERPRET``).
Off-TPU production paths therefore lower the pure-jnp reference math while
tests force ``interpret=True`` to exercise the kernels themselves — the
semantics are identical (tests assert allclose).

Integration points:
  * ``paged_decode_step`` — THE decode hot path: the incoming token's K/V
    write and the context-adaptive paged-attention kernel in one dispatch
    (core/itpp.py's shard body on a single shard),
  * ``write_targets``   — per-step Va2Pa write-target resolution (npage/noff
    with idle/frozen slots routed out of bounds so the scatter drops them);
    the device-side half of the host "configuration buffer" update, used by
    the fused multi-step decode (``models.model.decode_multi``) to advance
    write positions on device between host syncs,
  * ``decode_attention`` — full-attention decode over the paged pool,
  * ``itpp_partials``   — split-K partials for the cross-shard merge,
  * ``mamba_mixer``     — Mamba2 chunk scan for train/prefill.

``KernelConfig`` (re-exported from ``kernels/backend.py``) is the single
knob object threaded from configs/launch through ``models.model.Runtime``
down to these call sites.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.backend import (DEFAULT_KERNELS, KernelConfig,
                                   default_interpret, on_tpu)
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssm_scan import ssm_chunk_scan

__all__ = ["KernelConfig", "DEFAULT_KERNELS", "decode_attention",
           "verify_attention", "paged_decode_step", "write_targets",
           "itpp_partials", "attention_fwd", "mamba_mixer",
           "merge_partials"]


def _resolve(use_pallas: bool | None) -> bool:
    return on_tpu() if use_pallas is None else bool(use_pallas)


def write_targets(block_table, ctx, run, *, page_size: int, n_pages: int,
                  ring_width: int = 0):
    """Resolve the KV write target for each slot's incoming token.

    ``block_table`` [B, W] int32 Va2Pa; ``ctx`` [B] context INCLUDING the
    incoming token; ``run`` [B] bool — slots decoding this step. Inactive /
    frozen slots target page ``n_pages`` (out of bounds) so the pool scatter
    drops their write. Traceable: the fused decode scan calls this once per
    step on device; the per-token ``serving.engine.step`` keeps a host-numpy
    twin of the same resolution (kept deliberately eager-free there) — the
    two must stay bit-identical. Returns (npage [B], noff [B]) int32.
    """
    B, W = block_table.shape
    t = jnp.maximum(jnp.asarray(ctx, jnp.int32) - 1, 0)
    vp = t // page_size
    if ring_width:
        vp = vp % ring_width
    npage = block_table[jnp.arange(B), jnp.minimum(vp, W - 1)]
    npage = jnp.where(run, npage, n_pages).astype(jnp.int32)
    noff = jnp.where(run, t % page_size, 0).astype(jnp.int32)
    return npage, noff


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "n_splits"))
def decode_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                     use_pallas: bool | None = None,
                     interpret: bool | None = None, n_splits: int = 1):
    """q [B, KVH, G, D] -> [B, KVH, G, D] (q.dtype)."""
    if _resolve(use_pallas):
        return paged_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                               n_splits=n_splits, interpret=interpret)
    return REF.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   ctx_lens).astype(q.dtype)


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "n_splits"))
def verify_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                     window=None, use_pallas: bool | None = None,
                     interpret: bool | None = None, n_splits: int = 1):
    """Speculative-verify multi-query attention over the decode table.

    q [B, KVH, G, T, D] — T consecutive query positions per slot (pending
    token + draft proposals), query t at position ``ctx - 1 + t``; ctx_lens
    counts tokens INCLUDING the first query token. One split-K pool pass
    scores all T rows (``paged_attention.paged_attention_verify``); the
    reference fallback is the gather-then-dense oracle. Returns
    [B, KVH, G, T, D] in q.dtype.
    """
    from repro.kernels.paged_attention import paged_attention_verify
    if _resolve(use_pallas):
        return paged_attention_verify(q, k_pages, v_pages, block_tables,
                                      ctx_lens, window=window,
                                      n_splits=n_splits, interpret=interpret)
    return REF.paged_attention_verify_ref(
        q, k_pages, v_pages, block_tables, ctx_lens,
        window=window).astype(q.dtype)


@partial(jax.jit, static_argnames=("ring_width", "cond_window", "kernels"))
def paged_decode_step(q, k_new, v_new, pool_k, pool_v, block_table, ctx_len,
                      new_page, new_off, window=0, *, ring_width: int = 0,
                      cond_window: int = 0,
                      kernels: KernelConfig = DEFAULT_KERNELS):
    """One decode step's attention against the paged pool, single shard:
    the incoming token's K/V scatter AND the context-adaptive attention in
    one dispatch. q [B, H, D]; k_new/v_new [B, KVH, D];
    pool_{k,v} [P, page, KVH, D]; block_table [B, maxp]; ctx_len [B]
    (INCLUDING the new token); ``window`` may be traced.
    Returns (out [B, H, D], pool_k, pool_v).
    """
    from repro.core.itpp import ItppSpec, itpp_decode_attention_shard
    spec = ItppSpec((), (), None, 1, 1, pool_k.shape[1])
    return itpp_decode_attention_shard(
        q, k_new, v_new, pool_k, pool_v, block_table, ctx_len, new_page,
        new_off, window, spec=spec, mesh_axis_sizes={},
        max_pages_per_req=block_table.shape[1], ring_width=ring_width,
        cond_window=cond_window, kernels=kernels)


@partial(jax.jit, static_argnames=("n_splits", "use_pallas", "interpret"))
def itpp_partials(q, k, v, ctx_lens, *, n_splits: int = 8,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None):
    """Split-K partials (o, l, m) for the stable ITPP/EPU merge."""
    if _resolve(use_pallas):
        return flash_decode(q, k, v, ctx_lens, n_splits=n_splits,
                            interpret=interpret)
    return REF.flash_decode_ref(q, k, v, ctx_lens, n_splits)


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret"))
def attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                  use_pallas: bool | None = None,
                  interpret: bool | None = None):
    """Forward flash attention (prefill/training fwd): [B,S,H,D] -> same."""
    if _resolve(use_pallas):
        from repro.kernels.flash_attention import flash_attention_fwd
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   interpret=interpret)
    from repro.models.layers import flash_attention
    return flash_attention(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def mamba_mixer(q, k, v, log_a, log_g, *, chunk: int = 128, state=None,
                valid_len=None, use_pallas: bool | None = None,
                interpret: bool | None = None):
    """Chunked selective scan -> (y [B,S,H,P] f32, state [B,H,N,P] f32).

    ``state`` [B,H,N,P] resumes a sequence at a chunk boundary (the
    serving engine's state-carrying chunked prefill); ``valid_len`` [B]
    masks length-bucketed end-padding out of the returned state."""
    if _resolve(use_pallas):
        return ssm_chunk_scan(q, k, v, log_a, log_g, chunk=chunk,
                              state=state, valid_len=valid_len,
                              interpret=interpret)
    if valid_len is not None:
        from repro.models.ssm import mask_log_gates_tail
        log_a, log_g = mask_log_gates_tail(log_a, log_g, valid_len)
    h0 = None if state is None else (
        state, jnp.zeros(state.shape[:-1], state.dtype),
        jnp.zeros(state.shape[:-2], state.dtype))
    y, (C, _, _) = REF.ssm_chunk_scan_ref(q, k, v, log_a, log_g, h0, chunk)
    return y, C


def merge_partials(o, l, m):
    return REF.merge_flash_partials(o, l, m)
