"""jit'd wrappers around the Pallas kernels, with reference fallbacks.

On the TPU target, pass ``use_pallas=True`` (ParallelConfig.use_pallas) to
run the kernels compiled; on CPU (this container) the kernels execute in
interpret mode for correctness tests while production paths lower the
pure-jnp reference math (identical semantics — tests assert allclose).

Integration points:
  * ``decode_attention`` — full-attention decode over the paged pool
    (core/itpp.py's shard-local gather+partial math, kernelized),
  * ``itpp_partials``   — split-K partials for the cross-shard merge,
  * ``mamba_mixer``     — Mamba2 chunk scan for train/prefill.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssm_scan import ssm_chunk_scan


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                     use_pallas: bool = True, interpret: bool = True):
    """q [B, KVH, G, D] -> [B, KVH, G, D] (q.dtype)."""
    if use_pallas:
        return paged_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                               interpret=interpret)
    return REF.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   ctx_lens).astype(q.dtype)


@partial(jax.jit, static_argnames=("n_splits", "use_pallas", "interpret"))
def itpp_partials(q, k, v, ctx_lens, *, n_splits: int = 8,
                  use_pallas: bool = True, interpret: bool = True):
    """Split-K partials (o, l, m) for the stable ITPP/EPU merge."""
    if use_pallas:
        return flash_decode(q, k, v, ctx_lens, n_splits=n_splits,
                            interpret=interpret)
    return REF.flash_decode_ref(q, k, v, ctx_lens, n_splits)


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret"))
def attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                  use_pallas: bool = True, interpret: bool = True):
    """Forward flash attention (prefill/training fwd): [B,S,H,D] -> same."""
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention_fwd
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   interpret=interpret)
    from repro.models.layers import flash_attention
    return flash_attention(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def mamba_mixer(q, k, v, log_a, log_g, *, chunk: int = 128,
                use_pallas: bool = True, interpret: bool = True):
    """Chunked selective scan -> (y [B,S,H,P] f32, state [B,H,N,P] f32)."""
    if use_pallas:
        return ssm_chunk_scan(q, k, v, log_a, log_g, chunk=chunk,
                              interpret=interpret)
    y, (C, _, _) = REF.ssm_chunk_scan_ref(q, k, v, log_a, log_g, None, chunk)
    return y, C


def merge_partials(o, l, m):
    return REF.merge_flash_partials(o, l, m)
