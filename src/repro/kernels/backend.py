"""Backend autodetection + the KernelConfig threaded through the stack.

One knob object rides from ``configs.ParallelConfig`` / ``launch.serve``
through ``models.model.Runtime`` down to the Pallas call sites
(``kernels/ops.py``, ``core/itpp.py``): *which* compute path serves the
decode hot path and *how* the kernels execute.

Resolution rules (``KernelConfig.resolve``):

* ``use_pallas=None``  -> True on a TPU backend, False elsewhere (the
  pure-jnp reference math IS the production path off-TPU — identical
  semantics, tested);
* ``interpret=None``   -> False on TPU (compile via Mosaic), True elsewhere
  (Pallas interpret mode for correctness tests on CPU), overridable with
  the ``REPRO_KERNEL_INTERPRET`` env var (``1``/``0``).

The dataclass is frozen/hashable so it can ride as a jit static argument
and through ``functools.partial`` into shard_map bodies.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import jax

_FALSY = ("0", "false", "no", "off", "")


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # backend discovery can fail in exotic envs
        return False


def default_interpret() -> bool:
    """interpret=False on TPU, True elsewhere; REPRO_KERNEL_INTERPRET wins."""
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env.strip().lower() not in _FALSY
    return not on_tpu()


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


@dataclass(frozen=True)
class KernelConfig:
    """How the decode hot path executes (see module docstring).

    ``n_splits``: split-K partitions of the page axis inside one kernel
    call — the intra-chip analogue of the paper's TCP token split (shards
    are the inter-chip one). 1 = online-softmax over all pages in a single
    sequential pass.
    """
    use_pallas: bool | None = None
    interpret: bool | None = None
    n_splits: int = 1

    def resolve(self) -> "KernelConfig":
        return KernelConfig(
            use_pallas=on_tpu() if self.use_pallas is None else
            bool(self.use_pallas),
            interpret=resolve_interpret(self.interpret),
            n_splits=max(1, int(self.n_splits)))


DEFAULT_KERNELS = KernelConfig()


def decode_hbm_bytes(ctx_tokens: float, n_kv_heads: int, d_head: int,
                     bytes_per_el: int, n_layers: int = 1) -> float:
    """Modeled KV bytes one decode step streams from HBM for a request at
    context ``ctx_tokens``: K and V read once across the live context. The
    hot-path ideal the paged kernels approach (a dense gather reads the
    full table width instead) — used by ``benchmarks/kernel_bench`` for the
    offline MB/token report and by ``telemetry.pim_counters`` for the same
    quantity live during serving."""
    return 2.0 * ctx_tokens * n_kv_heads * d_head * bytes_per_el * n_layers
