"""Pallas TPU kernel: Mamba2 chunked selective-scan (gated linear attention).

Chunkwise-parallel SSM: inside a chunk the contribution is an attention-like
matmul pair (MXU work); across chunks a [N, P] recurrent state carries in
VMEM scratch while the grid streams chunk tiles HBM->VMEM (double-buffered —
the ping-pong pattern again). Grid: (B, H, n_chunks), chunks innermost.

Chunk-boundary continuation: ``state`` seeds the VMEM carry (a prefill chunk
resumes exactly where the previous chunk's returned state left off) and
``valid_len`` masks end-padding tails into identity recurrence steps
(decay 1, gain 0), so pow2 length-bucketed batches return the state at each
row's true last token — the two hooks behind the serving engine's
state-carrying chunked/batched prefill for recurrent hybrids.

Matches ``ref.ssm_chunk_scan_ref`` (= models.ssm.chunked_gla with
normalize=False, itself validated against the exact recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, la_ref, lg_ref, h0_ref, y_ref, hout_ref,
            state_s, *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_s[...] = h0_ref[0, 0].astype(jnp.float32)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # [chunk, N]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # [chunk, P]
    la = la_ref[0, :, 0].astype(jnp.float32)             # [chunk]
    lg = lg_ref[0, :, 0].astype(jnp.float32)

    bcum = jnp.cumsum(la)                                # [chunk]
    btot = bcum[-1]
    wlog = bcum[:, None] - bcum[None, :] + lg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    wlog = jnp.where(ii >= jj, wlog, NEG_INF)
    wmat = jnp.exp(jnp.clip(wlog, NEG_INF, 60.0))

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * wmat
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    state = state_s[...]                                 # [N, P]
    y = y + jnp.exp(bcum)[:, None] * jax.lax.dot_general(
        q, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    sc = jnp.exp(jnp.clip(btot - bcum + lg, NEG_INF, 60.0))   # [chunk]
    kv = jax.lax.dot_general(k * sc[:, None], v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    state_s[...] = state * jnp.exp(btot) + kv

    @pl.when(c == n_chunks - 1)
    def _done():
        hout_ref[0, 0] = state_s[...]


def ssm_chunk_scan(q, k, v, log_a, log_g, *, chunk: int = 128, state=None,
                   valid_len=None, interpret: bool | None = None):
    """q,k [B,S,H,N]; v [B,S,H,P]; log_a/log_g [B,S,H].

    Returns (y [B,S,H,P] fp32, state [B,H,N,P] fp32). ``state`` carries the
    previous chunk's final state in (zeros = fresh sequence); ``valid_len``
    [B] makes positions >= valid_len[b] identity steps (log_a=0,
    log_g=-inf) so length-bucketed tails never touch the returned state
    (their y rows are garbage — callers must not read them).
    """
    B, S, H, N = q.shape
    P_ = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    if valid_len is not None:
        from repro.models.ssm import mask_log_gates_tail
        log_a, log_g = mask_log_gates_tail(log_a, log_g, valid_len)
    h0 = (jnp.zeros((B, H, N, P_), jnp.float32) if state is None
          else state.astype(jnp.float32))
    grid = (B, H, n_chunks)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)

    def seq_map(b, h, c):
        return (b, c, h, 0)

    def g_map(b, h, c):
        return (b, c, h)

    def h_map(b, h, c):
        return (b, h, 0, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), seq_map),
            pl.BlockSpec((1, chunk, 1, N), seq_map),
            pl.BlockSpec((1, chunk, 1, P_), seq_map),
            pl.BlockSpec((1, chunk, 1), g_map),
            pl.BlockSpec((1, chunk, 1), g_map),
            pl.BlockSpec((1, 1, N, P_), h_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P_), seq_map),
            pl.BlockSpec((1, 1, N, P_), h_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P_), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P_), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P_), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(q, k, v, log_a, log_g, h0)
