"""Pallas TPU kernel: ITPP split-K decode attention partials.

The paper's §4.3 compute: the K-cache is partitioned along the TOKEN
dimension; each partition computes a partial attention (o, l, m) and the
partials merge with the stable log-sum-exp rule (the PIM Controller Hub's
EPU aggregation). On the mesh, partitions map to shards (core/itpp.py); on
one chip this kernel is the shard-local compute with splits = grid steps —
so it is also how flash-decoding-style split-K parallelism lands on the MXU.

Grid: (B, KVH, n_splits). Each step streams its [split, D] K/V tile
HBM->VMEM (pipeline double-buffers = ping-pong, §6) and emits one partial.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(ctx_ref, q_ref, k_ref, v_ref, o_ref, l_ref, m_ref, *,
            split: int):
    b = pl.program_id(0)
    s = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)                  # [G, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # [split, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    d = q.shape[-1]
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    sc = sc / jnp.sqrt(jnp.float32(d))                   # [G, split]
    tok = s * split + jax.lax.broadcasted_iota(jnp.int32, (1, split), 1)
    ok = tok < ctx_ref[b]
    sc = jnp.where(ok, sc, NEG_INF)
    m = sc.max(axis=1)                                   # [G]
    p = jnp.where(ok, jnp.exp(sc - m[:, None]), 0.0)
    l = p.sum(axis=1)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0, 0] = o
    l_ref[0, 0, 0] = l
    m_ref[0, 0, 0] = m


def flash_decode(q, k, v, ctx_lens, *, n_splits: int = 8,
                 interpret: bool | None = None):
    """q [B, KVH, G, D]; k/v [B, T, KVH, D]; ctx_lens [B].

    ``T`` need not divide ``n_splits``: the tail split is zero-padded and
    the in-kernel ctx mask (ctx clamped to T) keeps pad tokens dead.
    Returns per-split fp32 partials (o [S,B,KVH,G,D], l [S,B,KVH,G],
    m [S,B,KVH,G]) for the stable ITPP merge (ref.merge_flash_partials /
    core.paged_kv.merge_partials).
    """
    from repro.kernels.backend import resolve_interpret
    B, KVH, G, D = q.shape
    T = k.shape[1]
    split = -(-T // n_splits)
    if split * n_splits != T:
        pad = split * n_splits - T
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ctx_lens = jnp.minimum(ctx_lens, T)
    grid = (B, KVH, n_splits)
    kernel = functools.partial(_kernel, split=split)

    def q_map(b, h, s, ctx):
        return (b, h, 0, 0)

    def kv_map(b, h, s, ctx):
        return (b, s, h, 0)

    def po_map(b, h, s, ctx):
        return (s, b, h, 0, 0)

    def pl_map(b, h, s, ctx):
        return (s, b, h, 0)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), q_map),
                pl.BlockSpec((1, split, 1, D), kv_map),
                pl.BlockSpec((1, split, 1, D), kv_map),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, G, D), po_map),
                pl.BlockSpec((1, 1, 1, G), pl_map),
                pl.BlockSpec((1, 1, 1, G), pl_map),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n_splits, B, KVH, G, D), jnp.float32),
            jax.ShapeDtypeStruct((n_splits, B, KVH, G), jnp.float32),
            jax.ShapeDtypeStruct((n_splits, B, KVH, G), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(ctx_lens.astype(jnp.int32), q, k, v)
