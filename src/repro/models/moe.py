"""Mixture-of-Experts: top-k routing with capacity, virtual-expert layout.

Weights are stored as **virtual experts**: ``V = n_virtual`` slices where
each real expert's d_ff is split across ``v = V / E`` consecutive virtual
experts. With ``V = tp`` this maps any expert count onto the mesh:
mixtral's 8 experts on a 16-way model axis -> EP8 x TP2 (v=2), phi3.5's 16
experts -> pure EP16 (v=1); single-device tests use V = E (v=1).

Two execution paths share the routing math:

* ``moe_local`` — everything on one shard (reference / tests / smoke).
* ``moe_ep``    — for use inside ``shard_map``: each shard owns exactly one
  virtual expert; tokens travel by ``lax.all_to_all`` over the model axis and
  the v partial outputs per chosen expert sum at combine (d_ff row-split).

Router math is fp32. Capacity per real expert follows GShard:
``C = ceil(T * top_k * capacity_factor / E)``; overflow tokens keep only the
residual path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import activate, dense, init_dense

F32 = jnp.float32


def init_moe(key, cfg, dtype, n_virtual: int | None = None):
    E, D, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    V = n_virtual or E
    v = V // E
    assert v * E == V and ff % max(v, 1) == 0, (E, V, ff)
    ffv = ff // v
    ks = jax.random.split(key, 4)
    p = {"router": init_dense(ks[0], D, E, dtype, scale=0.02),
         "w1": jax.vmap(lambda k: init_dense(k, D, ffv, dtype))(
             jax.random.split(ks[1], V)),
         "w2": jax.vmap(lambda k: init_dense(k, ffv, D, dtype,
                        scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers)))(
             jax.random.split(ks[2], V))}
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = jax.vmap(lambda k: init_dense(k, D, ffv, dtype))(
            jax.random.split(ks[3], V))
    return p


def route(router_w, cfg, x):
    """x [T,D] -> (probs [T,K], experts [T,K], aux_loss scalar)."""
    logits = dense(x, router_w).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    E = cfg.n_experts
    me = probs.mean(0)
    ce = jax.nn.one_hot(top_e[:, 0], E, dtype=F32).mean(0)
    aux = E * jnp.sum(me * ce)
    return top_p, top_e, aux


def capacity(cfg, T: int) -> int:
    return max(1, math.ceil(T * cfg.moe_top_k * cfg.capacity_factor
                            / cfg.n_experts))


def _dispatch_indices(top_e, E: int, C: int):
    """Flat (T*K) choices -> slot in the [E, C] buffers + keep mask."""
    flat_e = top_e.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    return slot, slot < C


def dispatch(x, top_e, slot, keep, E: int, C: int):
    """x [T,D] -> per-real-expert buffers [E, C, D]."""
    K = top_e.shape[1]
    flat_e = top_e.reshape(-1)
    xs = jnp.repeat(x, K, axis=0)
    buf = jnp.zeros((E, C, x.shape[-1]), x.dtype)
    return buf.at[flat_e, jnp.where(keep, slot, 0)].add(
        jnp.where(keep[:, None], xs, 0), mode="drop")


def combine(buf, top_p, top_e, slot, keep):
    """buf [E, C, D] (v-slices pre-summed) -> y [T, D]."""
    T, K = top_e.shape
    flat_e = top_e.reshape(-1)
    picked = buf[flat_e, jnp.where(keep, slot, 0)]
    w = (top_p.reshape(-1) * keep).astype(buf.dtype)
    return (picked * w[:, None]).reshape(T, K, -1).sum(1)


def _emm(spec, x, w):
    """Expert matmul; ``w`` may be an int8 QTensor (core/quant.py)."""
    if isinstance(w, dict):
        y = jnp.einsum(spec, x, w["q"].astype(x.dtype),
                       preferred_element_type=F32)
        return y * w["s"].astype(F32)          # s [E, 1, out] broadcasts
    return jnp.einsum(spec, x, w, preferred_element_type=F32)


def expert_mlp(p, cfg, buf):
    """buf [V, C, D] through each virtual expert's MLP slice (partial out)."""
    h = _emm("ecd,edf->ecf", buf, p["w1"])
    h = activate(h, cfg.act).astype(buf.dtype)
    if "w3" in p:
        h = h * _emm("ecd,edf->ecf", buf, p["w3"]).astype(buf.dtype)
    return _emm("ecf,efd->ecd", h, p["w2"]).astype(buf.dtype)


def moe_local(p, cfg, x):
    """x [B,S,D] -> (y, aux). Virtual-expert count inferred from weights."""
    B, S, D = x.shape
    E = cfg.n_experts
    w1 = p["w1"]["q"] if isinstance(p["w1"], dict) else p["w1"]
    V = w1.shape[0]
    v = V // E
    xt = x.reshape(-1, D)
    top_p, top_e, aux = route(p["router"], cfg, xt)
    C = capacity(cfg, xt.shape[0])
    slot, keep = _dispatch_indices(top_e, E, C)
    buf = dispatch(xt, top_e, slot, keep, E, C)           # [E, C, D]
    out = expert_mlp(p, cfg, jnp.repeat(buf, v, axis=0))  # [V, C, D] partials
    summed = out.reshape(E, v, C, D).sum(1)
    y = combine(summed, top_p, top_e, slot, keep)
    return y.reshape(B, S, D), aux


def moe_ep(p_local, cfg, x_loc, axis: str, n_shards: int):
    """Expert-parallel path for shard_map bodies.

    ``x_loc`` [T_loc, D] — this shard's tokens. ``p_local['w*']`` [1, D, ffv]
    — this shard's virtual expert (arrives pre-sliced via in_specs); router
    replicated. Requires n_virtual == n_shards. Returns (y_loc, aux_local).
    """
    E, D = cfg.n_experts, cfg.d_model
    v = n_shards // E
    T = x_loc.shape[0]
    top_p, top_e, aux = route(p_local["router"], cfg, x_loc)
    C = capacity(cfg, T)
    slot, keep = _dispatch_indices(top_e, E, C)
    buf = dispatch(x_loc, top_e, slot, keep, E, C)          # [E, C, D]
    send = jnp.repeat(buf, v, axis=0)                       # [V=n_shards, C, D]
    recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
    flat = recv.reshape(1, n_shards * C, D)                 # my virtual expert
    out = expert_mlp(p_local, cfg, flat)[0]
    back = jax.lax.all_to_all(out.reshape(n_shards, C, D), axis, 0, 0,
                              tiled=False)
    summed = back.reshape(E, v, C, D).sum(1)
    y = combine(summed, top_p, top_e, slot, keep)
    return y, aux
