"""State-space / recurrent blocks: Mamba2 and xLSTM (mLSTM + sLSTM).

Both Mamba2 and mLSTM are members of the gated-linear-attention family
(state h_t = a_t * h_{t-1} + g_t * k_t v_t^T), so training/prefill use one
shared **chunkwise-parallel** engine (`chunked_gla`): quadratic attention-like
math inside a chunk, recurrent state handoff across chunks — the TPU-friendly
formulation (MXU matmuls instead of a length-S sequential scan). Decoding uses
the exact stabilized recurrences. sLSTM has memory mixing and is sequential by
construction (xLSTM §2.2); it runs as a `lax.scan` over time.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, init_dense, rms_norm, NEG_INF

F32 = jnp.float32


# ---------------------------------------------------------------------------
# shared chunkwise gated linear attention
# ---------------------------------------------------------------------------

def chunked_gla(q, k, v, log_a, log_g, *, chunk: int = 128,
                normalize: bool = False, state=None):
    """Chunkwise-parallel gated linear attention.

    q,k [B,S,H,dk]; v [B,S,H,dv]; log_a [B,S,H] log-decay applied to the
    previous state at each step; log_g [B,S,H] log input gain.
    h_t = exp(log_a_t) h_{t-1} + exp(log_g_t) k_t v_t^T;  y_t = h_t^T q_t.

    ``normalize=True`` adds the mLSTM normalizer/stabilizer (n, m) so gains
    may be unbounded (exp input gate). Returns (y [B,S,H,dv], state) where
    state = (C [B,H,dk,dv], n [B,H,dk], m [B,H]).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    def r(x, width=None):
        shp = (b, n_chunks, chunk, h) + ((width,) if width else ())
        return x.reshape(shp)

    qc, kc, vc = r(q, dk).astype(F32), r(k, dk).astype(F32), r(v, dv).astype(F32)
    la, lg = r(log_a).astype(F32), r(log_g).astype(F32)
    bcum = jnp.cumsum(la, axis=2)                    # [B,K,c,H] inclusive
    btot = bcum[:, :, -1]                            # [B,K,H]

    if state is None:
        C0 = jnp.zeros((b, h, dk, dv), F32)
        n0 = jnp.zeros((b, h, dk), F32)
        m0 = jnp.full((b, h), NEG_INF if normalize else 0.0, F32)
    else:
        C0, n0, m0 = state
        C0, n0, m0 = C0.astype(F32), n0.astype(F32), m0.astype(F32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]            # [c,c] j<=i

    def step(carry, xs):
        C, n, m = carry
        qb, kb, vb, bc, bt, lgb = xs                 # [B,c,H,*] / [B,c,H] / [B,H]
        # log weight of source j at query i: bc_i - bc_j + lg_j
        wlog = (bc[:, :, None, :] - bc[:, None, :, :] + lgb[:, None, :, :])
        wlog = jnp.where(causal[None, :, :, None], wlog, NEG_INF)   # [B,i,j,H]
        if normalize:
            m_intra = wlog.max(axis=2)                              # [B,c,H]
            m_i = jnp.maximum(m[:, None, :] + bc, m_intra)
            w_inter = jnp.exp(m[:, None, :] + bc - m_i)             # [B,c,H]
            wmat = jnp.exp(wlog - m_i[:, :, None, :])               # [B,i,j,H]
        else:
            m_i = jnp.zeros_like(bc)
            w_inter = jnp.exp(bc)
            wmat = jnp.exp(jnp.clip(wlog, NEG_INF, 60.0))
        scores = jnp.einsum("bihd,bjhd->bijh", qb, kb,
                            preferred_element_type=F32) * wmat
        y_intra = jnp.einsum("bijh,bjhv->bihv", scores, vb,
                             preferred_element_type=F32)
        y_inter = jnp.einsum("bihd,bhdv->bihv", qb, C,
                             preferred_element_type=F32) * w_inter[..., None]
        y = y_intra + y_inter
        if normalize:
            # n_i = sum_j w_ij k_j (+ carried n); den_i = q_i . n_i which is
            # exactly sum_j scores_ij + w_inter * (q_i . n_carried)
            den = scores.sum(axis=2) + jnp.einsum(
                "bihd,bhd->bih", qb, n, preferred_element_type=F32) * w_inter
            y = y / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # ---- state handoff ----
        slog = bt[:, None, :] - bc + lgb                            # [B,c,H]
        if normalize:
            m_new = jnp.maximum(m + bt, slog.max(axis=1))
            sc = jnp.exp(slog - m_new[:, None, :])
            carry_scale = jnp.exp(m + bt - m_new)
        else:
            m_new = m
            sc = jnp.exp(jnp.clip(slog, NEG_INF, 60.0))
            carry_scale = jnp.exp(bt)
        kv = jnp.einsum("bjhd,bjhv->bhdv", kb * sc[..., None], vb,
                        preferred_element_type=F32)
        C_new = C * carry_scale[..., None, None] + kv
        n_new = n * carry_scale[..., None] + jnp.einsum(
            "bjhd->bhd", kb * sc[..., None])
        return (C_new, n_new, m_new), y

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), bcum.transpose(1, 0, 2, 3),
          btot.transpose(1, 0, 2), lg.transpose(1, 0, 2, 3))
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return y, (C, n, m)


def gla_step(q, k, v, log_a, log_g, state, *, normalize: bool = False):
    """Exact single-step recurrence. q,k [B,H,dk]; v [B,H,dv];
    log_a, log_g [B,H]; state as in chunked_gla."""
    C, n, m = (s.astype(F32) for s in state)
    q, k, v = q.astype(F32), k.astype(F32), v.astype(F32)
    la, lg = log_a.astype(F32), log_g.astype(F32)
    if normalize:
        m_new = jnp.maximum(la + m, lg)
        fa = jnp.exp(la + m - m_new)
        gi = jnp.exp(lg - m_new)
    else:
        m_new = m
        fa = jnp.exp(la)
        gi = jnp.exp(jnp.clip(lg, NEG_INF, 60.0))
    C_new = C * fa[..., None, None] + gi[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = n * fa[..., None] + gi[..., None] * k
    y = jnp.einsum("bhd,bhdv->bhv", q, C_new, preferred_element_type=F32)
    if normalize:
        den = jnp.einsum("bhd,bhd->bh", q, n_new, preferred_element_type=F32)
        y = y / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return y, (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype):
    # separate projections (not one fused in_proj) so each is cleanly
    # column-shardable for TP (DESIGN.md §4)
    D, di, N, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * N
    return {
        "ln": jnp.zeros((D,), dtype),
        "wz": init_dense(ks[0], D, di, dtype),
        "wx": init_dense(ks[1], D, di, dtype),
        "wbc": init_dense(ks[2], D, 2 * N, dtype),
        "wdt": init_dense(ks[3], D, nh, dtype),
        "conv_w": (jax.random.normal(ks[4], (cfg.ssm_conv, conv_ch), F32)
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=F32)),
        "D": jnp.ones((nh,), F32),
        "dt_bias": jnp.full((nh,), -4.6, F32),   # softplus^-1(~0.01)
        "norm": jnp.zeros((di,), dtype),
        "out_proj": init_dense(ks[5], di, D, dtype,
                               scale=1.0 / math.sqrt(di * 2 * cfg.n_layers)),
    }


def _mamba_proj(p, cfg, x):
    """Shared in-proj/split. x [B,S,D] -> z, xbc_raw, dt_raw."""
    z = dense(x, p["wz"])
    xbc = jnp.concatenate([dense(x, p["wx"]), dense(x, p["wbc"])], axis=-1)
    dt_raw = dense(x, p["wdt"])
    return z, xbc, dt_raw


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc [B,S,C]; w [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :].astype(F32)
              for i in range(k))
    return jax.nn.silu(out + b.astype(F32)[None, None, :]).astype(xbc.dtype)


def _mamba_ssm_inputs(p, cfg, xbc, dt_raw):
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    xh = xbc[..., :di].reshape(*xbc.shape[:-1], nh, P)
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"])
    log_a = -jnp.exp(p["A_log"]) * dt                # [.., nh]
    return xh, Bm, Cm, dt, log_a


def mask_log_gates(log_a, log_g, mask):
    """Turn pad positions into identity recurrence steps: decay 1
    (``log_a=0``) and input gain 0 (``log_g=-inf``), so the GLA state passes
    through them unchanged. ``mask`` [B,S] bool (True = real token); the
    per-position outputs at pads are garbage and must not be read."""
    m = mask[..., None]
    return jnp.where(m, log_a, 0.0), jnp.where(m, log_g, NEG_INF)


def mask_log_gates_tail(log_a, log_g, valid_len):
    """``valid_len`` [B] form of :func:`mask_log_gates` for [B,S,H] gates:
    positions >= valid_len[b] become identity steps. The single home of
    the identity-step encoding for the kernel wrappers
    (``kernels/ssm_scan.py``, ``kernels.ops.mamba_mixer``)."""
    live = (jnp.arange(log_a.shape[1])[None, :, None]
            < jnp.asarray(valid_len, jnp.int32)[:, None, None])
    return (jnp.where(live, log_a, 0.0), jnp.where(live, log_g, NEG_INF))


def _masked_tail(full, mask, width: int):
    """Last ``width`` *valid* entries of ``full`` = [carried tail | seq],
    where row b has ``mask[b].sum()`` valid seq positions (end-padding) and
    the carried-tail entries are always valid: valid length of ``full`` is
    ``carried + vlen[b]``, so the window starts at ``carried + vlen - width``."""
    carried = full.shape[1] - mask.shape[1]
    vlen = mask.sum(axis=1).astype(jnp.int32)                   # [B]
    idx = vlen[:, None] + (carried - width) + jnp.arange(width)[None, :]
    idx = jnp.clip(idx, 0, full.shape[1] - 1)
    return jnp.take_along_axis(full, idx[..., None], axis=1)


def mamba_forward(p, cfg, x, state=None, *, chunk: int = 128, mask=None):
    """x [B,S,D] -> (y [B,S,D], state). state=(conv_tail [B,K-1,C], ssm (C,n,m)).

    ``mask`` [B,S] bool marks real tokens (end-padded rows in a
    length-bucketed batch): pad positions neither advance the SSM state nor
    enter the carried conv tail, so the returned state is exactly the state
    after each row's last valid token.
    """
    Bsz, S, D = x.shape
    nh, N = cfg.ssm_n_heads, cfg.ssm_state
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt_raw = _mamba_proj(p, cfg, xin)
    carried = cfg.ssm_conv - 1
    if state is not None:
        conv_tail = state["conv"]
        xbc_full = jnp.concatenate([conv_tail.astype(xbc.dtype), xbc], axis=1)
        xbc_act = _causal_conv(xbc_full, p["conv_w"], p["conv_b"])[:, conv_tail.shape[1]:]
    else:
        conv_tail = jnp.zeros((Bsz, carried, xbc.shape[-1]), xbc.dtype)
        xbc_full = jnp.concatenate([conv_tail, xbc], axis=1)
        xbc_act = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    if mask is None:
        new_conv_tail = xbc_full[:, -carried:]
    else:
        new_conv_tail = _masked_tail(xbc_full, mask, carried)
    xh, Bm, Cm, dt, log_a = _mamba_ssm_inputs(p, cfg, xbc_act, dt_raw)
    q = jnp.broadcast_to(Cm[:, :, None, :], (Bsz, S, nh, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (Bsz, S, nh, N))
    ssm_state = state["ssm"] if state is not None else None
    log_g = jnp.log(dt + 1e-20)
    if mask is not None:
        log_a, log_g = mask_log_gates(log_a, log_g, mask)
    y, ssm_state = chunked_gla(q, k, xh, log_a, log_g,
                               chunk=chunk, normalize=False, state=ssm_state)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])
    return out, {"conv": new_conv_tail, "ssm": ssm_state}


def mamba_step(p, cfg, x, state):
    """x [B,D] single token. state as returned by mamba_forward."""
    y, new_state = mamba_forward(p, cfg, x[:, None, :], state, chunk=1)
    return y[:, 0], new_state


def mamba_init_state(cfg, batch: int, dtype=jnp.float32):
    nh, N, P = cfg.ssm_n_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": (jnp.zeros((batch, nh, N, P), F32),
                jnp.zeros((batch, nh, N), F32),
                jnp.zeros((batch, nh), F32)),
    }


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype):
    D, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((D,), dtype),
        "wu": init_dense(ks[0], D, di, dtype),
        "wg": init_dense(ks[7], D, di, dtype),
        "wq": init_dense(ks[1], di, di, dtype),
        "wk": init_dense(ks[2], di, di, dtype),
        "wv": init_dense(ks[3], di, di, dtype),
        "wi": init_dense(ks[4], di, H, dtype, scale=0.01),
        "bi": jnp.zeros((H,), F32),
        "wf": init_dense(ks[5], di, H, dtype, scale=0.01),
        "bf": jnp.full((H,), 3.0, F32),          # open forget gates at init
        "norm": jnp.zeros((di,), dtype),
        "down": init_dense(ks[6], di, D, dtype,
                           scale=1.0 / math.sqrt(di * 2 * cfg.n_layers)),
    }


def _mlstm_qkvg(p, cfg, xin):
    di, H = cfg.d_inner, cfg.n_heads
    dh = di // H
    u, g = dense(xin, p["wu"]), dense(xin, p["wg"])
    shp = (*u.shape[:-1], H, dh)
    q = dense(u, p["wq"]).reshape(shp)
    k = dense(u, p["wk"]).reshape(shp) / math.sqrt(dh)
    v = dense(u, p["wv"]).reshape(shp)
    log_g = dense(u, p["wi"]).astype(F32) + p["bi"]                  # input gate preact
    log_a = -jax.nn.softplus(-(dense(u, p["wf"]).astype(F32) + p["bf"]))  # log sigmoid
    return q, k, v, log_a, log_g, g


def mlstm_forward(p, cfg, x, state=None, *, chunk: int = 128, mask=None):
    """``mask`` [B,S] bool: pad positions are identity steps (state carry
    unchanged); their outputs are garbage and must not be read."""
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v, log_a, log_g, g = _mlstm_qkvg(p, cfg, xin)
    if mask is not None:
        log_a, log_g = mask_log_gates(log_a, log_g, mask)
    y, new_state = chunked_gla(q, k, v, log_a, log_g, chunk=chunk,
                               normalize=True, state=state)
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    return dense(y, p["down"]), new_state


def mlstm_step(p, cfg, x, state):
    y, new_state = mlstm_forward(p, cfg, x[:, None, :], state, chunk=1)
    return y[:, 0], new_state


def mlstm_init_state(cfg, batch: int):
    H, dh = cfg.n_heads, cfg.d_inner // cfg.n_heads
    return (jnp.zeros((batch, H, dh, dh), F32),
            jnp.zeros((batch, H, dh), F32),
            jnp.full((batch, H), NEG_INF, F32))


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential by construction (memory mixing)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.zeros((D,), dtype),
        "w": init_dense(ks[0], D, 4 * D, dtype),
        "b": jnp.concatenate([jnp.zeros((D,), F32),          # i
                              jnp.full((D,), 3.0, F32),      # f
                              jnp.zeros((2 * D,), F32)]),    # z, o
        "r": (jax.random.normal(ks[1], (4, H, dh, dh), F32)
              / math.sqrt(dh)).astype(dtype),
        "norm": jnp.zeros((D,), dtype),
        "proj": init_dense(ks[2], D, D, dtype,
                           scale=1.0 / math.sqrt(D * 2 * cfg.n_layers)),
    }


def _slstm_cell(p, cfg, x_pre, state):
    """x_pre [B,4D] (input preactivations). state=(c,n,m,h) each [B,D]."""
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    c, n, m, hprev = state
    hh = hprev.reshape(-1, H, dh)
    rec = jnp.stack([
        jnp.einsum("bhd,hde->bhe", hh, p["r"][i].astype(F32)).reshape(-1, D)
        for i in range(4)], axis=-2)                        # [B,4,D]
    pre = x_pre.reshape(-1, 4, D).astype(F32) + rec + p["b"].reshape(4, D)
    it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    log_f = -jax.nn.softplus(-ft)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(zt)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * (c_new / jnp.maximum(n_new, 1e-6))
    return (c_new, n_new, m_new, h_new), h_new


def slstm_forward(p, cfg, x, state=None, *, mask=None):
    """``mask`` [B,S] bool: pad positions keep the previous carry (their
    emitted h is garbage and must not be read)."""
    B, S, D = x.shape
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    x_pre = dense(xin, p["w"])                               # [B,S,4D]
    if state is None:
        state = slstm_init_state(cfg, B)
    if mask is None:
        def step(carry, xp):
            return _slstm_cell(p, cfg, xp, carry)
        state, hs = jax.lax.scan(step, state, x_pre.transpose(1, 0, 2))
    else:
        def step(carry, xs):
            xp, mt = xs                                      # mt [B]
            new, h = _slstm_cell(p, cfg, xp, carry)
            new = tuple(jnp.where(mt[:, None], n, o)
                        for n, o in zip(new, carry))
            return new, h
        state, hs = jax.lax.scan(
            step, state, (x_pre.transpose(1, 0, 2), mask.T))
    h = hs.transpose(1, 0, 2).astype(x.dtype)                # [B,S,D]
    h = rms_norm(h, p["norm"], cfg.norm_eps)
    return dense(h, p["proj"]), state


def slstm_step(p, cfg, x, state):
    y, state = slstm_forward(p, cfg, x[:, None, :], state)
    return y[:, 0], state


def slstm_init_state(cfg, batch: int):
    D = cfg.d_model
    z = jnp.zeros((batch, D), F32)
    return (z, z, jnp.full((batch, D), NEG_INF, F32), z)
