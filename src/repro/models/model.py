"""Unified model zoo: one builder covering dense / MoE / SSM / hybrid /
enc-dec / VLM architectures from a ``ModelConfig``.

Layer stacks are scan-compiled (stacked params) for compile-time and memory
sanity at 60-80 layers. Heterogeneous patterns:

* gemma3 local:global — one uniform attention stack, per-layer ``window``
  flags ride through the scan as data;
* xlstm — scan over (mLSTM, sLSTM) cycles;
* zamba2 — scan over Mamba2 sub-stacks with a single shared attention block
  invoked between cycles (weights shared, zamba2-style);
* whisper — separate encoder stack (bidirectional) + decoder stack with
  cross-attention.

Execution is runtime-injected (``Runtime``): sharding constraints, the ITPP
sharded decode attention, and the expert-parallel MoE are provided by the
distribution layer; defaults are single-device reference paths so every model
runs standalone on CPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.core.itpp import ItppSpec, itpp_decode_attention_shard
from repro.kernels.backend import KernelConfig


# ---------------------------------------------------------------------------
# runtime injection
# ---------------------------------------------------------------------------

@dataclass
class Runtime:
    """Distribution hooks; defaults = single-device reference."""
    constrain: Callable = lambda x, name: x          # sharding constraints
    itpp: Callable | None = None                     # sharded decode attention
    moe: Callable | None = None                      # expert-parallel MoE
    write_pool: Callable | None = None               # sharded prefill writer
    remat: bool = False
    gla_chunk: int = 128
    ring_width: int = 0                              # sliding-window ring pool
    # decode-attention kernel selection (kernels/backend.py); None keeps the
    # gather-then-dense reference path unconditionally (seed semantics),
    # KernelConfig() autodetects (pallas on TPU, reference elsewhere)
    kernels: KernelConfig | None = None
    cond_window: int = 0                             # windowed-bound lax.cond

    def moe_apply(self, p, cfg, x):
        if self.moe is not None:
            return self.moe(p, cfg, x)
        return MOE.moe_local(p, cfg, x)

    def itpp_apply(self, q, k, v, pk, pv, bt, ctx, npage, noff, window):
        if self.itpp is not None:
            return self.itpp(q, k, v, pk, pv, bt, ctx, npage, noff, window)
        spec = ItppSpec((), (), None, 1, 1, pk.shape[1])
        return itpp_decode_attention_shard(
            q, k, v, pk, pv, bt, ctx, npage, noff, window, spec=spec,
            mesh_axis_sizes={}, max_pages_per_req=bt.shape[1],
            ring_width=self.ring_width, cond_window=self.cond_window,
            kernels=self.kernels)


DEFAULT_RT = Runtime()


# ---------------------------------------------------------------------------
# per-kind layer init
# ---------------------------------------------------------------------------

def _init_attn_layer(key, cfg, dtype, *, cross: bool = False,
                     with_mlp: bool = True, moe_virtual: int = 0):
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "attn": L.init_attention(ks[0], cfg, dtype)}
    if cross:
        p["lnx"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = L.init_attention(ks[1], cfg, dtype, cross=True)
    if with_mlp and (cfg.d_ff or cfg.is_moe):
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.is_moe:
            p["moe"] = MOE.init_moe(ks[2], cfg, dtype,
                                    n_virtual=moe_virtual or cfg.n_experts)
        else:
            p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


_KIND_INIT = {
    "mamba": SSM.init_mamba,
    "mlstm": SSM.init_mlstm,
    "slstm": SSM.init_slstm,
}


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg, key=None, dtype=None, *, moe_virtual: int = 0):
    key = key if key is not None else jax.random.PRNGKey(0)
    dtype = jnp.dtype(dtype or cfg.dtype)
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(ks[1], cfg.d_model, cfg.padded_vocab,
                                      dtype, scale=0.02)
    kinds = cfg.block_kinds()
    if cfg.family == "encdec":
        params["enc"] = _stack_init(
            ks[2], cfg.enc_layers,
            lambda k: _init_attn_layer(k, cfg, dtype))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["dec"] = _stack_init(
            ks[3], cfg.n_layers,
            lambda k: _init_attn_layer(k, cfg, dtype, cross=True))
        return params
    if all(k in ("attn", "local") for k in kinds):
        params["layers"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: _init_attn_layer(k, cfg, dtype, moe_virtual=moe_virtual))
        return params
    if set(cfg.pattern) == {"mlstm", "slstm"}:          # xlstm
        n_cyc = cfg.n_layers // len(cfg.pattern)
        params["mlstm"] = _stack_init(
            ks[2], n_cyc, lambda k: SSM.init_mlstm(k, cfg, dtype))
        params["slstm"] = _stack_init(
            ks[3], n_cyc, lambda k: SSM.init_slstm(k, cfg, dtype))
        return params
    if set(cfg.pattern) == {"mamba", "attn"}:           # zamba2 hybrid
        n_cyc = cfg.n_layers // len(cfg.pattern)
        per_cyc = sum(1 for k in cfg.pattern if k == "mamba")
        params["mamba"] = _stack_init(
            ks[2], n_cyc * per_cyc, lambda k: SSM.init_mamba(k, cfg, dtype))
        params["attn_shared"] = _init_attn_layer(ks[3], cfg, dtype)
        return params
    raise NotImplementedError(cfg.pattern)


def param_count_actual(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# position embeddings
# ---------------------------------------------------------------------------

def _cos_sin(cfg, positions):
    """positions [B,S] (rope) or [3,B,S] (mrope) -> cos/sin [B,S,dh/2]."""
    if cfg.rope_kind == "none":
        return None
    if cfg.rope_kind == "mrope":
        return L.mrope_cos_sin(positions, cfg.d_head, cfg.rope_theta,
                               cfg.mrope_sections)
    return L.rope_cos_sin(positions, cfg.d_head, cfg.rope_theta)


def default_positions(cfg, B, S, offset=0):
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None] + offset, (B, S))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


# ---------------------------------------------------------------------------
# training / prefill blocks (full-sequence)
# ---------------------------------------------------------------------------

def _attn_mlp_block(p, cfg, x, cs, window, rt: Runtime, *,
                    causal=True, enc_out=None, enc_cs=None):
    B, S, D = x.shape
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], cfg, h)
    if cs is not None:
        q = L.apply_rope(q, *cs)
        k = L.apply_rope(k, *cs)
    k = rt.constrain(k, "kv_full")
    v = rt.constrain(v, "kv_full")
    a = L.flash_attention(q, k, v, causal=causal, window=window)
    x = x + L.dense(a.reshape(B, S, cfg.q_dim), p["attn"]["wo"])
    aux = jnp.float32(0)
    if "xattn" in p:
        h = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        qx = L.dense(h, p["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        kx = L.dense(enc_out, p["xattn"]["wk"]).reshape(
            B, -1, cfg.n_kv_heads, cfg.d_head)
        vx = L.dense(enc_out, p["xattn"]["wv"]).reshape(
            B, -1, cfg.n_kv_heads, cfg.d_head)
        ax = L.flash_attention(qx, kx, vx, causal=False)
        x = x + L.dense(ax.reshape(B, S, cfg.q_dim), p["xattn"]["wo"])
    if "ln2" in p:
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, aux = rt.moe_apply(p["moe"], cfg, h2)
        else:
            y = L.mlp(p["mlp"], h2, cfg.act)
        x = x + y
    x = rt.constrain(x, "act")
    return x, aux


def _window_array(cfg) -> np.ndarray:
    return np.asarray([cfg.sliding_window if k == "local" else 0
                       for k in cfg.block_kinds()], np.int32)


def _stack_forward_train(cfg, params, x, cs, rt: Runtime):
    """Uniform attention stack via scan (dense/moe/local patterns)."""
    windows = jnp.asarray(_window_array(cfg))

    def body(carry, xs):
        h, aux = carry
        lp, w = xs
        h, a = _attn_mlp_block(lp, cfg, h, cs, w, rt)
        return (h, aux + a), None

    body = jax.checkpoint(body) if rt.remat else body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)),
                               (params["layers"], windows))
    return x, aux


def _xlstm_forward_train(cfg, params, x, rt: Runtime):
    def body(carry, lp):
        h = carry
        y, _ = SSM.mlstm_forward(lp["m"], cfg, h, chunk=rt.gla_chunk)
        h = rt.constrain(h + y, "act")
        y, _ = SSM.slstm_forward(lp["s"], cfg, h)
        return rt.constrain(h + y, "act"), None

    body = jax.checkpoint(body) if rt.remat else body
    x, _ = jax.lax.scan(body, x, {"m": params["mlstm"], "s": params["slstm"]})
    return x, jnp.float32(0)


def _zamba_forward_train(cfg, params, x, cs, rt: Runtime):
    n_cyc = cfg.n_layers // len(cfg.pattern)
    per_cyc = sum(1 for k in cfg.pattern if k == "mamba")

    def mamba_body(h, lp):
        y, _ = SSM.mamba_forward(lp, cfg, h, chunk=rt.gla_chunk)
        return rt.constrain(h + y, "act"), None

    mamba_body = jax.checkpoint(mamba_body) if rt.remat else mamba_body
    aux = jnp.float32(0)
    for c in range(n_cyc):                       # unrolled: n_cyc == 2
        sub = jax.tree.map(lambda a: a[c * per_cyc:(c + 1) * per_cyc],
                           params["mamba"])
        x, _ = jax.lax.scan(mamba_body, x, sub)
        x, a = _attn_mlp_block(params["attn_shared"], cfg, x, cs, 0, rt)
        aux = aux + a
    return x, aux


def encode(cfg, params, frames, rt: Runtime = DEFAULT_RT):
    """Whisper encoder over stub frame embeddings [B, enc_seq, D]."""
    pe = jnp.asarray(L.sinusoidal_positions(frames.shape[1], cfg.d_model))
    x = (frames + pe[None].astype(frames.dtype))

    def body(h, lp):
        h, _ = _attn_mlp_block(lp, cfg, h, None, 0, rt, causal=False)
        return h, None

    body = jax.checkpoint(body) if rt.remat else body
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_hidden(cfg, params, tokens, *, positions=None, extra_embeds=None,
                   frames=None, rt: Runtime = DEFAULT_RT):
    """Full-sequence forward -> (final hidden [B,S,D], moe aux)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None:                 # VLM stub modality fusion
        x = x + extra_embeds.astype(x.dtype)
    if cfg.rope_kind == "none" and cfg.family == "encdec":
        pe = jnp.asarray(L.sinusoidal_positions(S, cfg.d_model))
        x = x + pe[None].astype(x.dtype)
    x = rt.constrain(x, "act")
    if positions is None:
        positions = default_positions(cfg, B, S)
    cs = _cos_sin(cfg, positions)

    if cfg.family == "encdec":
        enc_out = encode(cfg, params, frames, rt)

        def body(carry, lp):
            h, aux = carry
            h, a = _attn_mlp_block(lp, cfg, h, cs, 0, rt, enc_out=enc_out)
            return (h, aux + a), None

        body = jax.checkpoint(body) if rt.remat else body
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), params["dec"])
    elif "layers" in params:
        x, aux = _stack_forward_train(cfg, params, x, cs, rt)
    elif "mlstm" in params:
        x, aux = _xlstm_forward_train(cfg, params, x, rt)
    else:
        x, aux = _zamba_forward_train(cfg, params, x, cs, rt)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward(cfg, params, tokens, *, positions=None, extra_embeds=None,
            frames=None, rt: Runtime = DEFAULT_RT):
    """Full-sequence forward -> (fp32 logits [B, S, padded_vocab], aux)."""
    x, aux = forward_hidden(cfg, params, tokens, positions=positions,
                            extra_embeds=extra_embeds, frames=frames, rt=rt)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.lm_head(x, w, transpose=cfg.tie_embeddings)
    return rt.constrain(logits, "logits"), aux


def train_loss(cfg, params, batch, rt: Runtime = DEFAULT_RT,
               *, loss_chunk: int = 1024):
    """batch: tokens/targets [B,S], mask [B,S]; returns (loss, metrics).

    Cross-entropy is computed in sequence chunks (remat'd) so [B,S,V] logits
    never materialize — at 4k x 256k-vocab the full fp32 logits would be
    ~4 GB/device, dominating the training memory term.
    """
    hidden, aux = forward_hidden(cfg, params, batch["tokens"],
                                 positions=batch.get("positions"),
                                 extra_embeds=batch.get("extra_embeds"),
                                 frames=batch.get("frames"), rt=rt)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    tgt = batch["targets"]
    mask = batch["mask"].astype(jnp.float32)
    B, S = tgt.shape
    c = min(loss_chunk, S)
    n_chunks = S // c if S % c == 0 else 1
    if S % c != 0:
        c = S

    @jax.checkpoint
    def chunk_nll(h_c, t_c, m_c):
        logits = L.lm_head(h_c, w, transpose=cfg.tie_embeddings)
        logits = rt.constrain(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        return ((lse - picked) * m_c).sum()

    def body(carry, xs):
        h_c, t_c, m_c = xs
        return carry + chunk_nll(h_c, t_c, m_c), None

    hs = hidden.reshape(B, n_chunks, c, -1).transpose(1, 0, 2, 3)
    ts = tgt.reshape(B, n_chunks, c).transpose(1, 0, 2)
    ms = mask.reshape(B, n_chunks, c).transpose(1, 0, 2)
    nll, _ = jax.lax.scan(body, jnp.float32(0), (hs, ts, ms))
    loss = nll / jnp.maximum(mask.sum(), 1.0)
    if cfg.is_moe:
        loss = loss + 0.01 * aux / max(1, cfg.n_layers)
    return loss, {"nll": loss, "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# decode (single token, paged KV via ITPP)
# ---------------------------------------------------------------------------

def _attn_block_decode(p, cfg, x, cs, window, pool_k, pool_v, bt, ctx,
                       npage, noff, rt: Runtime, cross_kv=None):
    """x [B, D] one token. Returns (x, pool_k, pool_v)."""
    B, D = x.shape
    h = L.rms_norm(x[:, None, :], p["ln1"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], cfg, h)          # [B,1,H,dh]
    if cs is not None:
        q = L.apply_rope(q, *cs)
        k = L.apply_rope(k, *cs)
    a, pool_k, pool_v = rt.itpp_apply(
        q[:, 0], k[:, 0], v[:, 0], pool_k, pool_v, bt, ctx, npage, noff, window)
    x = x + L.dense(a.reshape(B, cfg.q_dim), p["attn"]["wo"])
    if cross_kv is not None:
        h = L.rms_norm(x[:, None, :], p["lnx"], cfg.norm_eps)
        qx = L.dense(h, p["xattn"]["wq"]).reshape(B, cfg.n_heads, cfg.d_head)
        kx, vx = cross_kv
        ax = L.decode_attention_ref(
            qx, kx, vx, jnp.full((B,), kx.shape[1], jnp.int32))
        x = x + L.dense(ax.reshape(B, cfg.q_dim), p["xattn"]["wo"])
    if "ln2" in p:
        h2 = L.rms_norm(x[:, None, :], p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, _ = rt.moe_apply(p["moe"], cfg, h2)
        else:
            y = L.mlp(p["mlp"], h2, cfg.act)
        x = x + y[:, 0]
    return rt.constrain(x, "act_decode"), pool_k, pool_v


# state entries with a per-slot batch row at axis 1 ([L, B, ...] leaves):
# the recurrent carry (SSM/xLSTM hidden + conv states) and the enc-dec
# cross-attention KV. Everything the serving engine must snapshot/restore
# per slot for state-carrying chunked/batched prefill and
# preemption-resume; the paged ``pool`` is deliberately NOT here (pages are
# per-request via the block table, owned by the allocator).
RSTATE_KEYS = ("mamba", "mlstm", "slstm", "cross_k", "cross_v")


def rstate_entries(state) -> dict[str, Any]:
    """The per-slot recurrent/cross entries present in a decode state."""
    return {k: state[k] for k in RSTATE_KEYS if k in state}


def init_rstate(cfg, batch: int, *, dtype=None) -> dict[str, Any]:
    """Fresh (zero) recurrent/cross state for ``batch`` slots — every leaf
    [L, batch, ...]."""
    state: dict[str, Any] = {}
    kinds = cfg.block_kinds()
    if "mamba" in kinds:
        n_m = sum(1 for k in kinds if k == "mamba")
        state["mamba"] = jax.vmap(
            lambda _: SSM.mamba_init_state(cfg, batch))(jnp.arange(n_m))
    if "mlstm" in kinds:
        n = cfg.n_layers // 2
        state["mlstm"] = jax.vmap(
            lambda _: SSM.mlstm_init_state(cfg, batch))(jnp.arange(n))
        state["slstm"] = jax.vmap(
            lambda _: SSM.slstm_init_state(cfg, batch))(jnp.arange(n))
    if cfg.family == "encdec":
        state["cross_k"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head),
            jnp.dtype(dtype or cfg.dtype))
        state["cross_v"] = jnp.zeros_like(state["cross_k"])
    return state


def gather_rstate(state, idx) -> dict[str, Any]:
    """Rows ``idx`` of every recurrent/cross entry ([L, B, ...] ->
    [L, len(idx), ...]) — the engine's per-slot group gather for batched
    prefill and preemption snapshots."""
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda a: a[:, idx], rstate_entries(state))


def scatter_rstate(state, idx, rows) -> dict[str, Any]:
    """Return ``state`` with recurrent/cross rows ``idx`` replaced by
    ``rows`` (a ``gather_rstate``-shaped tree). Non-rstate entries pass
    through untouched."""
    idx = jnp.asarray(idx, jnp.int32)
    out = dict(state)
    out.update(jax.tree.map(lambda a, r: a.at[:, idx].set(r),
                            rstate_entries(state), rows))
    return out


def init_decode_state(cfg, pool_spec, batch: int, *, dtype=None):
    """Decode-side caches: paged pools for attention layers + recurrent
    states for ssm layers (+ cross-attn KV for enc-dec)."""
    from repro.core.paged_kv import init_pool
    state: dict[str, Any] = {}
    kinds = cfg.block_kinds()
    if any(k in ("attn", "local") for k in kinds) or cfg.family == "encdec":
        state["pool"] = init_pool(pool_spec)
    state.update(init_rstate(cfg, batch, dtype=dtype))
    return state


def make_cross_kv(cfg, params, enc_out):
    """Precompute whisper cross-attention KV [L, B, enc, KVH, dh]."""
    def one(lp):
        kx = L.dense(enc_out, lp["xattn"]["wk"]).reshape(
            enc_out.shape[0], -1, cfg.n_kv_heads, cfg.d_head)
        vx = L.dense(enc_out, lp["xattn"]["wv"]).reshape(
            enc_out.shape[0], -1, cfg.n_kv_heads, cfg.d_head)
        return kx, vx
    return jax.vmap(one)(params["dec"])


def _keep_rows(new, old, run):
    """Advance recurrent state only for running slots: rows with
    ``run=False`` keep their previous carry. Leaves are [L, B, ...]."""
    if run is None:
        return new
    return jax.tree.map(
        lambda n, o: jnp.where(run.reshape((1, -1) + (1,) * (n.ndim - 2)),
                               n, o), new, old)


def decode_step(cfg, params, state, tokens, bt, ctx, npage, noff, *,
                positions=None, run=None, rt: Runtime = DEFAULT_RT):
    """One decode step for the whole batch.

    tokens [B]; bt [B, maxp]; ctx [B] (INCLUDING the new token);
    npage/noff [B] write target for the new token's KV.
    ``run`` [B] bool: slots decoding this step. Attention KV writes already
    drop for non-running slots (out-of-bounds ``npage``), but recurrent /
    SSM state is a dense per-slot carry — without the mask an idle, paused
    or mid-chunk-prefill slot would absorb its stale pending token every
    step and corrupt the carry. ``None`` keeps the legacy advance-all
    behavior (callers whose batch is wholly active).
    Returns (fp32 logits [B, V], new_state).
    """
    B = tokens.shape[0]
    x = L.embed(params["embed"], tokens)                # [B, D]
    if cfg.rope_kind == "none" and cfg.family == "encdec":
        x = x + L.sinusoidal_at(ctx - 1, cfg.d_model).astype(x.dtype)
    if positions is None:
        pos = (ctx - 1).astype(jnp.int32)[:, None]      # [B,1]
        if cfg.rope_kind == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, B, 1))
        positions = pos
    cs = _cos_sin(cfg, positions)
    x = rt.constrain(x, "act_decode")
    kinds = cfg.block_kinds()
    state = dict(state)

    if cfg.family == "encdec" or all(k in ("attn", "local") for k in kinds):
        windows = jnp.asarray(_window_array(cfg))
        pool = state["pool"]
        stack = params["dec"] if cfg.family == "encdec" else params["layers"]
        has_cross = cfg.family == "encdec"

        # pool layers ride as scan xs/ys (per-layer slices stream through the
        # loop) rather than a carry + dynamic-update-slice: the carry pattern
        # made XLA copy the WHOLE pool twice per layer — 88% of decode HBM
        # traffic for gemma3-27b (EXPERIMENTS.md §Perf H1).
        def body(h, xs):
            if has_cross:
                lp, w, pkl, pvl, ck, cv = xs
                cross = (ck, cv)
            else:
                lp, w, pkl, pvl = xs
                cross = None
            h, pkl, pvl = _attn_block_decode(lp, cfg, h, cs, w, pkl, pvl,
                                             bt, ctx, npage, noff, rt,
                                             cross_kv=cross)
            return h, (pkl, pvl)

        xs = ((stack, windows, pool["k"], pool["v"],
               state["cross_k"], state["cross_v"])
              if has_cross else (stack, windows, pool["k"], pool["v"]))
        x, (pk, pv) = jax.lax.scan(body, x, xs)
        state["pool"] = {"k": pk, "v": pv}
    elif "mlstm" in params:
        def body(carry, xs):
            h = carry
            lp_m, lp_s, st_m, st_s = xs
            y, st_m = SSM.mlstm_step(lp_m, cfg, h, st_m)
            h = h + y
            y, st_s = SSM.slstm_step(lp_s, cfg, h, st_s)
            return h + y, (st_m, st_s)

        (x), (new_m, new_s) = jax.lax.scan(
            body, x, (params["mlstm"], params["slstm"],
                      state["mlstm"], state["slstm"]))
        state["mlstm"] = _keep_rows(new_m, state["mlstm"], run)
        state["slstm"] = _keep_rows(new_s, state["slstm"], run)
    else:                                               # zamba hybrid
        n_cyc = cfg.n_layers // len(cfg.pattern)
        per_cyc = sum(1 for k in cfg.pattern if k == "mamba")
        pool = state["pool"]
        pk, pv = pool["k"], pool["v"]
        new_mamba = []

        def mbody(h, xs):
            lp, st = xs
            y, st = SSM.mamba_step(lp, cfg, h, st)
            return h + y, st

        for c in range(n_cyc):
            sl = lambda a: a[c * per_cyc:(c + 1) * per_cyc]
            x, st_out = jax.lax.scan(
                mbody, x, (jax.tree.map(sl, params["mamba"]),
                           jax.tree.map(sl, state["mamba"])))
            new_mamba.append(st_out)
            pkl, pvl = pk[c], pv[c]
            x, pkl, pvl = _attn_block_decode(
                params["attn_shared"], cfg, x, cs, 0, pkl, pvl,
                bt, ctx, npage, noff, rt)
            pk = pk.at[c].set(pkl)
            pv = pv.at[c].set(pvl)
        state["pool"] = {"k": pk, "v": pv}
        state["mamba"] = _keep_rows(
            jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
            state["mamba"], run)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.lm_head(x, w, transpose=cfg.tie_embeddings)
    return rt.constrain(logits, "logits_decode"), state


def decode_multi(cfg, params, state, tokens, bt, ctx, rem, allow, key, *,
                 horizon: int, table_width: int, page_size: int, n_pages: int,
                 eos_token: int, sample, rt: Runtime = DEFAULT_RT):
    """Fused multi-step decode: ``horizon`` decode steps, on-device sampling
    and per-slot EOS/budget masking under ONE ``lax.scan`` — the host syncs
    once per horizon instead of once per token.

    Device-resident slot state (all [B] unless noted):
      tokens — incoming token per slot (the previous sample);
      bt     — [B, W] Va2Pa block table; attention reads the leading
               ``table_width`` slots (the engine's pow2 live-page bucket),
               write targets resolve against the full width;
      ctx    — context INCLUDING the incoming token;
      rem    — tokens the slot may still emit (budget - generated + 1);
      allow  — steps the slot may run THIS horizon (page reservation /
               chunked-prefill clamp; 0 = idle or frozen);
      key    — PRNG key chain for the sampler (split once per step).

    Per step, for every running slot: write the incoming token's KV
    (``ops.write_targets`` routes frozen slots out of bounds so their
    scatter drops), decode, sample ``sample(key, logits)``, then freeze the
    slot if the sampled token is EOS or the budget is spent. A slot that
    merely exhausts ``allow`` pauses with its pending token intact and
    resumes next horizon — per-token trajectories are identical for every
    horizon, so greedy outputs are horizon-invariant.

    Returns ``(toks [K, B], emit [K, B] bool, finished [B] bool, state,
    tokens, ctx, rem, key)`` — the last five re-enter the next horizon.
    """
    from repro.kernels.ops import write_targets
    W = bt.shape[1]
    bt_attn = bt[:, :table_width] if table_width < W else bt
    # samplers that opt in via the ``takes_run`` attribute (host-callback
    # adapters invoking a legacy per-row callable for active rows only)
    # get the run mask as a third argument
    sample_takes_run = getattr(sample, "takes_run", False)

    def body(carry, _):
        tokens, ctx, rem, allow, alive, state, key = carry
        run = alive & (allow > 0)
        npage, noff = write_targets(bt, ctx, run, page_size=page_size,
                                    n_pages=n_pages,
                                    ring_width=rt.ring_width)
        logits, state = decode_step(cfg, params, state, tokens, bt_attn,
                                    ctx, npage, noff, run=run, rt=rt)
        key, sub = jax.random.split(key)
        nxt = sample(sub, logits, run) if sample_takes_run \
            else sample(sub, logits)
        tokens = jnp.where(run, nxt, tokens)
        rem = jnp.where(run, rem - 1, rem)
        fin = run & ((nxt == eos_token) | (rem <= 0))
        alive = alive & ~fin
        # finished slots freeze at their final context; paused (allow
        # spent) and running slots advance so the pending token's write
        # position is ready for the next step/horizon
        ctx = jnp.where(run & ~fin, ctx + 1, ctx)
        allow = jnp.where(run, allow - 1, allow)
        return (tokens, ctx, rem, allow, alive, state, key), (nxt, run)

    alive0 = allow > 0
    carry = (tokens, ctx, rem, allow, alive0, state, key)
    (tokens, ctx, rem, allow, alive, state, key), (toks, emit) = jax.lax.scan(
        body, carry, None, length=horizon)
    return toks, emit, alive0 & ~alive, state, tokens, ctx, rem, key


# ---------------------------------------------------------------------------
# speculative decode: draft-propose + one-pass multi-query verify
# ---------------------------------------------------------------------------

def draft_propose(cfg, params, state, tokens, bt, ctx, allow, key, *,
                  horizon: int, table_width: int, page_size: int,
                  n_pages: int, sample, need_q: bool,
                  rt: Runtime = DEFAULT_RT):
    """Draft side of a speculative round: up to ``horizon`` masked decode
    steps proposing the next tokens for every slot at once.

    The draft shares the TARGET's block tables and page ids — its (smaller)
    pool is indexed by the same Va2Pa, so draft KV at any page/offset is a
    pure function of (token prefix, position) and radix-shared pages stay
    coherent across requests. ``tokens``/``ctx`` are the target's
    device-resident slot state (ctx INCLUDING the pending token, whose
    draft KV is written by step 0 at position ctx-1); ``allow`` is the
    horizon reservation — step ``i`` runs where ``i < clip(allow-1, 0,
    horizon)``, so a slot reserved for a single token proposes nothing and
    the verify pass degrades to plain decode for it.

    ``sample``: scan-sampler ``(key, logits) -> tokens`` (the engine's
    kind, so the proposal distribution q matches what the verifier
    assumes). ``need_q``: stack the raw per-step logits for residual
    rejection sampling (stochastic kinds only — greedy needs tokens alone).
    Returns ``(proposals [B, horizon], qlogits [horizon, B, V] | None,
    state, key)``; proposals/ctx/tokens of masked slots are untouched
    garbage the verifier masks out via its own ``allow``.
    """
    from repro.kernels.ops import write_targets
    W = bt.shape[1]
    bt_attn = bt[:, :table_width] if table_width < W else bt
    nprop = jnp.clip(allow - 1, 0, horizon)

    def body(carry, i):
        tokens, ctx, state, key = carry
        run = i < nprop
        npage, noff = write_targets(bt, ctx, run, page_size=page_size,
                                    n_pages=n_pages,
                                    ring_width=rt.ring_width)
        logits, state = decode_step(cfg, params, state, tokens, bt_attn,
                                    ctx, npage, noff, run=run, rt=rt)
        key, sub = jax.random.split(key)
        nxt = sample(sub, logits)
        tokens = jnp.where(run, nxt, tokens)
        ctx = jnp.where(run, ctx + 1, ctx)
        return (tokens, ctx, state, key), \
            ((nxt, logits) if need_q else nxt)

    carry = (tokens, ctx, state, key)
    (_, _, state, key), ys = jax.lax.scan(body, carry, jnp.arange(horizon))
    toks, qlogits = ys if need_q else (ys, None)
    return toks.T, qlogits, state, key


def decode_verify(cfg, params, state, tokens, proposals, qlogits, bt, ctx,
                  rem, allow, key, *, horizon: int, table_width: int,
                  page_size: int, n_pages: int, eos_token: int, verifier,
                  rt: Runtime = DEFAULT_RT):
    """One-pass speculative verify: score the pending token plus the
    draft's ``horizon`` proposals in a single multi-query target forward,
    accept a prefix, and advance the device slot state exactly as
    ``decode_multi`` would have.

    The round forwards ``[pending, d_1..d_G]`` at positions ctx-1..ctx+G-1
    (uniform attention stacks only — recurrent carries cannot roll back
    past rejected tokens). Every row's K/V lands via the multi-token
    ``write_prefill(ctx_start=ctx-1, valid_len=nprop+1)`` route — frozen /
    idle slots get valid_len 0 so their scatter drops, exactly like frozen
    slots in the fused scan — then ``kernels.ops.verify_attention`` scores
    all G+1 query rows against the paged pool in one split-K pass (query
    row t masked to tok < ctx+t, so the causal frontier advances inside the
    round). Rollback is free: rejected positions' KV is dead beyond the new
    ctx (attention masks it) and the next round's writes start at the new
    ctx-1, overwriting the first stale row before it can ever be read.

    ``verifier`` (serving.sampling.make_verifier) turns (logits, qlogits,
    proposals) into ``(candidates [B, G+1], accept_len [B])`` — greedy
    longest-matching-prefix (token-identical to target-only decoding) or
    stochastic residual rejection sampling. The emitted run is
    ``candidates[:e]`` with ``e = accept_len+1`` truncated at the first
    EOS/budget stop, replicating ``decode_multi``'s freeze semantics
    (finished slots do not advance past their final token).

    Returns ``(toks [G+1, B], emit [G+1, B] bool, finished [B], state,
    tokens, ctx, rem, key, accept_len [B])`` — decode_multi's contract plus
    the accept counter, so the engine folds spec rounds and plain horizons
    identically.
    """
    from repro.core.paged_kv import write_prefill
    from repro.kernels.ops import verify_attention
    B = tokens.shape[0]
    C = horizon + 1
    run = allow > 0
    nprop = jnp.clip(allow - 1, 0, horizon)
    seq = jnp.concatenate([tokens[:, None], proposals], axis=1)   # [B, C]
    start = jnp.maximum(ctx - 1, 0).astype(jnp.int32)
    valid_len = jnp.where(run, nprop + 1, 0)
    W = bt.shape[1]
    bt_attn = bt[:, :table_width] if table_width < W else bt
    kc = rt.kernels

    x = L.embed(params["embed"], seq)
    x = rt.constrain(x, "act")
    positions = default_positions(cfg, B, C, offset=start[:, None])
    cs = _cos_sin(cfg, positions)
    state = dict(state)
    windows = jnp.asarray(_window_array(cfg))
    pool = state["pool"]

    def block(h, xs):
        lp, w, pkl, pvl = xs
        hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, hn)      # [B, C, H, dh]
        if cs is not None:
            q = L.apply_rope(q, *cs)
            k = L.apply_rope(k, *cs)
        pkl, pvl = write_prefill(pkl, pvl, k, v, bt, ctx_start=start,
                                 valid_len=valid_len)
        grp = cfg.n_heads // cfg.n_kv_heads
        qr = q.transpose(0, 2, 1, 3).reshape(
            B, cfg.n_kv_heads, grp, C, cfg.d_head)
        a = verify_attention(
            qr, pkl, pvl, bt_attn, ctx, window=w,
            use_pallas=False if kc is None else kc.use_pallas,
            interpret=None if kc is None else kc.interpret,
            n_splits=1 if kc is None else kc.n_splits)
        a = a.transpose(0, 3, 1, 2, 4).reshape(B, C, cfg.q_dim)
        h = h + L.dense(a, lp["attn"]["wo"])
        return _prefill_block_tail(lp, cfg, h, None, rt), (pkl, pvl)

    x, (pk, pv) = jax.lax.scan(
        block, x, (params["layers"], windows, pool["k"], pool["v"]))
    state["pool"] = {"k": pk, "v": pv}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w_out = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.lm_head(x, w_out, transpose=cfg.tie_embeddings)    # [B, C, V]

    key, cand, acc = verifier(key, logits, qlogits, proposals, nprop, run)
    # EOS / budget truncation over the accepted run, replicating the fused
    # scan's per-step freeze: candidate j is EOS or spends the budget ->
    # emit exactly j+1 tokens and finish without advancing past them
    idx = jnp.arange(C)
    stop = (cand == eos_token) | (rem[:, None] - (idx + 1)[None] <= 0)
    stopped = (idx[None] <= acc[:, None]) & stop & run[:, None]
    any_stop = stopped.any(axis=1)
    e = jnp.where(any_stop, jnp.argmax(stopped, axis=1) + 1, acc + 1)
    e = jnp.where(run, e, 0).astype(jnp.int32)
    emit = idx[None] < e[:, None]                                 # [B, C]
    fin = run & any_stop
    newtok = cand[jnp.arange(B), jnp.maximum(e - 1, 0)]
    tokens = jnp.where(run, newtok, tokens)
    rem = jnp.where(run, rem - e, rem)
    ctx = jnp.where(run, ctx + e - fin.astype(jnp.int32), ctx)
    return (cand.T, emit.T, fin, state, tokens, ctx, rem, key,
            jnp.where(run, acc, 0).astype(jnp.int32))


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also fills the decode caches
# ---------------------------------------------------------------------------

def _prefill_block_tail(lp, cfg, h, cross, rt: Runtime):
    """Cross-attention + FFN epilogue of a prefill attention block, shared
    by the whole-sequence (``prefill``) and chunked (``prefill_chunk``)
    paths so the two can never diverge. ``cross``: (k, v) rows [B, enc,
    KVH, D] or None."""
    B, S = h.shape[:2]
    if cross is not None:
        hx = L.rms_norm(h, lp["lnx"], cfg.norm_eps)
        qx = L.dense(hx, lp["xattn"]["wq"]).reshape(
            B, S, cfg.n_heads, cfg.d_head)
        ax = L.flash_attention(qx, cross[0], cross[1], causal=False)
        h = h + L.dense(ax.reshape(B, S, cfg.q_dim), lp["xattn"]["wo"])
    if "ln2" in lp:
        h2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        y = (rt.moe_apply(lp["moe"], cfg, h2)[0] if "moe" in lp
             else L.mlp(lp["mlp"], h2, cfg.act))
        h = h + y
    return rt.constrain(h, "act")


def _xlstm_prefill_body(cfg, rt: Runtime, mask):
    """Scan body over (mLSTM, sLSTM) cycles with explicit state carry,
    shared by ``prefill`` and ``prefill_chunk``."""
    def body(carry, xs):
        h = carry
        lp_m, lp_s, st_m, st_s = xs
        y, st_m = SSM.mlstm_forward(lp_m, cfg, h, state=st_m,
                                    chunk=rt.gla_chunk, mask=mask)
        h = h + y
        y, st_s = SSM.slstm_forward(lp_s, cfg, h, state=st_s, mask=mask)
        return h + y, (st_m, st_s)
    return body


def _mamba_prefill_body(cfg, rt: Runtime, mask):
    """Scan body over a Mamba2 sub-stack with explicit state carry, shared
    by ``prefill`` and ``prefill_chunk``."""
    def mbody(h, xs):
        lp, st = xs
        y, st = SSM.mamba_forward(lp, cfg, h, state=st,
                                  chunk=rt.gla_chunk, mask=mask)
        return h + y, st
    return mbody


def prefill(cfg, params, state, tokens, bt, *, positions=None,
            extra_embeds=None, frames=None, last_idx=None, valid_len=None,
            rt: Runtime = DEFAULT_RT):
    """Run the prompt through the model, writing KV pages / recurrent states.

    Returns (fp32 logits of the LAST position [B, V], new_state). Requests in
    the batch share the (padded) length S; for length-bucketed batched
    prefill, ``last_idx`` [B] picks each request's true last position for the
    logits and ``valid_len`` [B] masks pad-position pool writes (causal
    attention already keeps end-padding out of the real positions' math).
    """
    from repro.core.paged_kv import write_prefill
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    if extra_embeds is not None:
        x = x + extra_embeds.astype(x.dtype)
    if cfg.rope_kind == "none" and cfg.family == "encdec":
        pe = jnp.asarray(L.sinusoidal_positions(S, cfg.d_model))
        x = x + pe[None].astype(x.dtype)
    x = rt.constrain(x, "act")
    if positions is None:
        positions = default_positions(cfg, B, S)
    cs = _cos_sin(cfg, positions)
    kinds = cfg.block_kinds()
    state = dict(state)
    aux_unused = jnp.float32(0)
    # recurrent carries are dense per-row state: length-bucketed batches
    # must stop each row's state at its true last token (attention needs no
    # mask — pad writes drop and causality isolates real positions)
    mask = None
    if valid_len is not None:
        mask = (jnp.arange(S)[None, :]
                < jnp.asarray(valid_len, jnp.int32)[:, None])

    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, frames, rt)
        ck, cv = make_cross_kv(cfg, params, enc_out)
        state["cross_k"], state["cross_v"] = ck, cv

    def attn_prefill_block(lp, h, w, pkl, pvl, cross=None):
        hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, hn)
        if cs is not None:
            q = L.apply_rope(q, *cs)
            k = L.apply_rope(k, *cs)
        if rt.write_pool is not None:
            pkl, pvl = rt.write_pool(pkl, pvl, k, v, bt)
        elif rt.ring_width:
            # ring pools recycle slots: tokens older than the ring are
            # overwritten before they could ever be read — write only the
            # final window (7x less scatter volume for mixtral prefill_32k;
            # EXPERIMENTS.md §Perf P4)
            page = pkl.shape[1]
            span = min(rt.ring_width * page, S)
            pkl, pvl = write_prefill(pkl, pvl, k[:, S - span:],
                                     v[:, S - span:], bt,
                                     ctx_start=S - span,
                                     ring_width=rt.ring_width)
        else:
            pkl, pvl = write_prefill(pkl, pvl, k, v, bt, valid_len=valid_len)
        kf = rt.constrain(k, "kv_full")
        vf = rt.constrain(v, "kv_full")
        a = L.flash_attention(q, kf, vf, causal=True, window=w)
        h = h + L.dense(a.reshape(B, S, cfg.q_dim), lp["attn"]["wo"])
        return _prefill_block_tail(lp, cfg, h, cross, rt), pkl, pvl

    if cfg.family == "encdec" or all(k in ("attn", "local") for k in kinds):
        windows = jnp.asarray(_window_array(cfg))
        pool = state["pool"]
        stack = params["dec"] if cfg.family == "encdec" else params["layers"]
        has_cross = cfg.family == "encdec"

        def body(carry, xs):
            h, pk, pv = carry
            if has_cross:
                i, lp, w, ckl, cvl = xs
                cross = (ckl, cvl)
            else:
                i, lp, w = xs
                cross = None
            pkl = jax.lax.dynamic_index_in_dim(pk, i, 0, keepdims=False)
            pvl = jax.lax.dynamic_index_in_dim(pv, i, 0, keepdims=False)
            h, pkl, pvl = attn_prefill_block(lp, h, w, pkl, pvl, cross)
            pk = jax.lax.dynamic_update_index_in_dim(pk, pkl, i, 0)
            pv = jax.lax.dynamic_update_index_in_dim(pv, pvl, i, 0)
            return (h, pk, pv), None

        body = jax.checkpoint(body) if rt.remat else body
        idx = jnp.arange(len(kinds))
        xs = ((idx, stack, windows, state["cross_k"], state["cross_v"])
              if has_cross else (idx, stack, windows))
        (x, pk, pv), _ = jax.lax.scan(body, (x, pool["k"], pool["v"]), xs)
        state["pool"] = {"k": pk, "v": pv}
    elif "mlstm" in params:
        body = _xlstm_prefill_body(cfg, rt, mask)
        body = jax.checkpoint(body) if rt.remat else body
        x, (new_m, new_s) = jax.lax.scan(
            body, x, (params["mlstm"], params["slstm"],
                      state["mlstm"], state["slstm"]))
        state["mlstm"], state["slstm"] = new_m, new_s
    else:                                               # zamba
        n_cyc = cfg.n_layers // len(cfg.pattern)
        per_cyc = sum(1 for k in cfg.pattern if k == "mamba")
        pool = state["pool"]
        pk, pv = pool["k"], pool["v"]
        new_mamba = []
        mbody = _mamba_prefill_body(cfg, rt, mask)
        mbody = jax.checkpoint(mbody) if rt.remat else mbody
        for c in range(n_cyc):
            sl = lambda a: a[c * per_cyc:(c + 1) * per_cyc]
            x, st_out = jax.lax.scan(
                mbody, x, (jax.tree.map(sl, params["mamba"]),
                           jax.tree.map(sl, state["mamba"])))
            new_mamba.append(st_out)
            x, pkl, pvl = attn_prefill_block(
                params["attn_shared"], x, 0, pk[c], pv[c])
            pk = pk.at[c].set(pkl)
            pv = pv.at[c].set(pvl)
        state["pool"] = {"k": pk, "v": pv}
        state["mamba"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *new_mamba)

    if last_idx is None:
        x = x[:, -1]
    else:
        x = x[jnp.arange(x.shape[0]), jnp.asarray(last_idx, jnp.int32)]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.lm_head(x, w, transpose=cfg.tie_embeddings)
    return logits, state


def prefill_chunk(cfg, params, state, tokens, bt, ctx_start, *,
                  last_idx=None, valid_len=None, rt: Runtime = DEFAULT_RT):
    """Chunked prefill continuation — the DCS-style interleave primitive.

    Processes tokens [B, C] at global positions ctx_start..ctx_start+C-1
    against context already held by earlier chunks. Attention layers write
    the chunk's K/V via ``write_prefill(ctx_start=...)``, gather their
    pages, and attend with ``q_offset=ctx_start`` so the causal mask spans
    prior chunks; recurrent layers (Mamba2 / mLSTM / sLSTM) resume from the
    explicit per-row carry in ``state`` (the previous chunk's returned
    state — chunk-boundary handoff, exactly the ``chunked_gla`` state
    mechanism) and enc-dec decoder chunks attend over the carried
    ``cross_k``/``cross_v`` rows (computed once from the encoder at
    admission). ``ctx_start``/``last_idx``/``valid_len`` may be traced, so
    one jit serves every chunk position; ``ctx_start`` may also be a [B]
    vector — each request resumes at its own depth (prefix-cache suffix
    prefill / snapshot restore over a batch of different resume depths).
    ``valid_len`` masks end-padding out of pool writes AND recurrent
    carries, so pow2 length-bucketed groups stay exact.

    ``state`` carries whatever the family needs (``pool`` and/or the
    ``RSTATE_KEYS`` rows, batch axis = B). Returns (fp32 logits at last_idx
    (default C-1) [B, V], new_state).
    """
    from repro.core.paged_kv import gather_kv, write_prefill
    B, C = tokens.shape
    x = L.embed(params["embed"], tokens)
    start = jnp.asarray(ctx_start, jnp.int32)
    offset = start if start.ndim == 0 else start[:, None]
    if cfg.rope_kind == "none" and cfg.family == "encdec":
        pos = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None] + offset,
                               (B, C))
        x = x + L.sinusoidal_at(pos, cfg.d_model).astype(x.dtype)
    x = rt.constrain(x, "act")
    positions = default_positions(cfg, B, C, offset=offset)
    cs = _cos_sin(cfg, positions)
    state = dict(state)
    mask = None
    if valid_len is not None:
        mask = (jnp.arange(C)[None, :]
                < jnp.asarray(valid_len, jnp.int32)[:, None])

    def chunk_attn_block(lp, h, w, pkl, pvl, cross=None):
        hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, hn)
        if cs is not None:
            q = L.apply_rope(q, *cs)
            k = L.apply_rope(k, *cs)
        pkl, pvl = write_prefill(pkl, pvl, k, v, bt, ctx_start=start,
                                 valid_len=valid_len)
        kf, vf = gather_kv(pkl, pvl, bt)        # [B, maxp*page, KVH, D]
        a = L.flash_attention(q, kf, vf, causal=True, window=w,
                              q_offset=start)
        h = h + L.dense(a.reshape(B, C, cfg.q_dim), lp["attn"]["wo"])
        return _prefill_block_tail(lp, cfg, h, cross, rt), pkl, pvl

    if "layers" in params:
        windows = jnp.asarray(_window_array(cfg))
        pool = state["pool"]

        # pool layers stream through the scan as xs/ys (same HBM-traffic
        # argument as decode_step)
        def body(h, xs):
            lp, w, pkl, pvl = xs
            h, pkl, pvl = chunk_attn_block(lp, h, w, pkl, pvl)
            return h, (pkl, pvl)

        x, (pk, pv) = jax.lax.scan(
            body, x, (params["layers"], windows, pool["k"], pool["v"]))
        state["pool"] = {"k": pk, "v": pv}
    elif cfg.family == "encdec":
        pool = state["pool"]

        def body(h, xs):
            lp, pkl, pvl, ck, cv = xs
            h, pkl, pvl = chunk_attn_block(lp, h, 0, pkl, pvl,
                                           cross=(ck, cv))
            return h, (pkl, pvl)

        x, (pk, pv) = jax.lax.scan(
            body, x, (params["dec"], pool["k"], pool["v"],
                      state["cross_k"], state["cross_v"]))
        state["pool"] = {"k": pk, "v": pv}
    elif "mlstm" in params:                             # xlstm
        x, (new_m, new_s) = jax.lax.scan(
            _xlstm_prefill_body(cfg, rt, mask), x,
            (params["mlstm"], params["slstm"],
             state["mlstm"], state["slstm"]))
        state["mlstm"], state["slstm"] = new_m, new_s
    else:                                               # zamba hybrid
        n_cyc = cfg.n_layers // len(cfg.pattern)
        per_cyc = sum(1 for k in cfg.pattern if k == "mamba")
        pool = state["pool"]
        pk, pv = pool["k"], pool["v"]
        new_mamba = []
        mbody = _mamba_prefill_body(cfg, rt, mask)
        for c in range(n_cyc):
            sl = lambda a: a[c * per_cyc:(c + 1) * per_cyc]
            x, st_out = jax.lax.scan(
                mbody, x, (jax.tree.map(sl, params["mamba"]),
                           jax.tree.map(sl, state["mamba"])))
            new_mamba.append(st_out)
            x, pkl, pvl = chunk_attn_block(
                params["attn_shared"], x, 0, pk[c], pv[c])
            pk = pk.at[c].set(pkl)
            pv = pv.at[c].set(pvl)
        state["pool"] = {"k": pk, "v": pv}
        state["mamba"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *new_mamba)

    if last_idx is None:
        x = x[:, -1]
    else:
        x = x[jnp.arange(B), jnp.asarray(last_idx, jnp.int32)]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = L.lm_head(x, w, transpose=cfg.tie_embeddings)
    return logits, state
