"""Core NN layers: norms, rotary embeddings, attention, MLPs, embeddings.

Everything is a pure function over explicit param pytrees. Matmuls run in the
model dtype (bf16) with fp32 accumulation (``preferred_element_type``);
norm/softmax/router math is fp32.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def dense(x: jax.Array, w) -> jax.Array:
    """x @ w in the compute dtype.

    bf16 in -> bf16 out: the MXU accumulates fp32 internally either way;
    requesting fp32 *outputs* (preferred_element_type=f32) materializes fp32
    activations/cotangents and pushes fp32 weight all-gathers into the FSDP
    path — measured 2x collective + activation traffic on the train cells
    (EXPERIMENTS.md §Perf T1). fp32 stays where it matters numerically:
    norms, softmax/flash accumulators, router/logits.

    ``w`` may be an int8 QTensor ({"q", "s"}, core/quant.py): dequantization
    fuses into the matmul per use — the int8 tensor is what streams from HBM.
    """
    if isinstance(w, dict):                      # int8 weight-only quant
        y = jax.lax.dot_general(
            x, w["q"].astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())))
        return (y * w["s"][..., 0, :].astype(x.dtype)).astype(x.dtype)
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ()))).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(x.dtype)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu",):            # silu on the gate half (applied by caller)
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head // 2, dtype=np.float32) * 2 / d_head))


def rope_cos_sin(positions: jax.Array, d_head: int, theta: float):
    """positions [...]->(cos,sin) of shape [..., d_head/2]."""
    inv = jnp.asarray(rope_freqs(d_head, theta))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, d_head: int, theta: float,
                  sections: tuple[int, ...]):
    """M-RoPE: positions [3, B, S] (t/h/w); sections sum to d_head/2.

    Frequency slot j takes its position from the axis whose section owns j
    (Qwen2-VL §3.1, interleaved t/h/w layout simplified to contiguous blocks).
    """
    assert sum(sections) == d_head // 2, (sections, d_head)
    inv = jnp.asarray(rope_freqs(d_head, theta))
    sel = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos = jnp.take(positions.astype(jnp.float32), jnp.asarray(sel), axis=0)  # [d/2,B,S]
    ang = jnp.moveaxis(pos, 0, -1) * inv                                     # [B,S,d/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, S, H, D]; cos/sin [B, S, D/2] (or broadcastable)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — chunked online-softmax ("lax-flash"), GQA + sliding window
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: jax.Array | int = 0,
                    q_offset: jax.Array | int = 0,
                    kv_chunk: int = 512) -> jax.Array:
    """Memory-bounded attention via online softmax over KV chunks.

    q [B, Sq, H, D]; k,v [B, Skv, KVH, D]. ``q_offset`` is the global position
    of q[0] relative to k[0] (sequence-parallel shards / prefill
    continuation); a [B] vector gives each request its own offset
    (prefix-cache suffix prefill batches different resume depths).
    ``window``>0 restricts attention to the last ``window`` keys (inclusive of
    self); it may be a traced scalar (per-layer scan value), 0 = unwindowed.
    Returns [B, Sq, H, D].

    GQA-group-aware: K/V are never repeated to H heads (grouped einsums), KV
    chunks are dynamic-sliced in place (no stacked/transposed copy), the
    probability matrix drops to the KV dtype for the PV matmul; fp32 lives
    only in the accumulators (§Perf P2).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kv_chunk = min(kv_chunk, skv)
    while skv % kv_chunk:          # largest divisor <= requested chunk
        kv_chunk -= 1
    n_chunks = skv // kv_chunk

    qt = q.reshape(b, sq, kvh, g, d).transpose(0, 2, 3, 1, 4)  # [B,KVH,G,Sq,d]
    qt = qt.astype(jnp.float32)
    q_pos = (jnp.reshape(jnp.asarray(q_offset, jnp.int32), (-1, 1))
             + jnp.arange(sq)[None, :])                        # [1|B,Sq]
    scale = 1.0 / math.sqrt(d)
    w = jnp.asarray(window, jnp.int32)

    def step(carry, idx):
        m, l, o = carry
        kb = jax.lax.dynamic_slice_in_dim(k, idx * kv_chunk, kv_chunk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, idx * kv_chunk, kv_chunk, 1)
        kv_pos = idx * kv_chunk + jnp.arange(kv_chunk)[None, :]
        s = jnp.einsum("bkgqd,bckd->bkgqc", qt, kb,
                       preferred_element_type=jnp.float32) * scale
        ok = jnp.broadcast_to(kv_pos < skv, (1, kv_chunk))[:, None, :]
        ok = jnp.broadcast_to(ok, (1, sq, kv_chunk))
        if causal:
            ok = ok & (kv_pos[None, :, :] <= q_pos[:, :, None])
        ok = ok & ((w <= 0) | (kv_pos[None, :, :] > q_pos[:, :, None] - w))
        s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    o0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0),
                                jnp.arange(n_chunks, dtype=jnp.int32))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         ctx_len: jax.Array, *, window: int = 0) -> jax.Array:
    """Single-token decode attention against a (contiguous) cache.

    q [B, H, D]; k,v [B, T, KVH, D]; ctx_len [B] = number of valid cache
    entries (the new token's K/V already appended). Reference path / oracle.
    """
    b, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // kvh)
    v = _repeat_kv(v, h // kvh)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    pos = jnp.arange(t)[None, :]
    ok = pos < ctx_len[:, None]
    if window:
        ok = ok & (pos >= ctx_len[:, None] - window)
    s = jnp.where(ok[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w1": init_dense(ks[0], d_model, d_ff, dtype),
         "w2": init_dense(ks[1], d_ff, d_model, dtype)}
    if act in ("swiglu", "geglu"):
        p["w3"] = init_dense(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p, x: jax.Array, act: str) -> jax.Array:
    h = activate(dense(x, p["w1"]), act)
    if "w3" in p:
        h = h * dense(x, p["w3"])
    return dense(h, p["w2"])


# ---------------------------------------------------------------------------
# attention block params
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype, *, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {"wq": init_dense(ks[0], cfg.d_model, cfg.q_dim, dtype),
         "wk": init_dense(ks[1], cfg.d_model, cfg.kv_dim, dtype),
         "wv": init_dense(ks[2], cfg.d_model, cfg.kv_dim, dtype),
         "wo": init_dense(ks[3], cfg.q_dim, cfg.d_model, dtype,
                          scale=1.0 / math.sqrt(cfg.q_dim * 2 * cfg.n_layers))}
    if cfg.qk_norm and not cross:
        p["qn"] = jnp.zeros((cfg.d_head,), dtype)
        p["kn"] = jnp.zeros((cfg.d_head,), dtype)
    return p


def qkv_project(p, cfg, x: jax.Array):
    """x [B,S,D] -> q [B,S,H,dh], k,v [B,S,KVH,dh], with qk-norm if configured."""
    b, s, _ = x.shape
    q = dense(x, p["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = dense(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = dense(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if "qn" in p:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def lm_head(x: jax.Array, w, *, transpose: bool) -> jax.Array:
    """Logits in fp32. ``transpose`` for tied embeddings ([V,D] table)."""
    if isinstance(w, dict):                      # int8 head (untied only)
        y = jax.lax.dot_general(
            x, w["q"].astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return y * w["s"][..., 0, :]
    wt = w.T if transpose else w
    return jax.lax.dot_general(
        x, wt, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    pos = np.arange(n, dtype=np.float32)[:, None]
    div = np.exp(np.arange(0, d, 2, dtype=np.float32) * (-math.log(10000.0) / d))
    pe = np.zeros((n, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return pe


def sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding at arbitrary (traced) positions [...]->[..., d]."""
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    ang = positions.astype(jnp.float32)[..., None] * div
    out = jnp.zeros((*positions.shape, d), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang))
    return out
