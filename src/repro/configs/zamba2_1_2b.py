"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242; hf].

Pattern: 18 Mamba2 blocks then one shared-weight full-attention block, cycled
twice (38 layers, attention invoked twice — matching zamba2's shared-block
design). Mamba core is recurrent (constant state) and only the two attention
invocations keep (paged, ITPP-sharded) KV -> long_500k runs (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,         # MHA in the shared attention blocks
    d_head=64,
    d_ff=8192,
    vocab_size=32000,
    pattern=("mamba",) * 18 + ("attn",),
    act="gelu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
))
set_skips(CONFIG.name, set())
