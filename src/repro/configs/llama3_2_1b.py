"""llama3.2-1b — dense GQA llama3 [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,             # 32 x 64 = 2048
    d_ff=8192,
    vocab_size=128256,
    act="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
))
# pure full attention -> 500k decode would need an unbounded quadratic-history
# KV cache; skipped per assignment (DESIGN.md §6).
set_skips(CONFIG.name, {"long_500k"})
