"""qwen2-vl-7b — VLM transformer backbone with M-RoPE [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision tower is a STUB —
``input_specs()`` provides patch-embedding stand-ins and the 3-axis
(temporal, height, width) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab_size=152064,
    act="swiglu",
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),   # t/h/w sections of d_head/2 = 64
    rope_theta=1_000_000.0,
    source="arXiv:2409.12191",
))
set_skips(CONFIG.name, {"long_500k"})
