"""qwen1.5-14b — the paper's mid-scale evaluation model (Table 1 LLM-14B:
40L, 40H, d_h=128, SwiGLU, 32K context) [arXiv:2309.16609]."""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="qwen1.5-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=13696,
    vocab_size=151936,
    act="swiglu",
    rope_theta=1_000_000.0,
    source="paper Table 1 (Qwen1.5-14B)",
))
set_skips(CONFIG.name, {"long_500k"})
