"""qwen1.5-7b — the paper's own primary evaluation model (Table 1 LLM-7B:
32L, 32H, d_h=128, SwiGLU, no GQA, 32K context) [arXiv:2309.16609]."""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="qwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,         # no GQA, per the paper's Table 1
    d_head=128,
    d_ff=11008,
    vocab_size=151936,
    act="swiglu",
    rope_theta=1_000_000.0,
    source="paper Table 1 (Qwen1.5-7B)",
))
set_skips(CONFIG.name, {"long_500k"})
