"""yi-34b — llama-arch dense GQA [arXiv:2403.04652; hf].

56 query heads: indivisible by a 16-way model axis — the showcase for ITPP
(token-parallel) sharding over head-first allocation (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,            # 56 x 128 = 7168
    d_ff=20480,
    vocab_size=64000,
    act="swiglu",
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
))
set_skips(CONFIG.name, {"long_500k"})
