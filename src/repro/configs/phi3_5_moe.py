"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

16 experts == 16-way model axis -> true expert parallelism (moe_mode="ep")
is exercised on this arch (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab_size=32064,
    act="swiglu",
    n_experts=16,
    moe_top_k=2,
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
set_skips(CONFIG.name, {"long_500k"})
