"""gemma3-27b — dense GQA, 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt family; unverified].

local layers use a 1024-token sliding window (bounded KV pages); every 6th
layer is global full attention (ITPP-sharded at long context). long_500k runs:
5/6 of layers have window-bounded KV and the global layers' 500k KV shards
over the whole pod via ITPP (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=1024,
    act="geglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-27b-pt",
))
set_skips(CONFIG.name, set())
