"""Config system: model architectures, input shapes, parallelism plans.

Every assigned architecture is a ``ModelConfig``; every assigned input shape
is a ``ShapeConfig``; the distribution strategy is a ``ParallelConfig``.
``Cell = (ModelConfig, ShapeConfig, ParallelConfig)`` is the unit the dry-run,
roofline, and benchmarks iterate over.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Block kinds appearing in layer patterns.
#   attn    - full (global) causal self-attention
#   local   - sliding-window causal self-attention (cfg.sliding_window)
#   mamba   - Mamba2 selective-state-space block
#   mlstm   - xLSTM matrix-LSTM block
#   slstm   - xLSTM scalar-LSTM block
# MoE applies to the FFN of attn/local blocks when cfg.n_experts > 0.
# ---------------------------------------------------------------------------
BLOCK_KINDS = ("attn", "local", "mamba", "mlstm", "slstm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    pattern: tuple[str, ...] = ("attn",)   # cycled over n_layers
    sliding_window: int = 0
    act: str = "swiglu"              # swiglu | geglu | relu | gelu
    norm_eps: float = 1e-5
    qk_norm: bool = False
    rope_kind: str = "rope"          # rope | mrope | none (learned/sinusoidal)
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM ---
    ssm_state: int = 0               # N (state size per head)
    ssm_expand: int = 2              # d_inner = ssm_expand * d_model
    ssm_head_dim: int = 64           # mamba2 P
    ssm_conv: int = 4
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0                 # fixed encoder context (1500 audio frames)
    cross_attn: bool = False
    # --- VLM ---
    mrope_sections: tuple[int, ...] = ()   # (t, h, w) rotary sections, in d_head/2 units
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    vocab_multiple: int = 256        # Megatron-style vocab padding
    source: str = ""                 # provenance tag from the assignment table

    # ---------------- derived ----------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def block_kinds(self) -> tuple[str, ...]:
        """Per-layer kinds, the pattern cycled across n_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return not any(k in ("attn", "local") for k in self.block_kinds())

    @property
    def uniform_stack(self) -> bool:
        """True when all layers share one block kind & shape (PP-stackable)."""
        kinds = set(self.block_kinds())
        return len(kinds) == 1 and self.enc_layers == 0

    # ------------- analytics (used by roofline & the PIM model) -------------
    def param_count(self) -> int:
        n = 0
        n += self.padded_vocab * self.d_model                       # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * self.d_model                   # lm head
        for kind in self.block_kinds():
            n += 2 * self.d_model                                   # norms
            if kind in ("attn", "local"):
                n += self.d_model * (self.q_dim + 2 * self.kv_dim)  # qkv
                n += self.q_dim * self.d_model                      # proj
                if self.is_moe:
                    n += self.d_model * self.n_experts              # router
                    n += self.n_experts * 3 * self.d_model * self.d_ff
                elif self.d_ff:
                    mult = 3 if self.act in ("swiglu", "geglu") else 2
                    n += mult * self.d_model * self.d_ff
            elif kind == "mamba":
                di, ns = self.d_inner, self.ssm_state
                n += self.d_model * (2 * di + 2 * ns + self.ssm_n_heads)
                n += di * self.d_model
            elif kind in ("mlstm", "slstm"):
                di = self.d_inner
                n += self.d_model * 4 * di + di * self.d_model
        if self.enc_layers:
            per = (self.d_model * (self.q_dim + 2 * self.kv_dim)
                   + self.q_dim * self.d_model
                   + 2 * self.d_model * self.d_ff)
            n += self.enc_layers * per
            # decoder cross-attention
            n += self.n_layers * (self.d_model * (self.q_dim + 2 * self.kv_dim)
                                  + self.q_dim * self.d_model)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for k in self.block_kinds() if k in ("attn", "local"))
        all_exp = moe_layers * self.n_experts * 3 * self.d_model * self.d_ff
        act_exp = moe_layers * self.moe_top_k * 3 * self.d_model * self.d_ff
        return full - all_exp + act_exp

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        attn_layers = sum(1 for k in self.block_kinds() if k in ("attn", "local"))
        return attn_layers * 2 * self.kv_dim * bytes_per_el


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How a cell is laid out on the mesh."""
    dp: int = 16
    tp: int = 16
    pods: int = 1
    pod_mode: str = "dp"         # pp | dp  (how the pod axis is used)
    attn_mode: str = "sp"        # sp (sequence parallel) | heads (Megatron) — training
    moe_mode: str = "tp"         # tp (per-expert TP) | ep (expert parallel)
    decode_page_axes: tuple[str, ...] = ("model",)   # mesh axes sharding KV pages
    page_size: int = 256         # tokens per KV page
    remat: bool = True
    # grad-accum microbatches. 1 by default: with remat bounding activation
    # memory, extra microbatches only re-run the per-step FSDP weight
    # all-gathers (measured 4x collective waste — EXPERIMENTS.md §Perf).
    microbatches: int = 1
    # ---- kernel selection (threaded into kernels.backend.KernelConfig) ----
    # use_pallas: None = autodetect (pallas on TPU, jnp reference elsewhere);
    # kernel_interpret: None = autodetect (compiled on TPU, interpret off-TPU,
    # REPRO_KERNEL_INTERPRET env override); kernel_splits: split-K partitions
    # of the decode page axis inside one kernel call.
    use_pallas: Optional[bool] = None
    kernel_interpret: Optional[bool] = None
    kernel_splits: int = 1
    # fused multi-step decode: serving ticks run this many decode steps
    # (decode + on-device sampling + EOS/budget masking) under ONE jit, so
    # the host syncs once per horizon instead of once per token (threads
    # into serving EngineConfig.decode_horizon / launch.serve
    # --decode-horizon). 1 = per-token dispatch; greedy outputs are
    # horizon-invariant.
    decode_horizon: int = 8
    param_dtype: str = "bfloat16"
    fsdp_params: bool = True     # shard params over the data axis too (ZeRO-3)
    serve_quant: str = ""        # "int8" = weight-only quant on serve paths

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp * self.pods


def reduced(cfg: ModelConfig, *, layers: int | None = None) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    n_layers = layers if layers is not None else max(2, 2 * len(cfg.pattern))
    if len(cfg.pattern) > 1:   # keep at least one full pattern cycle
        n_layers = max(n_layers, len(cfg.pattern))
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        vocab_multiple=16,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.is_moe:
        kw.update(n_experts=4, moe_top_k=2)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_head_dim=16)
    if cfg.enc_layers:
        kw.update(enc_layers=2, enc_seq=8)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(2, 3, 3))
    return replace(cfg, **kw)


def validate_draft_pair(target: ModelConfig, draft: ModelConfig) -> None:
    """Reject incompatible draft/target pairings for speculative decoding.

    The draft proposes token IDS the target then scores, so the two MUST
    share a tokenizer — in config terms, identical ``vocab_size`` (and
    ``padded_vocab``, or the verify jit's lm-head shapes silently diverge
    from the id space). Cross-family pairs like llama3 (128256) drafting
    for qwen (151936) fail here, at ``EngineConfig.draft_config``
    validation time, not as a shape error inside the compiled verify pass.
    Speculative verify also needs attention stacks on BOTH sides: a
    recurrent carry cannot roll back past rejected positions, while paged
    KV rolls back for free (stale rows are masked then overwritten).
    """
    if draft.vocab_size != target.vocab_size or \
            draft.padded_vocab != target.padded_vocab:
        raise ValueError(
            f"draft/target tokenizer mismatch: draft {draft.name!r} has "
            f"vocab {draft.vocab_size} (padded {draft.padded_vocab}) but "
            f"target {target.name!r} has vocab {target.vocab_size} (padded "
            f"{target.padded_vocab}); EngineConfig.draft_config requires a "
            "draft sharing the target's tokenizer")
    for side, cfg in (("target", target), ("draft", draft)):
        if cfg.family == "encdec" or not all(
                k in ("attn", "local") for k in cfg.block_kinds()):
            raise ValueError(
                f"speculative decode needs attention-only decoder stacks; "
                f"{side} {cfg.name!r} (family {cfg.family!r}, pattern "
                f"{cfg.pattern!r}) has recurrent or encoder blocks whose "
                "state cannot roll back past rejected proposals")


# Populated by configs/__init__.py
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    return sorted(_REGISTRY)


# Which shapes apply per arch (DESIGN.md §6). None means all four.
_SKIP: dict[str, set[str]] = {}


def set_skips(name: str, skips: set[str]) -> None:
    _SKIP[name] = skips


def applicable_shapes(name: str) -> list[str]:
    skips = _SKIP.get(name, set())
    return [s for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k")
            if s not in skips]
