"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

SWA bounds the KV cache to the window, which pairs naturally with the DPA
paged pool (window-capped page budget) -> long_500k runs (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=("local",),    # sliding-window attention per the assignment
    sliding_window=4096,
    act="swiglu",
    n_experts=8,
    moe_top_k=2,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
))
set_skips(CONFIG.name, set())
