"""qwen1.5-72b — the paper's largest evaluation model (Table 1 LLM-72B:
80L, 64H, d_h=128, SwiGLU, 32K context) [arXiv:2309.16609]."""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="qwen1.5-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=64,
    d_head=128,
    d_ff=24576,
    vocab_size=151936,
    act="swiglu",
    rope_theta=1_000_000.0,
    source="paper Table 1 (Qwen1.5-72B)",
))
set_skips(CONFIG.name, {"long_500k"})
