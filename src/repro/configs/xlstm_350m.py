"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

Attention-free: constant-size recurrent state instead of a KV cache, so the
paper's ITPP/DPA KV-cache machinery is inapplicable by design (noted in
DESIGN.md §6 / §Arch-applicability); decode uses recurrent state slots. The
assignment's d_ff=0 means the xLSTM blocks carry their own up/down
projections (ssm_expand).
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_head=256,            # qkv head dim inside mLSTM blocks
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm", "slstm"),
    ssm_expand=2,
    rope_kind="none",
    source="arXiv:2405.04517",
))
set_skips(CONFIG.name, set())   # recurrent -> long_500k applies
