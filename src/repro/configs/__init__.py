"""Architecture registry: import every config module to register it."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, ParallelConfig, SHAPES,
    get_config, list_configs, applicable_shapes, reduced,
)

# assigned architectures (one module per arch, per the assignment)
from repro.configs import llama3_2_1b    # noqa: F401
from repro.configs import internlm2_1_8b # noqa: F401
from repro.configs import yi_34b         # noqa: F401
from repro.configs import gemma3_27b     # noqa: F401
from repro.configs import xlstm_350m     # noqa: F401
from repro.configs import whisper_small  # noqa: F401
from repro.configs import mixtral_8x22b  # noqa: F401
from repro.configs import phi3_5_moe     # noqa: F401
from repro.configs import qwen2_vl_7b    # noqa: F401
from repro.configs import zamba2_1_2b    # noqa: F401
# the paper's own evaluation models
from repro.configs import qwen1_5_7b     # noqa: F401
from repro.configs import qwen1_5_14b    # noqa: F401
from repro.configs import qwen1_5_72b    # noqa: F401

ASSIGNED = (
    "llama3.2-1b", "internlm2-1.8b", "yi-34b", "gemma3-27b", "xlstm-350m",
    "whisper-small", "mixtral-8x22b", "phi3.5-moe-42b-a6.6b", "qwen2-vl-7b",
    "zamba2-1.2b",
)
PAPER_MODELS = ("qwen1.5-7b", "qwen1.5-14b", "qwen1.5-72b")
