"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356; unverified].

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings [B, 1500, d_model] for the encoder.
Decoder = LM backbone with cross-attention to the 1500 encoder states.
Full attention, enc-dec -> long_500k skipped (DESIGN.md §6). vocab 51865 is
padded to 52224 (Megatron-style) for 16-way vocab sharding.
"""
from repro.configs.base import ModelConfig, register, set_skips

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,           # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    rope_kind="none",      # learned absolute positions (sinusoidal here)
    enc_layers=12,
    enc_seq=1500,
    cross_attn=True,
    source="arXiv:2212.04356",
))
set_skips(CONFIG.name, {"long_500k"})
