"""Live PIM counters: the quantities ``kernel_bench`` / ``pim_model``
model offline, emitted during serving.

PIMphony's mechanisms are utilization arguments, so the counters mirror
them one-to-one:

* **modeled HBM bytes/token** (TCP's bandwidth story) — what a decode step
  streams for the current batch/contexts, via
  ``kernels.backend.decode_hbm_bytes`` (same formula as kernel_bench's
  MB/token column), plus a cumulative modeled-bytes counter the engine
  feeds with per-horizon (tokens x context) products;
* **live vs pool occupancy** (DPA's capacity story) — pages in use, pages
  the live contexts actually need, and the waste a static max-context
  reservation would have cost instead;
* **pow2 decode-table bucket** high-water — the live width the fused
  decode actually dispatches with (``serving.prefill.decode_table_bucket``);
* **channel-utilization proxy** (ITPP) — ``pim_model.attn_channel_util``
  over the live (batch, mean context) from the scheduler's host snapshot.

Everything reads host-side scheduler/allocator state through pull
bindings: scrapes cost a few numpy reductions and the hot path pays
nothing. No device syncs anywhere in this module.
"""
from __future__ import annotations

import numpy as np

from repro.core import pim_model
from repro.kernels.backend import decode_hbm_bytes


class PIMCounters:
    """Binds the live PIM gauges for one engine; the engine calls
    ``on_horizon`` at each collect (host-side snapshot already in hand) and
    ``observe_bucket`` when it re-buckets the decode table."""

    def __init__(self, registry, model_cfg, batcher, *,
                 bytes_per_el: int = 2, system: pim_model.System | None = None):
        self.cfg = model_cfg
        self.batcher = batcher
        self.alloc = batcher.alloc
        self.el = int(bytes_per_el)
        self.llm = pim_model.LLM(
            model_cfg.name, model_cfg.n_layers, model_cfg.d_model,
            model_cfg.n_heads, model_cfg.n_kv_heads, model_cfg.d_head,
            model_cfg.d_ff, bytes_per_el=self.el)
        # one-node full LoL-PIM geometry unless the caller scales it
        self.system = system or pim_model.lol_pim(1)
        self.bucket_hw = 0
        self.pages_hw = 0
        r = registry
        self.c_bytes = r.counter(
            "pim_modeled_hbm_bytes_total",
            "modeled KV bytes streamed by decode (sum over emitted tokens "
            "of context x kv_bytes_per_token)")
        r.bind("pim_hbm_bytes_per_token", self._bytes_per_token,
               "modeled KV bytes one decode step streams at the live mean "
               "context")
        r.bind("pim_channel_util", self._channel_util,
               "ITPP channel-utilization proxy at the live (batch, mean "
               "context)")
        r.bind("kv_pages_total", lambda: self.alloc.n_pages,
               "device KV page pool size", labels={"tier": "device"})
        r.bind("kv_pages_in_use", lambda: self.alloc.pages_in_use,
               "device KV pages allocated (requests + cache tree)",
               labels={"tier": "device"})
        r.bind("kv_pages_in_use_peak", self._pages_peak,
               "high-water of device KV pages in use",
               labels={"tier": "device"})
        r.bind("kv_live_tokens", self._live_tokens,
               "tokens of KV the live contexts actually hold")
        r.bind("dpa_page_waste_ratio", self._waste_ratio,
               "allocated-but-unused fraction of in-use pages (lazy "
               "allocation's rounding waste)")
        r.bind("dpa_static_pages_saved", self._static_saved,
               "pages a static max-context reservation would hold beyond "
               "the lazy allocation, for the live batch")
        r.bind("decode_table_bucket", lambda: self._bucket(),
               "pow2 block-table width the next decode dispatch uses")
        r.bind("decode_table_bucket_highwater", lambda: self.bucket_hw,
               "largest pow2 decode-table bucket dispatched so far")
        cache = batcher.cache
        if cache is not None:
            r.bind("kv_cache_pages", cache.tree.device_pages,
                   "prefix-cache radix-tree pages resident on device",
                   labels={"tier": "device"})
            r.bind("kv_cache_pages", cache.tree.host_pages,
                   "prefix-cache radix-tree pages resident on the host tier",
                   labels={"tier": "host"})
            for name in ("lookups", "hits", "hit_tokens", "inserted_pages",
                         "evicted_pages", "reclaims", "cow_copies"):
                r.bind(f"kv_cache_{name}",
                       (lambda n=name: getattr(cache.stats, n)),
                       f"prefix cache {name.replace('_', ' ')}",
                       kind="counter")
            if cache.host is not None:
                host = cache.host
                r.bind("kv_pages_total", lambda: host.capacity,
                       "host offload tier capacity (pages)",
                       labels={"tier": "host"})
                r.bind("kv_pages_in_use", lambda: host.used,
                       "host offload tier pages used",
                       labels={"tier": "host"})
                r.bind("kv_pages_in_use_peak",
                       lambda: host.stats.peak_host_pages,
                       "high-water of host tier pages used",
                       labels={"tier": "host"})
                for name in ("swapped_out_pages", "swapped_in_pages",
                             "dropped_pages"):
                    r.bind(f"kv_{name}",
                           (lambda n=name: getattr(host.stats, n)),
                           f"host tier {name.replace('_', ' ')}",
                           kind="counter")

    # ---- live snapshot reductions (pull bindings) ---------------------
    def _live(self) -> tuple[int, float]:
        ctx = self.batcher._ctx
        b = int(np.count_nonzero(ctx))
        return b, (float(ctx.sum()) / b if b else 0.0)

    def _bytes_per_token(self) -> float:
        _b, avg = self._live()
        return decode_hbm_bytes(avg, self.cfg.n_kv_heads, self.cfg.d_head,
                                self.el, self.cfg.n_layers)

    def _channel_util(self) -> float:
        b, avg = self._live()
        if b == 0:
            return 0.0
        ctx = self.batcher._ctx
        live = ctx[ctx > 0].astype(np.float64)
        cv = float(live.std() / live.mean()) if live.mean() > 0 else 0.0
        return pim_model.attn_channel_util(self.system, self.llm, b, avg, cv)

    def _live_tokens(self) -> int:
        return int(self.batcher._ctx.sum())

    def _pages_peak(self) -> int:
        self.pages_hw = max(self.pages_hw, self.alloc.pages_in_use)
        return self.pages_hw

    def _waste_ratio(self) -> float:
        used = self.alloc.pages_in_use
        if used == 0:
            return 0.0
        need = float(self._live_tokens()) / self.alloc.page_size
        return max(0.0, 1.0 - need / used)

    def _static_saved(self) -> int:
        """Pages a static allocator would reserve for the live batch beyond
        what lazy allocation holds right now (DPA's headline saving)."""
        page = self.alloc.page_size
        static_pages = -(-self.batcher.max_context // page)
        occupied = sum(1 for r in self.batcher.slots if r is not None)
        lazy = int(self.batcher._npages.sum())
        return max(0, occupied * static_pages - lazy)

    def _bucket(self) -> int:
        from repro.serving.prefill import decode_table_bucket
        width = self.batcher._bt_width or 1
        return decode_table_bucket(self.batcher.max_live_pages(), width)

    # ---- engine-driven updates ----------------------------------------
    def on_horizon(self, tokens_bytes: float) -> None:
        """Cumulative modeled bytes for one collected horizon: the engine
        passes sum over emitted tokens of ctx-at-dispatch x
        kv_bytes_per_token (cheap host arithmetic on data it already has).
        Also refreshes the pow2-bucket and pool high-waters."""
        self.c_bytes.inc(tokens_bytes)
        self.bucket_hw = max(self.bucket_hw, self._bucket())
        self.pages_hw = max(self.pages_hw, self.alloc.pages_in_use)

    def kv_bytes_per_token(self) -> float:
        return self.llm.kv_bytes_per_token
