"""Prometheus text-exposition endpoint (``GET /metrics``).

A stdlib ``ThreadingHTTPServer`` on a daemon thread: ``serve.py`` starts it
with ``--metrics-port`` (0 = ephemeral; the bound port is reported and kept
in ``LAST_SERVER`` so the in-process CI smoke can scrape without a race).
Scrapes call ``registry.render()`` on the serving thread's live objects —
pull bindings read plain python ints/floats, so a concurrent scrape is
torn-read-safe at worst, never corrupting."""
from __future__ import annotations

import logging
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

log = logging.getLogger(__name__)

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# most recent endpoint started in this process (CI smoke / tests)
LAST_SERVER: "MetricsServer | None" = None


class MetricsServer:
    def __init__(self, registry, port: int = 0, host: str = "127.0.0.1",
                 scrape_timeout: float = 10.0):
        reg = registry
        # default urlopen timeout for self-scrapes (tests / CI smoke);
        # per-call override via scrape(timeout=...)
        self.scrape_timeout = float(scrape_timeout)

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                       # noqa: N802 (stdlib API)
                if self.path.split("?")[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                body = reg.render().encode()
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):               # quiet access log
                pass

        self.httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self.httpd.server_address[1]
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        name="metrics-http", daemon=True)
        self._thread.start()
        global LAST_SERVER
        LAST_SERVER = self

    @property
    def url(self) -> str:
        return f"http://{self.httpd.server_address[0]}:{self.port}/metrics"

    def scrape(self, timeout: float | None = None) -> str:
        """Fetch the endpoint over real HTTP (tests / CI smoke)."""
        from urllib.request import urlopen
        t = self.scrape_timeout if timeout is None else timeout
        with urlopen(self.url, timeout=t) as resp:
            assert resp.headers.get("Content-Type") == CONTENT_TYPE
            return resp.read().decode()

    def close(self, join_timeout: float = 5.0) -> bool:
        """Shut the endpoint down. Returns True once the serving thread has
        exited; if it is still alive after ``join_timeout`` the leak is
        REPORTED (warning log) and False is returned instead of being
        swallowed — the thread is a daemon, so the process can still exit,
        but a caller that cares (tests, long-lived servers restarting the
        endpoint) can now see the failure."""
        self.httpd.shutdown()
        self.httpd.server_close()
        self._thread.join(timeout=join_timeout)
        if self._thread.is_alive():
            log.warning("metrics-http thread still alive %.1fs after "
                        "shutdown — leaked daemon thread", join_timeout)
            return False
        return True
