"""Chrome-trace/Perfetto exporter for the serving tick pipeline.

The fused tick (docs/serving.md § tick pipeline) is a DCS ping-pong at host
granularity: next-tick host work overlaps device compute, and the only
rendezvous is the horizon's token readback. That story is invisible in
aggregate timings — this exporter renders it as a Trace Event JSON the
Perfetto UI (ui.perfetto.dev) or ``chrome://tracing`` loads directly:

* pid 1 "engine" holds one thread track per pipeline stage — ``host work``
  (schedule / config assembly / overlap-window work), ``prefill``,
  ``dispatch`` (non-blocking jit dispatches), ``sync`` (the blocking
  readback) and ``device (inferred)``, an async span from each horizon's
  dispatch to its collect. Dispatch/compute overlap shows as host/prefill
  slices sitting strictly inside the inferred device span of the
  *previous* horizon.
* pid 2 "requests" holds per-request lifecycle spans (queue -> prefill ->
  decode) emitted at finish by ``tracing.RequestTracker``, plus instant
  markers for preemptions.

Events are buffered host-side (bounded; drops are counted, never silently)
and written once by ``save`` — nothing here touches the device.
"""
from __future__ import annotations

import json
import time

ENGINE_PID = 1
REQUEST_PID = 2

# fixed tids so the track order in the UI tells the pipeline story
TRACKS = {"host": 1, "prefill": 2, "dispatch": 3, "sync": 4, "device": 5}
TRACK_NAMES = {1: "host work", 2: "prefill", 3: "dispatch (async)",
               4: "sync rendezvous", 5: "device (inferred)"}


class TraceWriter:
    """Bounded buffer of Trace Event dicts; timestamps are microseconds on
    the ``time.perf_counter`` clock, zeroed at construction so traces start
    near t=0."""

    def __init__(self, max_events: int = 200_000):
        self.t0 = time.perf_counter()
        self.max_events = max_events
        self.dropped = 0
        self.events: list[dict] = []
        for tid, name in TRACK_NAMES.items():
            self._meta(ENGINE_PID, tid, name)
        self._meta_named = {ENGINE_PID}
        self.events.append({"name": "process_name", "ph": "M",
                            "pid": ENGINE_PID, "tid": 0,
                            "args": {"name": "engine"}})

    def _meta(self, pid: int, tid: int, name: str) -> None:
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def _push(self, ev: dict) -> bool:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return False
        self.events.append(ev)
        return True

    # ------------------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter()

    def slice(self, track: str, name: str, t_start: float, dur_s: float,
              args: dict | None = None) -> None:
        """Complete ('X') slice on an engine pipeline track."""
        ev = {"name": name, "ph": "X", "pid": ENGINE_PID,
              "tid": TRACKS[track], "ts": self._us(t_start),
              "dur": max(dur_s, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self._push(ev)

    def span(self, track: str, name: str, span_id: int, t_start: float,
             t_end: float, args: dict | None = None) -> None:
        """Async ('b'/'e') span — used for the inferred device-busy window,
        which OVERLAPS host slices (a complete event could not)."""
        b = {"name": name, "cat": track, "ph": "b", "id": span_id,
             "pid": ENGINE_PID, "tid": TRACKS[track],
             "ts": self._us(t_start)}
        if args:
            b["args"] = args
        if self._push(b):
            self._push({"name": name, "cat": track, "ph": "e", "id": span_id,
                        "pid": ENGINE_PID, "tid": TRACKS[track],
                        "ts": self._us(t_end)})

    def request_span(self, req_id: int, name: str, t_start: float,
                     t_end: float, args: dict | None = None) -> None:
        """Per-request lifecycle slice on the requests pid (one tid per
        request, so each request reads as its own timeline row)."""
        if REQUEST_PID not in self._meta_named:
            self.events.append({"name": "process_name", "ph": "M",
                                "pid": REQUEST_PID, "tid": 0,
                                "args": {"name": "requests"}})
            self._meta_named.add(REQUEST_PID)
        key = (REQUEST_PID, req_id)
        if key not in self._meta_named:
            self._meta(REQUEST_PID, req_id, f"req {req_id}")
            self._meta_named.add(key)
        ev = {"name": name, "ph": "X", "pid": REQUEST_PID, "tid": req_id,
              "ts": self._us(t_start),
              "dur": max(t_end - t_start, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, req_id: int, name: str, t: float) -> None:
        self._push({"name": name, "ph": "i", "s": "t", "pid": REQUEST_PID,
                    "tid": req_id, "ts": self._us(t)})

    # ------------------------------------------------------------------
    def to_doc(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str) -> int:
        """Write the JSON document; returns the event count."""
        with open(path, "w") as f:
            json.dump(self.to_doc(), f)
        return len(self.events)


# ---------------------------------------------------------------------------
# schema validation (tests + CI smoke)
# ---------------------------------------------------------------------------
_PHASES = {"X", "B", "E", "b", "e", "n", "i", "I", "M", "C", "s", "t", "f"}


def validate_trace(doc: dict) -> dict:
    """Validate a Trace Event JSON document the way Perfetto's importer
    would: traceEvents must be a list of dicts with name/ph/pid/tid, 'X'
    events need numeric ts+dur, async begin/end must pair up per id.
    Returns summary stats; raises ValueError on violations."""
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("trace: missing traceEvents list")
    tracks: set[tuple] = set()
    opens: dict[tuple, int] = {}
    n_slices = 0
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            raise ValueError(f"trace[{i}]: not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"trace[{i}]: missing {k!r}: {ev}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"trace[{i}]: unknown phase {ev['ph']!r}")
        if ev["ph"] == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            raise ValueError(f"trace[{i}]: bad ts: {ev}")
        tracks.add((ev["pid"], ev["tid"]))
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"trace[{i}]: 'X' without dur: {ev}")
            n_slices += 1
        elif ev["ph"] == "b":
            key = (ev.get("cat"), ev.get("id"))
            opens[key] = opens.get(key, 0) + 1
        elif ev["ph"] == "e":
            key = (ev.get("cat"), ev.get("id"))
            if opens.get(key, 0) <= 0:
                raise ValueError(f"trace[{i}]: 'e' without open 'b': {ev}")
            opens[key] -= 1
    dangling = {k: v for k, v in opens.items() if v}
    if dangling:
        raise ValueError(f"trace: unclosed async spans: {dangling}")
    return {"events": len(doc["traceEvents"]), "slices": n_slices,
            "tracks": sorted(tracks)}
