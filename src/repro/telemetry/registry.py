"""Metrics registry: counters / gauges / histograms behind one namespace.

The serving stack accumulates state in many places — ``EngineTiming``,
``SchedulerStats``, ``PageAllocator`` occupancy, ``CacheStats`` /
``TierStats``, the engine's speculative and rstate counters. The registry
unifies them behind ``repro_*`` metric names without moving any of them:

* **push instruments** (``Counter.inc`` / ``Gauge.set`` /
  ``Histogram.observe``) for values telemetry itself owns (per-request
  latency histograms, modeled PIM byte accounting);
* **pull bindings** (``bind``) for counters that already live in a
  subsystem: a zero-argument callable is read at scrape time, so the hot
  path pays nothing and the authoritative value stays where it always was.

``render()`` emits Prometheus text exposition format (served by
``telemetry.prom``); ``parse_exposition`` is the strict parser the tests
and the CI smoke use to validate it. A ``NullRegistry`` with the same API
backs disabled telemetry: every instrument is a shared no-op.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default latency buckets (seconds): 1ms .. ~100s, multiplicative
LATENCY_BUCKETS = tuple(0.001 * (10 ** (i / 4)) for i in range(21))


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats via repr."""
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_str(labels: dict[str, str] | None) -> str:
    if not labels:
        return ""
    parts = []
    for k in sorted(labels):
        v = str(labels[k]).replace("\\", r"\\").replace('"', r"\"") \
            .replace("\n", r"\n")
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}"


@dataclass
class Counter:
    name: str
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


@dataclass
class Gauge:
    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, v: float = 1.0) -> None:
        self.value += v


@dataclass
class Histogram:
    """Fixed-bucket histogram (cumulative ``le`` semantics on render)."""
    name: str
    buckets: tuple[float, ...] = LATENCY_BUCKETS
    counts: list[int] = field(default_factory=list)
    sum: float = 0.0
    count: int = 0

    def __post_init__(self):
        self.buckets = tuple(sorted(self.buckets))
        if not self.counts:
            self.counts = [0] * (len(self.buckets) + 1)  # +1 = +Inf

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class _NullInstrument:
    """Shared no-op instrument: disabled telemetry costs one attribute call."""
    __slots__ = ()

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    value = 0.0


_NULL = _NullInstrument()


class MetricsRegistry:
    """Instrument factory + scrape surface, one per engine."""

    enabled = True

    def __init__(self, namespace: str = "repro"):
        assert _NAME_RE.match(namespace), namespace
        self.ns = namespace
        # (name, labels-key) -> (kind, help, instrument-or-callable, labels)
        self._metrics: dict[tuple, tuple] = {}
        self._help: dict[str, str] = {}

    # ------------------------------------------------------------------
    def _key(self, name: str, labels: dict | None) -> tuple:
        return (name, tuple(sorted((labels or {}).items())))

    def _register(self, kind: str, name: str, help: str, obj,
                  labels: dict | None):
        name = f"{self.ns}_{name}"
        assert _NAME_RE.match(name), name
        for lk in (labels or {}):
            assert _LABEL_RE.match(lk), lk
        key = self._key(name, labels)
        if key in self._metrics:
            prev = self._metrics[key]
            assert prev[0] == kind, (name, prev[0], kind)
            return prev[2]
        assert self._help.setdefault(name, help) == help or True
        self._metrics[key] = (kind, help, obj, dict(labels or {}))
        return obj

    # ---- push instruments --------------------------------------------
    def counter(self, name: str, help: str = "",
                labels: dict | None = None) -> Counter:
        return self._register("counter", name, help, Counter(name), labels)

    def gauge(self, name: str, help: str = "",
              labels: dict | None = None) -> Gauge:
        return self._register("gauge", name, help, Gauge(name), labels)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = LATENCY_BUCKETS,
                  labels: dict | None = None) -> Histogram:
        return self._register("histogram", name, help,
                              Histogram(name, buckets), labels)

    # ---- pull bindings ------------------------------------------------
    def bind(self, name: str, fn, help: str = "", kind: str = "gauge",
             labels: dict | None = None) -> None:
        """Bind a zero-arg callable read at scrape time (``kind`` is the
        Prometheus type it is exposed as: counters that live in subsystem
        stats objects stay there; the registry just reads them)."""
        assert kind in ("counter", "gauge"), kind
        self._register(kind, name, help, fn, labels)

    # ------------------------------------------------------------------
    def collect(self) -> dict[str, float]:
        """Flat snapshot {sample_name+labels: value} — histograms contribute
        ``_sum`` and ``_count``. The tests' counter-exactness surface."""
        out: dict[str, float] = {}
        for (name, _), (kind, _h, obj, labels) in self._metrics.items():
            ls = _labels_str(labels)
            if kind == "histogram":
                out[f"{name}_sum{ls}"] = obj.sum
                out[f"{name}_count{ls}"] = obj.count
            elif callable(obj):
                out[f"{name}{ls}"] = float(obj())
            else:
                out[f"{name}{ls}"] = float(obj.value)
        return out

    def get(self, name: str, labels: dict | None = None) -> float:
        """One sample value by unprefixed name (tests / stats line)."""
        full = f"{self.ns}_{name}"
        kind, _h, obj, _l = self._metrics[self._key(full, labels)]
        if kind == "histogram":
            return float(obj.count)
        return float(obj() if callable(obj) else obj.value)

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        by_name: dict[str, list] = {}
        kinds: dict[str, str] = {}
        for (name, _), (kind, help, obj, labels) in self._metrics.items():
            by_name.setdefault(name, []).append((labels, obj))
            kinds[name] = kind
        lines: list[str] = []
        for name in sorted(by_name):
            kind = kinds[name]
            help = self._help.get(name, "")
            if help:
                esc = help.replace("\\", r"\\").replace("\n", r"\n")
                lines.append(f"# HELP {name} {esc}")
            lines.append(f"# TYPE {name} {kind}")
            for labels, obj in by_name[name]:
                ls = _labels_str(labels)
                if kind == "histogram":
                    cum = 0
                    for i, ub in enumerate(obj.buckets):
                        cum += obj.counts[i]
                        bl = dict(labels, le=_fmt(ub))
                        lines.append(f"{name}_bucket{_labels_str(bl)} {cum}")
                    cum += obj.counts[-1]
                    bl = dict(labels, le="+Inf")
                    lines.append(f"{name}_bucket{_labels_str(bl)} {cum}")
                    lines.append(f"{name}_sum{ls} {_fmt(obj.sum)}")
                    lines.append(f"{name}_count{ls} {obj.count}")
                else:
                    v = obj() if callable(obj) else obj.value
                    lines.append(f"{name}{ls} {_fmt(float(v))}")
        return "\n".join(lines) + "\n"


class NullRegistry(MetricsRegistry):
    """Same API, every instrument a shared no-op, renders empty."""

    enabled = False

    def __init__(self, namespace: str = "repro"):
        super().__init__(namespace)

    def counter(self, name, help="", labels=None):
        return _NULL

    def gauge(self, name, help="", labels=None):
        return _NULL

    def histogram(self, name, help="", buckets=LATENCY_BUCKETS, labels=None):
        return _NULL

    def bind(self, name, fn, help="", kind="gauge", labels=None):
        pass


# ---------------------------------------------------------------------------
# exposition-format validation (tests + CI smoke)
# ---------------------------------------------------------------------------
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?"
    r"\s+(?P<value>[^\s]+)(\s+\d+)?$")
_LABEL_PAIR_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')


def parse_exposition(text: str) -> dict[str, float]:
    """Strict-enough parser for Prometheus text format: every non-comment
    line must be ``name[{labels}] value``, every TYPE must be a known kind,
    histogram series must carry _bucket/_sum/_count. Returns
    {sample: value}; raises ValueError on malformed input."""
    samples: dict[str, float] = {}
    types: dict[str, str] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {ln}: bad TYPE line: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            if not line.startswith(("# HELP ", "# TYPE ")):
                raise ValueError(f"line {ln}: bad comment: {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: bad sample: {line!r}")
        if m.group("labels"):
            body = m.group("labels")[1:-1]
            if body:
                for pair in re.split(r",(?=[a-zA-Z_])", body):
                    if not _LABEL_PAIR_RE.match(pair.strip()):
                        raise ValueError(
                            f"line {ln}: bad label {pair!r}")
        v = m.group("value")
        if v not in ("+Inf", "-Inf", "NaN"):
            try:
                float(v)
            except ValueError:
                raise ValueError(f"line {ln}: bad value {v!r}") from None
        samples[m.group("name") + (m.group("labels") or "")] = (
            float("inf") if v == "+Inf" else
            float("-inf") if v == "-Inf" else
            float("nan") if v == "NaN" else float(v))
    # histogram series integrity
    for name, kind in types.items():
        if kind != "histogram":
            continue
        have = {s for s in samples if s.startswith(name)}
        for suffix in ("_bucket", "_sum", "_count"):
            if not any(s.startswith(name + suffix) for s in have):
                raise ValueError(f"histogram {name} missing {suffix} series")
    return samples
