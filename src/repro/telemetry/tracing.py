"""Per-request lifecycle tracing: queue -> admission -> prefill -> decode
-> (preempt/resume)* -> finish.

The engine and scheduler push timestamped events; the tracker folds them
into one ``RequestRecord`` per request, exported on finish (optionally as
JSONL) and summarized as percentiles. TTFT / TPOT / queue time are computed
from the same clock the engine's wall timings use, so the bench-reported
latencies and the telemetry records are one source of truth
(benchmarks/serving_bench.py reads its TTFT/TPOT straight from here).

The tracker implements the scheduler's ``events`` protocol (``on_admit`` /
``on_preempt`` / ``on_finish``) — the batcher calls it at the exact
bookkeeping points, no polling. All host-side; nothing here syncs the
device (token timestamps ride the horizon readback the engine already
pays for).
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass, field


@dataclass
class RequestRecord:
    req_id: int
    prompt_len: int = 0
    max_new_tokens: int = 0
    submit_t: float = 0.0
    admit_t: float | None = None        # first admission
    first_token_t: float | None = None
    last_token_t: float | None = None
    finish_t: float | None = None
    tokens: int = 0                     # emitted (prefill first + decode)
    preemptions: int = 0
    resumes: int = 0                    # re-admissions after preemption
    cached_tokens: int = 0              # prefix-cache KV reused at admission
    spec_proposed: int = 0
    spec_accepted: int = 0
    preempt_ts: list = field(default_factory=list)
    finished: bool = False
    # terminal-but-not-finished: torn down before a natural finish (client
    # abort / deadline / NaN quarantine / load shed); reason in abort_reason
    aborted: bool = False
    abort_reason: str | None = None
    # SLO identity, copied from the submission spec (serving.Request):
    # targets are what the client asked for; tenant/priority identify the
    # traffic class in per-tier goodput breakdowns
    priority: int = 0
    tenant: str | None = None
    ttft_slo_s: float | None = None
    tpot_slo_s: float | None = None

    # ---- derived latencies (seconds) ----------------------------------
    @property
    def queue_s(self) -> float | None:
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> float | None:
        return (None if self.first_token_t is None
                else self.first_token_t - self.submit_t)

    @property
    def tpot_s(self) -> float | None:
        """Mean time per output token after the first (decode cadence)."""
        if self.first_token_t is None or self.last_token_t is None \
                or self.tokens < 2:
            return None
        return (self.last_token_t - self.first_token_t) / (self.tokens - 1)

    @property
    def slo_ok(self) -> bool:
        """SLO attainment: the request finished AND met every target it
        declared (unset targets are vacuously met; a request too short to
        have a TPOT is judged on TTFT alone). Aborted/shed requests never
        attain — goodput counts work the client actually got in time."""
        if not self.finished:
            return False
        if self.ttft_slo_s is not None and (
                self.ttft_s is None or self.ttft_s > self.ttft_slo_s):
            return False
        if self.tpot_slo_s is not None and self.tpot_s is not None \
                and self.tpot_s > self.tpot_slo_s:
            return False
        return True

    @property
    def accept_len_mean(self) -> float | None:
        rounds = getattr(self, "_spec_rounds", 0)
        if not rounds:
            return None
        return 1.0 + self.spec_accepted / rounds

    def as_dict(self) -> dict:
        d = asdict(self)
        d["queue_s"] = self.queue_s
        d["ttft_s"] = self.ttft_s
        d["tpot_s"] = self.tpot_s
        d["slo_ok"] = self.slo_ok
        d["spec_rounds"] = getattr(self, "_spec_rounds", 0)
        return d


def percentile(vals: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not vals:
        return 0.0
    vs = sorted(vals)
    k = max(0, min(len(vs) - 1, int(round(q / 100.0 * (len(vs) - 1)))))
    return vs[k]


class RequestTracker:
    """Folds engine/scheduler events into per-request records."""

    def __init__(self, registry=None, trace=None, log_path: str | None = None,
                 clock=None):
        self.live: dict[int, RequestRecord] = {}
        self.records: list[RequestRecord] = []
        self.trace = trace
        # injectable time source (the telemetry facade rebinds this to the
        # engine's clock at attach, so record timestamps live in the same
        # frame as the engine's deadlines — virtual or wall)
        self.clock = clock or time.perf_counter
        self._log = open(log_path, "w") if log_path else None
        r = registry
        if r is not None and r.enabled:
            self.h_ttft = r.histogram(
                "request_ttft_seconds", "submit -> first emitted token")
            self.h_tpot = r.histogram(
                "request_tpot_seconds", "mean inter-token time after the "
                "first token")
            self.h_queue = r.histogram(
                "request_queue_seconds", "submit -> first admission")
            self.c_finished = r.counter(
                "requests_finished_total", "requests run to completion")
            self.c_aborted = r.counter(
                "requests_aborted_total", "requests torn down before a "
                "natural finish (abort / deadline / quarantine / shed)")
            self.c_tokens = r.counter(
                "request_tokens_total", "tokens emitted across all requests")
            self.c_slo = r.counter(
                "requests_slo_attained_total", "finished requests that met "
                "every SLO target they declared")
            r.bind("requests_live", lambda: len(self.live),
                   "submitted requests not yet finished")
            r.bind("goodput", lambda: self.goodput(),
                   "fraction of closed requests that finished within their "
                   "SLO targets")
        else:
            from repro.telemetry.registry import _NULL
            self.h_ttft = self.h_tpot = self.h_queue = _NULL
            self.c_finished = self.c_tokens = self.c_aborted = _NULL
            self.c_slo = _NULL

    # ---- engine-side events -------------------------------------------
    def on_submit(self, req_id: int, prompt_len: int, max_new: int,
                  t: float | None = None, spec=None) -> None:
        rec = self.live[req_id] = RequestRecord(
            req_id, prompt_len, max_new,
            submit_t=self.clock() if t is None else t)
        if spec is not None:
            rec.priority = getattr(spec, "priority", 0)
            rec.tenant = getattr(spec, "tenant", None)
            rec.ttft_slo_s = getattr(spec, "ttft_slo_s", None)
            rec.tpot_slo_s = getattr(spec, "tpot_slo_s", None)

    def on_first_token(self, req_id: int, t: float) -> None:
        rec = self.live.get(req_id)
        if rec is not None and rec.first_token_t is None:
            rec.first_token_t = t

    def on_tokens(self, req_id: int, n: int, t: float) -> None:
        rec = self.live.get(req_id)
        if rec is None or n <= 0:
            return
        rec.tokens += n
        rec.last_token_t = t
        if rec.first_token_t is None:
            rec.first_token_t = t
        self.c_tokens.inc(n)

    def on_spec(self, req_id: int, proposed: int, accepted: int) -> None:
        rec = self.live.get(req_id)
        if rec is None:
            return
        rec.spec_proposed += proposed
        rec.spec_accepted += accepted
        rec._spec_rounds = getattr(rec, "_spec_rounds", 0) + 1

    # ---- scheduler ``events`` protocol --------------------------------
    def on_admit(self, req, slot: int) -> None:
        rec = self.live.get(req.req_id)
        if rec is None:
            return
        t = self.clock()
        if rec.admit_t is None:
            rec.admit_t = t
        else:
            rec.resumes += 1
        rec.cached_tokens += int(getattr(req, "cached_len", 0))

    def on_preempt(self, req, slot: int) -> None:
        rec = self.live.get(req.req_id)
        if rec is None:
            return
        t = self.clock()
        rec.preemptions += 1
        rec.preempt_ts.append(t)
        if self.trace is not None:
            self.trace.instant(req.req_id, "preempt", t)

    def on_abort(self, req, slot: int, reason: str = "abort") -> None:
        """Terminal teardown without a natural finish (scheduler
        ``abort_slot`` / ``abort_queued``, engine shed). The record is
        closed and exported like a finish — aborted requests must appear in
        the JSONL log and summaries, not vanish — but flagged ``aborted``
        and excluded from the finished counter."""
        rec = self.live.pop(req.req_id, None)
        if rec is None:
            return
        t = self.clock()
        rec.aborted = True
        rec.abort_reason = reason
        rec.finish_t = t
        self.records.append(rec)
        self.c_aborted.inc()
        if self.trace is not None:
            self.trace.instant(req.req_id, f"abort:{reason}", t)
        if self._log is not None:
            self._log.write(json.dumps(rec.as_dict()) + "\n")
            self._log.flush()

    def on_finish(self, req, slot: int) -> None:
        rec = self.live.pop(req.req_id, None)
        if rec is None:
            return
        rec.finished = True
        rec.finish_t = rec.last_token_t or self.clock()
        self.records.append(rec)
        self.c_finished.inc()
        if rec.slo_ok:
            self.c_slo.inc()
        if rec.ttft_s is not None:
            self.h_ttft.observe(rec.ttft_s)
        if rec.tpot_s is not None:
            self.h_tpot.observe(rec.tpot_s)
        if rec.queue_s is not None:
            self.h_queue.observe(rec.queue_s)
        if self.trace is not None and rec.admit_t is not None:
            self.trace.request_span(rec.req_id, "queue", rec.submit_t,
                                    rec.admit_t)
            if rec.first_token_t is not None:
                self.trace.request_span(
                    rec.req_id, "prefill", rec.admit_t, rec.first_token_t,
                    args={"prompt_len": rec.prompt_len,
                          "cached_tokens": rec.cached_tokens})
                self.trace.request_span(
                    rec.req_id, "decode", rec.first_token_t, rec.finish_t,
                    args={"tokens": rec.tokens,
                          "preemptions": rec.preemptions})
        if self._log is not None:
            self._log.write(json.dumps(rec.as_dict()) + "\n")
            self._log.flush()

    # -------------------------------------------------------------------
    def goodput(self) -> float:
        """SLO attainment over closed (finished + aborted) records: the
        fraction that finished within every target they declared. Aborted
        and shed requests count against goodput — work the client never
        got, or got too late, is not good throughput. 0.0 before any
        request closes."""
        if not self.records:
            return 0.0
        return sum(1 for r in self.records if r.slo_ok) / len(self.records)

    def summary(self) -> dict:
        """Percentile summary over finished records (seconds -> ms).
        Aborted records are counted but excluded from the latency
        percentiles — a request torn down mid-stream has no meaningful
        TPOT, and including partial TTFTs would skew the SLO numbers a
        no-abort run reports."""
        recs = [r for r in self.records if r.finished]
        ttft = [r.ttft_s for r in recs if r.ttft_s is not None]
        tpot = [r.tpot_s for r in recs if r.tpot_s is not None]
        queue = [r.queue_s for r in recs if r.queue_s is not None]
        out = {"finished": len(recs),
               "aborted": sum(1 for r in self.records if r.aborted),
               "preemptions": sum(r.preemptions for r in recs),
               "tokens": sum(r.tokens for r in recs),
               "slo_attained": sum(1 for r in self.records if r.slo_ok),
               "goodput": self.goodput()}
        for name, vals in (("ttft", ttft), ("tpot", tpot), ("queue", queue)):
            if not vals:
                continue
            out[f"{name}_mean_ms"] = 1e3 * sum(vals) / len(vals)
            for q in (50, 90, 99):
                out[f"{name}_p{q}_ms"] = 1e3 * percentile(vals, q)
        return out

    def close(self) -> None:
        if self._log is not None:
            self._log.close()
            self._log = None
