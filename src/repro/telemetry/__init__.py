"""Unified telemetry for the serving stack.

One facade object (``Telemetry``) wires the four pieces together and is
threaded through the engine as ``EngineConfig.telemetry``:

* ``registry``  — metrics registry (``telemetry.registry``): push
  instruments + pull bindings over the subsystems' existing counters,
  rendered as Prometheus text (served by ``telemetry.prom``);
* ``tracker``   — per-request lifecycle records (``telemetry.tracing``):
  TTFT / TPOT / queue time / preemptions / spec accepts, percentile
  summaries, optional JSONL export;
* ``trace``     — Chrome-trace/Perfetto tick timeline
  (``telemetry.chrome_trace``): host / prefill / dispatch / sync tracks
  plus the inferred device span, so DCS overlap is visible per tick;
* ``pim``       — live PIM counters (``telemetry.pim_counters``): modeled
  HBM bytes/token, DPA occupancy/waste, pow2-bucket high-water, channel
  utilization.

Disabled telemetry is the shared ``NULL`` singleton: ``enabled`` is False,
every event method is a bound no-op, the scheduler's ``events`` hook stays
unset and no binding, span or counter exists — the engine's behavior and
device-sync count are bit-identical to a build without telemetry (tested).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.telemetry.chrome_trace import TraceWriter, validate_trace
from repro.telemetry.pim_counters import PIMCounters
from repro.telemetry.registry import (LATENCY_BUCKETS, MetricsRegistry,
                                      NullRegistry, parse_exposition)
from repro.telemetry.tracing import RequestRecord, RequestTracker, percentile

__all__ = [
    "TelemetryConfig", "Telemetry", "make_telemetry", "NULL",
    "MetricsRegistry", "NullRegistry", "parse_exposition", "LATENCY_BUCKETS",
    "TraceWriter", "validate_trace", "RequestTracker", "RequestRecord",
    "PIMCounters", "percentile",
]


@dataclass
class TelemetryConfig:
    metrics: bool = True              # registry + bindings + PIM counters
    trace: bool = False               # Perfetto tick timeline
    trace_path: str | None = None     # implies trace when set
    request_log: str | None = None    # JSONL per-request record export
    namespace: str = "repro"
    trace_max_events: int = 200_000
    pim_bytes_per_el: int = 2         # KV element width the PIM model uses


class Telemetry:
    """Live telemetry facade (see module docstring). Construct via
    ``make_telemetry`` so disabled configs collapse to the NULL no-op."""

    enabled = True

    def __init__(self, cfg: TelemetryConfig):
        self.cfg = cfg
        self.registry = (MetricsRegistry(cfg.namespace) if cfg.metrics
                         else NullRegistry(cfg.namespace))
        self.trace = (TraceWriter(cfg.trace_max_events)
                      if (cfg.trace or cfg.trace_path) else None)
        self.tracker = RequestTracker(self.registry, self.trace,
                                      cfg.request_log)
        self.pim: PIMCounters | None = None
        self._kv_bpt = 0.0

    # ------------------------------------------------------------------
    def attach_engine(self, engine) -> None:
        """Bind the engine's existing counters into the registry, build the
        PIM counters over its scheduler/allocator, and install the tracker
        as the scheduler's events hook. Called once from DecodeEngine
        construction; everything here is a pull binding — no hot-path cost,
        no device access."""
        engine.batcher.events = self.tracker
        # record timestamps must live in the engine's clock frame (virtual
        # clocks included), not the wall clock the tracker defaults to
        self.tracker.clock = engine.clock
        r = self.registry
        t = engine.timing
        r.bind("engine_steps_total", lambda: t.steps,
               "serving ticks run", kind="counter")
        r.bind("engine_device_syncs_total", lambda: t.device_syncs,
               "host<->device decode rendezvous", kind="counter")
        r.bind("engine_decode_tokens_total", lambda: t.decode_tokens,
               "tokens emitted by decode dispatches", kind="counter")
        r.bind("engine_host_seconds_total", lambda: t.host_s,
               "host scheduling + config-buffer assembly time",
               kind="counter")
        r.bind("engine_prefill_seconds_total", lambda: t.prefill_s,
               "prefill wall time", kind="counter")
        r.bind("engine_decode_seconds_total", lambda: t.decode_s,
               "decode dispatch + sync wall time", kind="counter")
        b = engine.batcher
        s = b.stats
        r.bind("sched_admitted_total", lambda: s.admitted,
               "requests admitted to slots", kind="counter")
        r.bind("sched_preempted_total", lambda: s.preempted,
               "requests preempted (pool exhausted)", kind="counter")
        r.bind("sched_priority_preempted_total",
               lambda: s.priority_preempted,
               "policy-driven preemptions for starved higher-priority "
               "requests (subset of sched_preempted_total)", kind="counter")
        r.bind("sched_completed_total", lambda: s.completed,
               "requests completed (EOS / budget)", kind="counter")
        r.bind("sched_dedup_deferred_total", lambda: s.dedup_deferred,
               "admissions deferred behind an in-flight same-prefix "
               "prefill", kind="counter")
        r.bind("sched_queue_depth", lambda: len(b.queue),
               "requests waiting for a slot")
        r.bind("sched_active_slots",
               lambda: sum(1 for x in b.slots if x is not None),
               "slots holding a request")
        r.bind("rstate_snapshots_total", lambda: engine.rstate_snapshots,
               "recurrent-state preemption snapshots taken", kind="counter")
        r.bind("rstate_restores_total", lambda: engine.rstate_restores,
               "recurrent-state snapshot restores", kind="counter")
        # ---- robustness namespace (PR 8): aborts / faults / degradation
        r.bind("sched_aborted_total", lambda: s.aborted,
               "requests torn down before a natural finish", kind="counter")
        r.bind("sched_migrated_total", lambda: s.migrated,
               "requests drained off dead rows into re-queued prefills",
               kind="counter")
        ab = engine.abort_counts
        for reason in ("client", "deadline", "nan", "shed", "chaos",
                       "handoff", "stale"):
            r.bind("aborts_total", lambda rr=reason: ab.get(rr, 0),
                   "terminal teardowns by reason", kind="counter",
                   labels={"reason": reason})
        r.bind("degraded_mode", lambda: engine.degraded_mode,
               "sticky degradation bitmask (1=horizon->1, 2=spec off, "
               "4=host tier dropped)")
        r.bind("engine_snapshot_saves_total",
               lambda: engine.snapshot_saves,
               "crash-consistent serving snapshots written", kind="counter")
        r.bind("engine_snapshot_restores_total",
               lambda: engine.snapshot_restores,
               "engine starts restored from a serving snapshot",
               kind="counter")
        r.bind("engine_snapshot_rejects_total",
               lambda: engine.snapshot_rejects,
               "torn/corrupt snapshot steps rejected before restore",
               kind="counter")
        if engine.faults.enabled:
            fc = engine.faults.counts
            r.bind("faults_injected_total",
                   lambda: engine.faults.total_fired,
                   "injected faults across all kinds", kind="counter")
            from repro.runtime.faults import KINDS
            for kind in KINDS:
                r.bind("faults_total", lambda kk=kind: fc.get(kk, 0),
                       "injected faults by kind", kind="counter",
                       labels={"kind": kind})
        if engine.draft_cfg is not None:
            r.bind("spec_rounds_total", lambda: engine.spec_rounds,
                   "speculative verify passes", kind="counter")
            r.bind("spec_proposed_total", lambda: engine.spec_proposed,
                   "draft tokens proposed", kind="counter")
            r.bind("spec_accepted_total", lambda: engine.spec_accepted,
                   "draft tokens accepted", kind="counter")
        if r.enabled:
            self.pim = PIMCounters(r, engine.cfg, engine.batcher,
                                   bytes_per_el=self.cfg.pim_bytes_per_el)
            self._kv_bpt = self.pim.kv_bytes_per_token()

    # ---- engine-driven events (cheap host arithmetic only) ------------
    def on_submit(self, req_id: int, prompt_len: int, max_new: int,
                  t: float | None = None, spec=None) -> None:
        self.tracker.on_submit(req_id, prompt_len, max_new, t, spec=spec)

    def on_tokens(self, req_id: int, n: int, t: float) -> None:
        self.tracker.on_tokens(req_id, n, t)

    def on_spec(self, req_id: int, proposed: int, accepted: int) -> None:
        self.tracker.on_spec(req_id, proposed, accepted)

    def on_abort(self, req, slot: int, reason: str) -> None:
        """Engine-side terminal teardown (load shed happens before the
        scheduler ever sees the request, so the batcher's events hook
        can't cover it)."""
        self.tracker.on_abort(req, slot, reason)

    def on_horizon(self, token_ctx_sum: float) -> None:
        """One collected horizon: ``token_ctx_sum`` = sum over emitted
        tokens of the emitting slot's dispatch-time context length."""
        if self.pim is not None:
            self.pim.on_horizon(token_ctx_sum * self._kv_bpt)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        return self.tracker.summary()

    def stats_line(self) -> str:
        """One-line periodic stats (serve.py's --stats-every)."""
        sm = self.summary()
        parts = [f"reqs={sm['finished']}", f"tokens={sm['tokens']}"]
        if "ttft_p50_ms" in sm:
            parts.append(f"ttft_p50={sm['ttft_p50_ms']:.1f}ms")
        if "tpot_p50_ms" in sm:
            parts.append(f"tpot_p50={sm['tpot_p50_ms']:.2f}ms")
        if self.registry.enabled:
            g = self.registry.get
            try:
                parts.append(f"pages={g('kv_pages_in_use', {'tier': 'device'}):.0f}")
                parts.append(f"chan_util={g('pim_channel_util'):.2f}")
            except KeyError:
                pass
        return "telemetry: " + " ".join(parts)

    def save_trace(self, path: str | None = None) -> int | None:
        if self.trace is None:
            return None
        return self.trace.save(path or self.cfg.trace_path)

    def close(self) -> None:
        self.tracker.close()


class _NullTelemetry:
    """Shared disabled singleton: same surface, every method a no-op, no
    registry entries, no scheduler events hook, no trace."""

    enabled = False
    trace = None
    tracker = None
    pim = None

    def __init__(self):
        self.cfg = TelemetryConfig(metrics=False)
        self.registry = NullRegistry()

    def attach_engine(self, engine) -> None:
        pass

    def on_submit(self, req_id, prompt_len, max_new, t=None,
                  spec=None) -> None:
        pass

    def on_tokens(self, req_id, n, t) -> None:
        pass

    def on_spec(self, req_id, proposed, accepted) -> None:
        pass

    def on_abort(self, req, slot, reason) -> None:
        pass

    def on_horizon(self, token_ctx_sum) -> None:
        pass

    def summary(self) -> dict:
        return {}

    def stats_line(self) -> str:
        return "telemetry: disabled"

    def save_trace(self, path=None):
        return None

    def close(self) -> None:
        pass


NULL = _NullTelemetry()


def make_telemetry(cfg) -> "Telemetry | _NullTelemetry":
    """None / falsy -> the shared no-op; an existing facade passes through
    (serve.py builds one and hands it to the engine); a config builds a
    live facade unless everything in it is off."""
    if cfg is None or cfg is False:
        return NULL
    if isinstance(cfg, (Telemetry, _NullTelemetry)):
        return cfg
    if isinstance(cfg, TelemetryConfig):
        if not (cfg.metrics or cfg.trace or cfg.trace_path
                or cfg.request_log):
            return NULL
        return Telemetry(cfg)
    if cfg is True:
        return Telemetry(TelemetryConfig())
    raise TypeError(f"telemetry: expected TelemetryConfig/bool/None, "
                    f"got {type(cfg).__name__}")
