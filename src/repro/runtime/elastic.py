"""Elastic scaling & straggler mitigation (host-level planning logic).

At 1000+ nodes, failures are routine; the runtime must (a) keep serving /
training with the survivors and (b) not let one slow node gate the fleet.
This module contains the *planning* logic — pure, unit-tested functions the
launcher consults; actual process orchestration is the cluster manager's job.

Policies (DESIGN.md §4):

* ``plan_remesh`` — shrink the ``data`` axis first (DP rows are stateless
  replicas in serving; in training their optimizer shards re-gather from the
  checkpoint), keep the ``model`` axis intact (TP shards are stateful and
  resharding them mid-flight costs a full weight reshuffle). A pod that
  loses any chip beyond the data-axis slack drops out whole (PP stage
  granularity).
* ``plan_request_migration`` — serving rows own their requests (row-affine
  pages); when a row dies its in-flight requests are re-queued for prefill
  on surviving rows (KV pages are lost — recompute, the standard trade).
* ``StragglerPolicy`` — EMA of per-row step times; rows slower than
  ``factor``x the fleet median get their decode batch share shrunk
  (scheduler admits fewer requests to those rows), the continuous-batching
  equivalent of backup tasks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class MeshPlan:
    pods: int
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.pods * self.data * self.model


def plan_remesh(current: MeshPlan, failed_devices: list[int]) -> MeshPlan:
    """New mesh after failures. Device ids are row-major (pod, data, model).

    Keeps the model axis; drops whole data rows containing failures; drops a
    pod entirely if fewer than half its rows survive (PP stages need symmetric
    capacity across pods).
    """
    failed = set(failed_devices)
    rows_per_pod = current.data
    surviving_rows = []
    for p in range(current.pods):
        rows = 0
        for d in range(current.data):
            base = (p * current.data + d) * current.model
            if not any(base + m in failed for m in range(current.model)):
                rows += 1
        surviving_rows.append(rows)
    # symmetric row count across surviving pods
    pods = [p for p, r in enumerate(surviving_rows)
            if r >= max(1, rows_per_pod // 2)]
    if not pods:
        raise RuntimeError("no pod has enough surviving rows")
    data = min(surviving_rows[p] for p in pods)
    return MeshPlan(pods=len(pods), data=data, model=current.model)


def plan_request_migration(row_of_request: dict[int, int],
                           dead_rows: set[int]) -> list[int]:
    """Requests to re-queue (their row died; pages lost -> re-prefill)."""
    return sorted(r for r, row in row_of_request.items() if row in dead_rows)


def plan_role_collapse(roles: dict[int, str],
                       healthy: set[int]) -> dict[int, str] | None:
    """Sticky degradation planning for the disaggregated engine cluster
    (``serving/cluster.py``): when either the prefill or the decode role
    has no healthy member left, every surviving engine collapses to the
    colocated ``both`` role — the cluster keeps serving as a (possibly
    single-engine) colocated pool instead of wedging on a missing stage.

    Returns the new role map over the healthy engines, or None when both
    roles are still covered (no change needed). An empty map means nothing
    survived — the cluster must go terminal."""
    def covered(role: str) -> bool:
        return any(ix in healthy and r in (role, "both")
                   for ix, r in roles.items())
    if covered("prefill") and covered("decode"):
        return None
    return {ix: "both" for ix in roles if ix in healthy}


@dataclass
class StragglerPolicy:
    n_rows: int
    factor: float = 1.5       # slower than factor x median => straggler
    alpha: float = 0.2        # EMA coefficient
    min_share: float = 0.25   # never shrink a row below this batch share
    ema: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.ema is None:
            self.ema = np.zeros(self.n_rows)

    def observe(self, row_step_times: np.ndarray) -> None:
        t = np.asarray(row_step_times, np.float64)
        self.ema = np.where(self.ema == 0, t,
                            (1 - self.alpha) * self.ema + self.alpha * t)

    def shares(self) -> np.ndarray:
        """Per-row decode batch share in (min_share, 1]."""
        if not self.ema.any():
            return np.ones(self.n_rows)
        med = np.median(self.ema[self.ema > 0])
        ratio = np.where(self.ema > 0, self.ema / max(med, 1e-9), 1.0)
        share = np.clip(self.factor / np.maximum(ratio, self.factor),
                        self.min_share, 1.0)
        return share

    def stragglers(self) -> list[int]:
        med = np.median(self.ema[self.ema > 0]) if self.ema.any() else 0
        return [i for i, t in enumerate(self.ema)
                if med and t > self.factor * med]
