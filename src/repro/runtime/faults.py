"""Deterministic, seeded fault injection for the serving engine.

Chaos testing only works if a failing run can be REPLAYED: every injection
decision here is a pure function of ``(seed, kind, tick, key)`` — a
splitmix-style integer hash, no RNG state, no call-order dependence — so
the same seed over the same trace fires the same faults at the same ticks
no matter how subsystems interleave their ``fire()`` calls. The injector
records every fired event (the chaos soak's uploaded artifact) and counts
per kind (telemetry's ``faults_*`` namespace reads them as pull bindings).

Fault kinds and where the engine wires them (see docs/robustness.md):

* ``alloc_exhaust`` — a request's per-tick page growth behaves as if the
  pool were dry: the scheduler preempts it (the real ``MemoryError`` path).
* ``swap_fail``    — a host-tier swap-in refuses; the radix match truncates
  at the last materializable node (prefill covers the rest).
* ``swap_stall``   — the cache's once-per-tick ``maintain()`` is skipped
  (the ping-pong drain stalls one tick).
* ``row_death``    — a serving row dies; its requests' KV is lost and they
  are drained into re-queued prefills via
  ``elastic.plan_request_migration``.
* ``nan_logits``   — a slot's collected horizon is treated as invalid
  (the NaN/garbage-logits case): the request is quarantined and terminal.
* ``slow_tick``    — a straggler tick: the host loop sleeps
  ``slow_tick_s`` (exercises watchdogs and overlap accounting).
* ``client_abort`` — a live request receives a client abort (the seeded
  stand-in for a user hanging up mid-stream).

Cluster-level kinds (fired by ``serving/cluster.py``'s router, not by an
engine — the cluster runs its own injector clock):

* ``engine_death``    — a pool engine dies at the tick boundary; its
  in-flight requests are re-routed (cold quiescent-frame re-prefill) or
  restored warm from the engine's last serving snapshot.
* ``handoff_torn``    — a cross-engine KV handoff is truncated in flight;
  the byte-stream length check rejects it and the router retries.
* ``handoff_corrupt`` — one byte of a handoff transfer flips; the manifest
  checksum rejects it before anything is applied.

Disabled fault injection is the shared ``NULL_FAULTS`` singleton:
``enabled`` is False and every ``fire()`` short-circuits — the engine's
outputs and device-sync count are bit-identical to a build without the
subsystem (tested), mirroring the telemetry NULL facade.
"""
from __future__ import annotations

from dataclasses import dataclass

KINDS = ("alloc_exhaust", "swap_fail", "swap_stall", "row_death",
         "nan_logits", "slow_tick", "client_abort",
         "engine_death", "handoff_torn", "handoff_corrupt")

_MASK = (1 << 64) - 1


def _hash01(seed: int, kind_ix: int, tick: int, key: int) -> float:
    """Uniform [0, 1) from the decision coordinates (splitmix64-style
    finalizer) — replayable regardless of call order."""
    h = (seed * 0x9E3779B97F4A7C15 + 0xD1B54A32D192ED03) & _MASK
    for v in (kind_ix + 1, tick + 1, key + 1):
        h = (h ^ (v & _MASK)) * 0xBF58476D1CE4E5B9 & _MASK
        h ^= h >> 31
    h = (h * 0x94D049BB133111EB) & _MASK
    h ^= h >> 29
    return (h >> 11) / float(1 << 53)


@dataclass
class FaultConfig:
    """Seeded fault plan. Probabilities are per decision point per tick
    (a kind whose probability is 0 never fires — an all-zero config is a
    live injector that never injects, useful for no-op identity tests)."""
    seed: int = 0
    alloc_exhaust_p: float = 0.0      # per (tick, growing request)
    swap_fail_p: float = 0.0          # per (tick, swap-in attempt)
    swap_stall_p: float = 0.0         # per tick
    row_death_p: float = 0.0          # per (tick, serving row)
    nan_logits_p: float = 0.0         # per (tick, collected slot)
    slow_tick_p: float = 0.0          # per tick
    slow_tick_s: float = 0.002        # straggler sleep when it fires
    client_abort_p: float = 0.0       # per (tick, live request)
    engine_death_p: float = 0.0       # per (tick, pool engine)
    handoff_torn_p: float = 0.0       # per (tick, handoff transmission)
    handoff_corrupt_p: float = 0.0    # per (tick, handoff transmission)
    start_tick: int = 0               # no injections before this tick
    max_faults: int = 0               # total fire budget (0 = unbounded)


class FaultInjector:
    """Live injector over a ``FaultConfig`` (see module docstring)."""

    enabled = True

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.tick = 0
        self.counts: dict[str, int] = {k: 0 for k in KINDS}
        self.total_fired = 0
        # fired-event log: the chaos soak's replay/debug artifact
        self.events: list[dict] = []
        self._p = {k: float(getattr(cfg, f"{k}_p")) for k in KINDS}
        self._ix = {k: i for i, k in enumerate(KINDS)}

    def on_tick(self) -> None:
        """Advance the injection clock — called once per engine tick."""
        self.tick += 1

    def fire(self, kind: str, key: int = 0) -> bool:
        """Deterministic injection decision for ``kind`` at the current
        tick, disambiguated by ``key`` (request id, row id, lookup count —
        anything deterministic across replays)."""
        p = self._p[kind]
        if p <= 0.0 or self.tick < self.cfg.start_tick:
            return False
        if self.cfg.max_faults and self.total_fired >= self.cfg.max_faults:
            return False
        if _hash01(self.cfg.seed, self._ix[kind], self.tick, int(key)) >= p:
            return False
        self.counts[kind] += 1
        self.total_fired += 1
        self.events.append({"kind": kind, "tick": self.tick,
                            "key": int(key)})
        return True


class _NullFaults:
    """Shared disabled singleton: ``fire`` always declines, counters stay
    empty, ``on_tick`` is a no-op — zero work on the hot path."""

    enabled = False
    tick = 0
    total_fired = 0
    counts: dict[str, int] = {}
    events: list = []

    def on_tick(self) -> None:
        pass

    def fire(self, kind: str, key: int = 0) -> bool:
        return False


NULL_FAULTS = _NullFaults()


def make_faults(cfg) -> "FaultInjector | _NullFaults":
    """None/False -> the shared no-op; an injector passes through (so a
    driver can hand the same plan to several engines and read one event
    log); a ``FaultConfig`` builds a live injector."""
    if cfg is None or cfg is False:
        return NULL_FAULTS
    if isinstance(cfg, (FaultInjector, _NullFaults)):
        return cfg
    if isinstance(cfg, FaultConfig):
        return FaultInjector(cfg)
    raise TypeError(f"faults: expected FaultConfig/FaultInjector/None, "
                    f"got {type(cfg).__name__}")
