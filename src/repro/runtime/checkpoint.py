"""Sharded, crash-safe checkpointing (no external deps).

Layout: ``<dir>/step_<N>/`` holding one ``.npz`` per (host) shard plus a
``manifest.json`` written LAST — a step directory without a manifest is
incomplete and ignored on restore, so a crash mid-save can never corrupt
resume (atomic-rename-free but manifest-gated). ``restore_latest`` picks the
newest complete step; shards are keyed by flattened tree path so a restart
on a DIFFERENT topology re-shards on load (the arrays are saved unsharded
per host slice and re-committed to the new mesh by the caller's
``jax.device_put`` with the new sharding).

Fault-tolerance contract (runtime/elastic.py): checkpoint every N steps;
on any node failure the job restarts from the last complete step with a
(possibly smaller) mesh and an identical data stream (data/pipeline.py is
seeded per step).

The manifest additionally records a crc32 per flattened array, so a step
whose payload was corrupted *after* the commit point (bit rot, a torn
copy) is detected by ``verify_step`` and skipped by callers that walk
``valid_steps`` newest-to-oldest — restore degrades to an older step (or
to a cold start) instead of applying garbage. ``kvcache/handoff.py``
reuses ``array_crc`` for its transfer manifests.
"""
from __future__ import annotations

import json
import shutil
import time
import zlib
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def array_crc(arr: np.ndarray) -> int:
    """crc32 over an array's bytes + dtype + shape (a reshaped or recast
    payload with identical bytes still fails verification)."""
    arr = np.ascontiguousarray(arr)
    h = zlib.crc32(arr.tobytes())
    h = zlib.crc32(str(arr.dtype).encode(), h)
    return zlib.crc32(repr(arr.shape).encode(), h)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":        # ml_dtypes (bf16): store as f32
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        items[key] = arr
    return items, treedef


def save(ckpt_dir, step: int, tree, *, host_id: int = 0,
         extra: dict | None = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:08d}"
    step_dir.mkdir(parents=True, exist_ok=True)
    items, _ = _flatten(tree)
    np.savez(step_dir / f"shard_{host_id:05d}.npz", **items)
    if host_id == 0:
        manifest = {"step": step, "time": time.time(),
                    "n_arrays": len(items), "extra": extra or {},
                    "crc": {k: array_crc(v) for k, v in items.items()}}
        # manifest written last = commit point
        (step_dir / "manifest.json").write_text(json.dumps(manifest))
        _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "manifest.json").exists())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "manifest.json").exists())
    return int(steps[-1].name.split("_")[1]) if steps else None


def valid_steps(ckpt_dir) -> list[int]:
    """Steps with a *parseable* manifest, oldest first. A truncated or
    garbled manifest.json (crash or corruption mid-write) disqualifies the
    step — it never reached its commit point."""
    out = []
    for d in sorted(Path(ckpt_dir).glob("step_*")):
        mf = d / "manifest.json"
        if not mf.exists():
            continue
        try:
            json.loads(mf.read_text())
        except (OSError, ValueError):
            continue
        out.append(int(d.name.split("_")[1]))
    return out


def verify_step(ckpt_dir, step: int, *, host_id: int = 0) -> bool:
    """Full payload validation for one step: manifest parses, the shard
    loads, and every manifest-listed array is present with a matching
    crc32. Pre-checksum manifests (no ``crc`` key) only get the
    load/presence checks. Never raises — any failure is False."""
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    try:
        manifest = json.loads((step_dir / "manifest.json").read_text())
        with np.load(step_dir / f"shard_{host_id:05d}.npz") as data:
            crcs = manifest.get("crc")
            keys = crcs if crcs is not None else data.files
            if len(data.files) != int(manifest.get("n_arrays",
                                                   len(data.files))):
                return False
            for key in keys:
                arr = data[key]                 # KeyError/zlib error = bad
                if crcs is not None and array_crc(arr) != int(crcs[key]):
                    return False
    except Exception:
        return False
    return True


def restore(ckpt_dir, step: int, like, *, host_id: int = 0):
    """Restore into the structure of ``like`` (a pytree or SDS tree)."""
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(step_dir / f"shard_{host_id:05d}.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            # jnp handles ml_dtypes (bf16) casts that numpy cannot
            out.append(jnp.asarray(arr).astype(leaf.dtype))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(ckpt_dir, like, *, host_id: int = 0):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, like, host_id=host_id)
