"""Sharded, crash-safe checkpointing (no external deps).

Layout: ``<dir>/step_<N>/`` holding one ``.npz`` per (host) shard plus a
``manifest.json`` written LAST — a step directory without a manifest is
incomplete and ignored on restore, so a crash mid-save can never corrupt
resume (atomic-rename-free but manifest-gated). ``restore_latest`` picks the
newest complete step; shards are keyed by flattened tree path so a restart
on a DIFFERENT topology re-shards on load (the arrays are saved unsharded
per host slice and re-committed to the new mesh by the caller's
``jax.device_put`` with the new sharding).

Fault-tolerance contract (runtime/elastic.py): checkpoint every N steps;
on any node failure the job restarts from the last complete step with a
(possibly smaller) mesh and an identical data stream (data/pipeline.py is
seeded per step).
"""
from __future__ import annotations

import json
import shutil
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":        # ml_dtypes (bf16): store as f32
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        items[key] = arr
    return items, treedef


def save(ckpt_dir, step: int, tree, *, host_id: int = 0,
         extra: dict | None = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:08d}"
    step_dir.mkdir(parents=True, exist_ok=True)
    items, _ = _flatten(tree)
    np.savez(step_dir / f"shard_{host_id:05d}.npz", **items)
    if host_id == 0:
        manifest = {"step": step, "time": time.time(),
                    "n_arrays": len(items), "extra": extra or {}}
        # manifest written last = commit point
        (step_dir / "manifest.json").write_text(json.dumps(manifest))
        _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "manifest.json").exists())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(d for d in ckpt_dir.glob("step_*")
                   if (d / "manifest.json").exists())
    return int(steps[-1].name.split("_")[1]) if steps else None


def restore(ckpt_dir, step: int, like, *, host_id: int = 0):
    """Restore into the structure of ``like`` (a pytree or SDS tree)."""
    step_dir = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(step_dir / f"shard_{host_id:05d}.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            # jnp handles ml_dtypes (bf16) casts that numpy cannot
            out.append(jnp.asarray(arr).astype(leaf.dtype))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(ckpt_dir, like, *, host_id: int = 0):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, like, host_id=host_id)
