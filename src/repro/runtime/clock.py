"""Injectable time sources for the serving stack.

The engine, scheduler and request tracker never call ``time.perf_counter``
directly any more — they read ``engine.clock`` (a zero-arg callable
returning seconds). The default is the wall clock; tests, trace replay and
the SLO bench inject a ``VirtualClock`` so deadline expiry, TTFT/TPOT and
goodput become deterministic functions of scheduling decisions alone (no
machine-speed dependence, no flaky deadline aborts under load).
"""
from __future__ import annotations

import time

#: the production default — module-level so call sites read one name
WALL_CLOCK = time.perf_counter


class VirtualClock:
    """Deterministic manual clock: time advances only when the driver says
    so. Callable (returns current virtual seconds), so it drops into any
    ``clock=`` slot interchangeably with ``time.perf_counter``."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, dt
        self.t += float(dt)
        return self.t

    def advance_to(self, t: float) -> float:
        """Jump forward to absolute time ``t`` (no-op when in the past)."""
        self.t = max(self.t, float(t))
        return self.t
