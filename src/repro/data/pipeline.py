"""Data pipeline: deterministic synthetic token streams + request traces.

Two producers:

* ``TrainPipeline`` — sharded, deterministic, resumable token batches for
  the training driver (seeded per (step, host) so restarts reproduce the
  exact stream — required for fault-tolerant resume).
* ``request_trace`` — serving request traces whose context-length
  distribution matches the paper's Table 2 LongBench statistics (QMSum /
  HotpotQA / Musique: mean/std/max/min), used by the scheduler benchmarks to
  reproduce the lazy-allocation batch-size results (Fig. 4b, §5.4).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Table 2 of the paper: input context length statistics (Qwen tokenizer).
LONGBENCH_STATS = {
    "qmsum":    {"mean": 13966, "std": 6182, "max": 30456, "min": 2651},
    "hotpotqa": {"mean": 13465, "std": 3921, "max": 17674, "min": 1917},
    "musique":  {"mean": 16362, "std": 1651, "max": 17917, "min": 6820},
}


@dataclass
class TrainPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for (step, host) — resumable by construction."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S = self.host_batch, self.seq_len
        # zipf-ish marginals so the loss has learnable structure
        ranks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        toks = (ranks % (self.vocab_size - 2)) + 2
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((B, S), np.float32),
        }


def request_trace(task: str, n_requests: int, *, seed: int = 0,
                  max_context: int | None = None,
                  mean_new_tokens: int = 128) -> list[tuple[int, int]]:
    """[(prompt_len, max_new_tokens)] with the task's length distribution."""
    st = LONGBENCH_STATS[task]
    rng = np.random.default_rng(seed)
    lens = rng.normal(st["mean"], st["std"], size=n_requests)
    lens = np.clip(lens, st["min"], st["max"]).astype(np.int64)
    if max_context is not None:
        lens = np.minimum(lens, max_context - mean_new_tokens - 1)
    new = np.maximum(8, rng.poisson(mean_new_tokens, size=n_requests))
    return [(int(l), int(n)) for l, n in zip(lens, new)]
