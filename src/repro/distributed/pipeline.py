"""Pipeline parallelism over the 'pod' axis for decode (paper §4.2).

The paper's scaling argument: TP-only multi-module PIM collapses (aspect
distortion), so LoL-PIM groups layers into PP stages and keeps TP moderate
inside each stage, with microbatches keeping the pipeline full. Here:

* stage s = pod s (layer stack reshaped [stages, L/stages, ...], the stage
  dim sharded over 'pod');
* one decode step runs a GPipe tick loop of M + stages - 1 ticks inside a
  shard_map that is MANUAL over 'pod' and AUTO over data/model — so each
  stage's inner compute keeps the Megatron-TP weight layout and the inner
  ITPP shard_map (which inherits the partial-manual context mesh);
* microbatch b enters stage 0 at tick b; activations hop stages via
  ``ppermute``; fill/drain ticks compute garbage whose pool writes are
  masked (new_page = -1 owns nowhere) — the paper's pipeline bubbles, visible
  in the roofline as idle fraction (m/(m+S-1));
* the last stage's logits psum over 'pod' (other stages contribute zeros).

Applicable to uniform attention stacks (dense / MoE / VLM archs, incl.
gemma3's windowed pattern); hybrid/enc-dec archs use pod=dp (DESIGN.md §6).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.itpp import ItppSpec, itpp_decode_attention_shard
from repro.core.jax_compat import shard_map
from repro.models import layers as L
from repro.models import model as MDL
from repro.models import moe as MOE


def stack_stages(stacked, n_stages: int):
    """[L, ...] layer-stacked params -> [stages, L/stages, ...]."""
    def r(x):
        assert x.shape[0] % n_stages == 0, (x.shape, n_stages)
        return x.reshape(n_stages, x.shape[0] // n_stages, *x.shape[1:])
    return jax.tree.map(r, stacked)


def _inner_itpp(spec: ItppSpec, max_pages_per_req: int, ring_width: int,
                mesh_axis_sizes, mesh=None):
    """ITPP shard_map that inherits the partial-manual context mesh."""
    body = partial(itpp_decode_attention_shard, spec=spec,
                   mesh_axis_sizes=mesh_axis_sizes,
                   max_pages_per_req=max_pages_per_req, ring_width=ring_width)
    b = spec.batch_axis
    pool_spec = P(spec.page_axes, None, None, None)
    in_specs = (P(b, None, None), P(b, None, None), P(b, None, None),
                pool_spec, pool_spec, P(b, None), P(b), P(b), P(b), P())
    out_specs = (P(b, None, None), pool_spec, pool_spec)
    axes = set(spec.page_axes)
    if b is not None:
        axes |= set(b) if isinstance(b, tuple) else {b}
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False, axis_names=axes)


def _inner_moe(cfg, tp_axis: str, tp_n: int, batch_axis, mesh=None):
    def body(pw, x_loc):
        Bl, S, D = x_loc.shape
        y, aux = MOE.moe_ep(pw, cfg, x_loc.reshape(-1, D), tp_axis, tp_n)
        return y.reshape(Bl, S, D), aux

    pspec = {"router": P(None, None), "w1": P(tp_axis, None, None),
             "w2": P(tp_axis, None, None)}
    xspec = P(batch_axis, None, None)

    def apply(p, cfg_, x):
        ps = dict(pspec)
        if "w3" in p:
            ps["w3"] = P(tp_axis, None, None)
        fn = shard_map(
            body, mesh=mesh, in_specs=(ps, xspec), out_specs=(xspec, P()),
            check_vma=False,
            axis_names={tp_axis} | ({batch_axis} if batch_axis else set()))
        y, aux = fn({k: p[k] for k in ps}, x)
        return y, aux

    return apply


def make_pp_decode_step(cfg, plan, parallel, pool_spec, *, n_stages: int,
                        microbatches: int):
    """Returns (step(params, state, batch) -> (logits, state), param/state
    transforms). Params must be passed through ``stage_params(params)``."""
    mesh = plan.mesh
    sizes = dict(mesh.shape)
    ispec = plan.itpp_spec(parallel.page_size)
    # inside the manual-pod region the inner axes see the same sizes
    inner_sizes = {k: v for k, v in sizes.items() if k != "pod"}
    inner_mesh = None if hasattr(jax, "shard_map") else mesh
    itpp_fn = _inner_itpp(ispec, pool_spec.max_pages_per_req,
                          pool_spec.max_pages_per_req if pool_spec.ring else 0,
                          inner_sizes, mesh=inner_mesh)
    moe_fn = _inner_moe(cfg, plan.tp_axis, plan.tp, ispec.batch_axis,
                        mesh=inner_mesh) if cfg.is_moe else None
    rt = MDL.Runtime(itpp=itpp_fn, moe=moe_fn,
                     ring_width=pool_spec.max_pages_per_req
                     if pool_spec.ring else 0)
    windows = np.asarray(MDL._window_array(cfg)).reshape(
        n_stages, cfg.n_layers // n_stages)

    def body(stage_p, embed_w, head_w, final_norm, pool_k, pool_v,
             tokens, bt, ctx, npage, noff):
        """Manual over 'pod': stage_p has leading [1, L/stages, ...]."""
        s = jax.lax.axis_index("pod")
        sp = jax.tree.map(lambda x: x[0], stage_p)
        B = tokens.shape[0]
        mb = B // microbatches
        D = cfg.d_model
        n_ticks = microbatches + n_stages - 1
        w_stage = jnp.asarray(windows)[s]                     # [L/stages]

        def mb_slice(x, i):
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def tick(carry, t):
            reg, pk, pv, out = carry
            # my stage processes microbatch (t - s) this tick
            my_mb = t - s
            active = (my_mb >= 0) & (my_mb < microbatches)
            i = jnp.clip(my_mb, 0, microbatches - 1)
            tok_i = mb_slice(tokens, i)
            ctx_i = mb_slice(ctx, i)
            bt_i = mb_slice(bt, i)
            npage_i = jnp.where(active, mb_slice(npage, i), -1)  # mask writes
            noff_i = mb_slice(noff, i)
            x0 = L.embed(embed_w, tok_i)
            x = jnp.where(s == 0, x0, reg)
            pos = (ctx_i - 1).astype(jnp.int32)[:, None]
            if cfg.rope_kind == "mrope":
                pos = jnp.broadcast_to(pos[None], (3, mb, 1))
            cs = MDL._cos_sin(cfg, pos)

            def layer(carry2, xs):
                h, pk_, pv_ = carry2
                li, lp, w = xs
                # pool layer dim is stage-local (sharded over 'pod')
                pkl = jax.lax.dynamic_index_in_dim(pk_, li, 0, keepdims=False)
                pvl = jax.lax.dynamic_index_in_dim(pv_, li, 0, keepdims=False)
                h, pkl, pvl = MDL._attn_block_decode(
                    lp, cfg, h, cs, w, pkl, pvl, bt_i, ctx_i, npage_i,
                    noff_i, rt)
                pk_ = jax.lax.dynamic_update_index_in_dim(pk_, pkl, li, 0)
                pv_ = jax.lax.dynamic_update_index_in_dim(pv_, pvl, li, 0)
                return (h, pk_, pv_), None

            li = jnp.arange(cfg.n_layers // n_stages)
            (x, pk, pv), _ = jax.lax.scan(layer, (x, pk, pv),
                                          (li, sp, w_stage))
            # last stage: head + write logits for my_mb
            hfin = L.rms_norm(x, final_norm, cfg.norm_eps)
            w_ = embed_w if cfg.tie_embeddings else head_w
            lg = L.lm_head(hfin, w_, transpose=cfg.tie_embeddings)
            is_last = s == n_stages - 1
            valid_out = active & is_last
            upd = jnp.where(valid_out, lg, mb_slice(out, i))
            out = jax.lax.dynamic_update_slice_in_dim(out, upd, i * mb, 0)
            # hop to next stage
            perm = [(k, k + 1) for k in range(n_stages - 1)]
            reg_next = jax.lax.ppermute(x, "pod", perm)
            return (reg_next, pk, pv, out), None

        # pool arrives stage-local: [L/stages, pages, ...] (P('pod') on dim0)
        reg0 = jnp.zeros((mb, D), embed_w.dtype)
        out0 = jnp.zeros((B, cfg.padded_vocab), jnp.float32)
        (reg, pk, pv, out), _ = jax.lax.scan(
            tick, (reg0, pool_k, pool_v, out0), jnp.arange(n_ticks))
        # logits live on the last stage only; share across pods
        out = jax.lax.psum(jnp.where(s == n_stages - 1, out, 0.0), "pod")
        return out, pk, pv

    # manual only over 'pod'; data/model stay auto (the Megatron-TP weight
    # layout and ITPP page sharding flow through). The pool's layer dim is
    # stage-sharded over 'pod' — each pod holds only its stage's KV.
    in_specs = (P("pod"), P(), P(), P(), P("pod"), P("pod"),
                P(), P(), P(), P(), P())
    out_specs = (P(), P("pod"), P("pod"))
    shmap = shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False,
                      axis_names={"pod"})

    def step(params, state, batch):
        sp = stack_stages(params["layers"], n_stages)
        head = params.get("head", params["embed"])
        pool = state["pool"]
        logits, pk, pv = shmap(sp, params["embed"], head,
                               params["final_norm"], pool["k"], pool["v"],
                               batch["tokens"], batch["bt"], batch["ctx"],
                               batch["npage"], batch["noff"])
        return logits, {**state, "pool": {"k": pk, "v": pv}}

    return step
