"""Sharding plans: parameter PartitionSpecs, activation constraints, and the
Runtime wiring (ITPP decode attention + expert-parallel MoE) per cell.

Two weight layouts (DESIGN.md §4):

* ``train`` — FSDP/ZeRO-3: every large leaf sharded over (dp..., model) on
  its last two divisible dims; compute is data/sequence-parallel ("sp" mode:
  batch over the data axes, sequence over the model axis) with weights
  gathered per layer by XLA. Works for every arch regardless of head counts —
  the same argument the paper makes for token-parallel over head-first.
* ``serve`` — Megatron TP resident weights: column-parallel up/QKV,
  row-parallel down/out over the model axis; the batch rides the data axes
  as independent serving rows; attention is ITPP (pages sharded over
  dp+model, stable merge). MoE weights live in virtual-expert layout with
  the expert dim on the model axis (EP).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.itpp import ItppSpec, make_itpp_attention
from repro.core.jax_compat import abstract_mesh as _abstract_mesh
from repro.core.jax_compat import shard_map
from repro.models.model import Runtime
from repro.models import moe as MOE

STACKED_KEYS = {"layers", "enc", "dec", "mamba", "mlstm", "slstm"}
# serve-mode column-parallel (shard last dim) / row-parallel (shard first
# non-stack dim) weight names
COL_NAMES = {"wq", "wk", "wv", "w1", "w3", "wz", "wx", "wu", "wg"}
ROW_NAMES = {"wo", "w2", "out_proj", "down"}
REPLICATE_SMALL = 1 << 16


def abstract_mesh(shape, axis_names):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor (tests and
    dry-run tooling build meshes through this so plan invariants can be
    checked without real devices)."""
    return _abstract_mesh(shape, axis_names)


def _path_keys(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


@dataclass
class Plan:
    mesh: Any
    dp_axes: tuple[str, ...]          # ('data',) or ('pod','data')
    tp_axis: str                      # 'model'
    shape_kind: str                   # train | prefill | decode
    batch_divisible: bool             # global_batch % prod(dp_axes) == 0
    seq_divisible: bool = True
    pod_mode: str = "dp"
    # train/prefill activation layout:
    #  'fsdp' — batch sharded over EVERY mesh axis, sequence local: no KV
    #           gathers, weights gathered per layer (ZeRO-3). Chosen when
    #           global_batch divides the device count.
    #  'sp'   — batch over dp axes, sequence over the model axis (context
    #           parallelism): K/V all-gathered per layer. Chosen otherwise.
    train_layout: str = "fsdp"

    # -------------------- sizes --------------------
    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(self.mesh.shape)

    @property
    def dp_total(self) -> int:
        s = self.axis_sizes
        return int(np.prod([s[a] for a in self.dp_axes]))

    @property
    def tp(self) -> int:
        return self.axis_sizes[self.tp_axis]

    @property
    def dp_spec(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def batch_spec(self):
        return self.dp_spec if self.batch_divisible else None

    # -------------------- activation constraints --------------------
    @property
    def full_batch_spec(self):
        return (*self.dp_axes, self.tp_axis)

    def _act_table(self) -> dict[str, P]:
        dp, tp, b = self.dp_spec, self.tp_axis, self.batch_spec
        if self.train_layout == "fsdp":
            fb = self.full_batch_spec
            return {
                "act": P(fb, None, None),
                "kv_full": P(fb, None, None, None),
                "logits": P(fb, None, None),
                "act_decode": P(b, None),
                "logits_decode": P(b, tp),
            }
        seq = tp if self.seq_divisible else None
        return {
            "act": P(dp, seq, None),
            "kv_full": P(dp, None, None, None),
            "logits": P(dp, seq, None),
            "act_decode": P(b, None),
            "logits_decode": P(b, tp),
        }

    def constrain(self, x, name: str):
        spec = self._act_table().get(name)
        if spec is None:
            return x
        spec = P(*spec[: x.ndim])
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    # -------------------- parameter specs --------------------
    def param_specs(self, params, *, mode: str):
        """mode: 'train' (FSDP) or 'serve' (Megatron TP, rows replicated)."""
        sizes = self.axis_sizes
        dp_n, tp_n = self.dp_total, self.tp
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        specs = []
        for path, leaf in flat:
            keys = _path_keys(path)
            stacked = keys[0] in STACKED_KEYS
            name = keys[-1]
            if name in ("q", "s") and len(keys) >= 2:   # int8 QTensor leaves
                name = keys[-2]
            shape = leaf.shape
            dims = list(shape)
            spec = [None] * len(dims)
            start = 1 if stacked else 0
            body = dims[start:]
            is_moe = "moe" in keys
            if int(np.prod(body or [1])) < REPLICATE_SMALL:
                specs.append(P())
                continue
            if mode == "serve":
                if is_moe and name in ("w1", "w2", "w3"):
                    # [*, V, D, ffv] — virtual experts on the model axis (EP)
                    spec[start] = self.tp_axis
                elif name == "embed":
                    spec[1] = self.tp_axis          # [V, D] shard D
                elif name == "head":
                    spec[1] = self.tp_axis          # [D, V] vocab col-TP
                elif name in COL_NAMES and len(shape) - start == 2:
                    if shape[-1] % tp_n == 0:
                        spec[-1] = self.tp_axis
                elif name in ROW_NAMES and len(shape) - start == 2:
                    if shape[start] % tp_n == 0:
                        spec[start] = self.tp_axis
                specs.append(P(*spec))
                continue
            # ---- train: FSDP over (dp, model) on last two divisible dims
            if is_moe and name in ("w1", "w2", "w3"):
                spec[start] = self.tp_axis          # EP entry layout
                if shape[start + 1] % dp_n == 0:
                    spec[start + 1] = self.dp_spec
                specs.append(P(*spec))
                continue
            if name == "embed":
                spec[1] = self.tp_axis
                if shape[0] % dp_n == 0:
                    spec[0] = self.dp_spec
                specs.append(P(*spec))
                continue
            if shape[-1] % tp_n == 0 and len(shape) - start >= 1:
                spec[-1] = self.tp_axis
            if len(shape) - start >= 2 and shape[-2] % dp_n == 0:
                spec[-2] = self.dp_spec
            specs.append(P(*spec))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def named(self, spec_tree):
        return jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    # -------------------- decode state specs --------------------
    @property
    def page_axes(self) -> tuple[str, ...]:
        return (*self.dp_axes, self.tp_axis)

    def pool_spec(self):
        return P(None, self.page_axes, None, None, None)

    def decode_state_specs(self, state):
        b = self.batch_spec
        out = {}
        for k, v in state.items():
            if k == "pool":
                out[k] = {"k": self.pool_spec(), "v": self.pool_spec()}
            elif k in ("cross_k", "cross_v"):
                out[k] = P(None, b, *([None] * (v.ndim - 2)))
            else:   # recurrent states: [n, B, ...]
                out[k] = jax.tree.map(
                    lambda a: P(None, b, *([None] * (a.ndim - 2))), v)
        return out

    # -------------------- runtime wiring --------------------
    def itpp_spec(self, page_size: int) -> ItppSpec:
        sizes = self.axis_sizes
        n_page_shards = int(np.prod([sizes[a] for a in self.page_axes]))
        if self.batch_divisible:
            # requests pinned to data rows; stripe over the row's model shards
            return ItppSpec(self.page_axes, (self.tp_axis,), self.batch_spec,
                            n_page_shards, self.tp, page_size)
        # batch replicated: stripe each request over the whole mesh
        return ItppSpec(self.page_axes, self.page_axes, None,
                        n_page_shards, n_page_shards, page_size)

    def make_runtime(self, cfg, parallel, *, pool_spec=None,
                     mode: str = "train") -> Runtime:
        from repro.kernels.backend import KernelConfig
        kernels = KernelConfig(use_pallas=parallel.use_pallas,
                               interpret=parallel.kernel_interpret,
                               n_splits=parallel.kernel_splits)
        rt = Runtime(constrain=self.constrain, remat=parallel.remat,
                     kernels=kernels)
        if pool_spec is not None:
            rt.ring_width = pool_spec.max_pages_per_req if pool_spec.ring else 0
            if mode == "decode":
                spec = self.itpp_spec(parallel.page_size)
                kinds = set(cfg.block_kinds())
                mixed = "local" in kinds and "attn" in kinds
                rt.cond_window = cfg.sliding_window if mixed else 0
                rt.itpp = make_itpp_attention(
                    self.mesh, spec,
                    max_pages_per_req=pool_spec.max_pages_per_req,
                    ring_width=rt.ring_width,
                    cond_window=rt.cond_window,
                    kernels=kernels)
            if mode == "prefill" and not pool_spec.ring \
                    and self.train_layout == "sp" and self.seq_divisible:
                from repro.core.itpp import make_prefill_writer
                rt.write_pool = make_prefill_writer(
                    self.mesh, self.itpp_spec(parallel.page_size),
                    seq_axis=self.tp_axis)
        if cfg.is_moe:
            rt.moe = self._make_moe_ep(cfg)
        return rt

    def _make_moe_ep(self, cfg):
        mesh, tp_axis, tp_n = self.mesh, self.tp_axis, self.tp
        dp, b = self.dp_spec, self.batch_spec
        seq = tp_axis if self.seq_divisible else None

        def body(pw, x_loc):
            B, S, D = x_loc.shape
            y, aux = MOE.moe_ep(pw, cfg, x_loc.reshape(-1, D), tp_axis, tp_n)
            return y.reshape(B, S, D), jax.lax.pmean(
                aux, (*self.dp_axes, tp_axis))

        def apply(p, cfg_, x):
            is_decode = x.shape[1] == 1
            act = self._act_table()["act"]
            xspec = P(b, None, None) if is_decode else P(*act[:2], None)
            pspec = {"router": P(None, None),
                     "w1": P(tp_axis, None, None),
                     "w2": P(tp_axis, None, None)}
            if "w3" in p:
                pspec["w3"] = P(tp_axis, None, None)
            fn = shard_map(
                body, mesh=mesh, in_specs=(pspec, xspec),
                out_specs=(xspec, P()), check_vma=False)
            return fn({k: p[k] for k in pspec}, x)

        return apply


def make_plan(mesh, parallel, shape, *, pod_mode: str = "dp",
              train_layout: str | None = None) -> Plan:
    axes = mesh.axis_names
    dp_axes = tuple(a for a in axes if a in ("pod", "data")) \
        if pod_mode == "dp" else ("data",)
    sizes = dict(mesh.shape)
    dp_total = int(np.prod([sizes[a] for a in dp_axes]))
    tp = sizes["model"]
    n_dev = int(np.prod(list(sizes.values())))
    if train_layout is None:
        train_layout = "fsdp" if shape.global_batch % n_dev == 0 else "sp"
    return Plan(
        mesh=mesh,
        dp_axes=dp_axes,
        tp_axis="model",
        shape_kind=shape.kind,
        batch_divisible=shape.global_batch % dp_total == 0,
        seq_divisible=(shape.seq_len % tp == 0) and shape.kind != "decode",
        pod_mode=pod_mode,
        train_layout=train_layout,
    )
