"""Analytic PIM/GPU decode-latency model — the paper's simulator analogue.

The paper evaluates with a Ramulator-based simulator validated against the
AiM-SDK (Table 6). We reproduce its *mechanisms* analytically:

* attention = KV GEMV at aggregate internal bandwidth x DRAM efficiency x
  channel utilization. ① ITPP vs HFA enters through utilization: HFA parks
  one (request, kv-head) pair per channel -> util = B*n_kv/channels and
  suffers context-length imbalance (Table 2 variability); ITPP token-
  partitions -> util ~ 1 for long contexts (paper §4.3).
* FC = weight-streaming GEMV, B passes over the weights; per-module output
  slice width d_ff/TP collapses at high TP (aspect-ratio distortion,
  paper Fig. 5) -> efficiency min(1, slice/256). ① PP keeps TP moderate.
* module I/O through the 64 GB/s interface (Table 5): input broadcast +
  partial-output collection for FC; QK^T score-out / softmax-in for
  attention (the Fig. 7 DT-Out/DT-GB terms). ③ ping-pong overlaps I/O with
  compute: t = max(core, io) instead of core + io, and the extra GB doubles
  input-batch reuse for FC streams.
* ② DPA enters through batch: static allocation reserves max-context KV per
  request, lazy reserves the actual context (paper §5.4).
* PP bubbles: m/(m + pp - 1) with m concurrent microbatches + host sync.

Two constants are NOT published — DRAM command/row-activate efficiency and
the effective FC input-reuse — and are CALIBRATED against the paper's own
Table 8 (Qwen-7B row: 1833 / 2455 / 3668 tok/s); the 14B/72B rows and the
Fig. 9/10 capacity sweeps are then *predictions* reported next to the
paper's values (see benchmarks/). This mirrors the paper's own SDK-based
calibration methodology.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Node:
    name: str
    compute_tflops: float
    ext_bw_gbs: float           # off-node bandwidth GB/s
    int_bw_gbs: float           # internal bandwidth GB/s
    capacity_gb: float
    modules: int = 0            # PIM modules per node
    channels_per_module: int = 16
    module_if_gbs: float = 64.0  # Table 5 interface bandwidth


GPU_HBM = Node("GPU-HBM", 312, 3352, 3352, 80)
GPU_GDDR = Node("GPU-GDDR", 312, 4096, 4096, 64)
PIM_NODE = Node("PIM", 66, 4096, 65_500, 64, modules=8)

INTER_NODE_BW_GBS = 10.0        # QSFP, paper §8.1
HOST_SYNC_US = 10.0
# Host DRAM offload link (PCIe/CXL-class) for the KV capacity tier below
# the PIM pool (repro.kvcache.offload). Well under the module-internal
# bandwidth: swapping a prefix in is only worth it when it replaces a
# re-prefill, which the swap cost term below lets admission weigh.
HOST_LINK_GBS = 16.0
# Out-Reg drain path per module: 2-byte registers per PU, serialized RD-OUT
# commands — an order of magnitude below the 64 GB/s interface. This is what
# makes DT-Out ~half of QK^T latency in the paper's Fig. 7.
OUTREG_BW_GBS = 8.0

# ---- calibrated constants (least-squares fit to the paper's Table 8 grid;
# mean error 5.9% over its nine (model-scale x technique-level) entries —
# see benchmarks/utilization.py for the side-by-side) ----
DRAM_EFF = 0.20                 # command/row-activate efficiency of GEMV
FC_REUSE_BASE = 2.0             # input vectors resident per weight stream
FC_REUSE_ITPP = 4.0             # ①'s PP shrinks per-module working set
FC_REUSE_PP = 4.0               # (③'s gain is overlap, not extra reuse)


@dataclass(frozen=True)
class LLM:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    bytes_per_el: int = 2

    @property
    def weight_bytes_per_layer(self) -> float:
        qkv = self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        proj = self.n_heads * self.d_head * self.d_model
        ffn = 3 * self.d_model * self.d_ff
        return (qkv + proj + ffn) * self.bytes_per_el

    @property
    def weight_bytes(self) -> float:
        return self.n_layers * self.weight_bytes_per_layer

    @property
    def kv_bytes_per_token(self) -> float:
        return (self.n_layers * 2 * self.n_kv_heads * self.d_head
                * self.bytes_per_el)

    @property
    def flops_per_token(self) -> float:
        return 2 * self.weight_bytes / self.bytes_per_el


QWEN_7B = LLM("qwen1.5-7b", 32, 4096, 32, 32, 128, 11008)
QWEN_14B = LLM("qwen1.5-14b", 40, 5120, 40, 40, 128, 13696)
QWEN_72B = LLM("qwen1.5-72b", 80, 8192, 64, 64, 128, 24576)


@dataclass(frozen=True)
class System:
    node: Node
    n_nodes: int
    pp: int = 1
    itpp: bool = False
    dpa: bool = False
    pingpong: bool = False
    gpu_hybrid: bool = False

    @property
    def is_pim(self) -> bool:
        return self.node.modules > 0

    @property
    def capacity_bytes(self) -> float:
        return self.n_nodes * self.node.capacity_gb * 1e9

    @property
    def modules(self) -> int:
        return self.n_nodes * self.node.modules

    @property
    def channels(self) -> int:
        return self.modules * self.node.channels_per_module

    @property
    def agg_int_bw(self) -> float:
        return self.n_nodes * self.node.int_bw_gbs * 1e9

    @property
    def agg_compute(self) -> float:
        return self.n_nodes * self.node.compute_tflops * 1e12


def max_batch(sys: System, model: LLM, avg_ctx: float, max_ctx: float,
              *, slots: int = 256) -> int:
    cap = sys.capacity_bytes
    if sys.gpu_hybrid:
        cap = cap / 2                       # paper §8.1: hybrid halves PIM
    kv_budget = cap - model.weight_bytes
    if kv_budget <= 0:
        return 0
    per_req = model.kv_bytes_per_token * (avg_ctx if sys.dpa else max_ctx)
    return max(0, min(slots, int(kv_budget / per_req)))


def _attn_util(sys: System, model: LLM, B: int, avg_ctx: float,
               ctx_cv: float) -> float:
    if not sys.is_pim:
        return 1.0
    ch = sys.channels / sys.pp
    if sys.itpp:
        tokens = B * avg_ctx
        return min(1.0, tokens / (ch * 256.0))
    # HFA: (request, head) per channel + per-channel KV-length imbalance:
    # the slowest channel holds a max-length context -> mean/max factor
    occupancy = min(1.0, B * model.n_kv_heads / ch)
    balance = 1.0 / (1.0 + ctx_cv)
    return occupancy * balance


def attn_channel_util(sys: System, model: LLM, B: int, avg_ctx: float,
                      ctx_cv: float = 0.0) -> float:
    """Public alias of the attention channel-utilization term — the ITPP
    (tokens / channel-capacity) vs HFA ((request, head) occupancy x balance)
    proxy. ``telemetry.pim_counters`` emits this live during serving from
    the scheduler's host-side context snapshot."""
    return _attn_util(sys, model, B, avg_ctx, ctx_cv)


def decode_latency(sys: System, model: LLM, B: int, avg_ctx: float,
                   *, ctx_cv: float = 0.3) -> dict:
    """Seconds per decode step for batch B at average context avg_ctx."""
    B = max(B, 1)
    el = model.bytes_per_el
    L = model.n_layers
    if_bw = sys.node.module_if_gbs * 1e9 if sys.is_pim else 0.0

    # -------- attention --------
    attn_bytes = B * avg_ctx * model.kv_bytes_per_token
    if sys.is_pim:
        util = max(_attn_util(sys, model, B, avg_ctx, ctx_cv), 1e-3)
        t_attn = attn_bytes / (sys.agg_int_bw * DRAM_EFF * util)
        # QK^T scores out (DT-Out, slow Out-Reg drain) + softmaxed scores
        # back in for SV (DT-GB via the interface):
        score_bytes = B * avg_ctx * model.n_heads * el * L
        t_attn_io = (score_bytes / (sys.modules * OUTREG_BW_GBS * 1e9)
                     + score_bytes / (sys.modules * if_bw))
    else:
        t_attn = max(attn_bytes / sys.agg_int_bw,
                     (2 * attn_bytes / el) / sys.agg_compute)
        t_attn_io = 0.0

    # -------- FC layers --------
    w = model.weight_bytes
    if sys.is_pim and not sys.gpu_hybrid:
        reuse = (FC_REUSE_PP if sys.pingpong
                 else FC_REUSE_ITPP if sys.itpp else FC_REUSE_BASE)
        tp_modules = sys.modules / sys.pp
        slice_w = model.d_ff / max(tp_modules, 1)
        aspect_eff = min(1.0, slice_w / 256.0)      # Fig. 5 distortion
        t_fc = (math.ceil(B / reuse) * w
                / (sys.agg_int_bw * DRAM_EFF * aspect_eff))
        fc_io_bytes = B * (L / sys.pp) * 4 * model.d_model * el
        t_fc_io = fc_io_bytes / if_bw               # per-module broadcast
    else:
        flops = model.flops_per_token * B
        bw = sys.agg_int_bw
        t_fc = max(w / bw, flops / sys.agg_compute)
        t_fc_io = 0.0
        if sys.gpu_hybrid:
            t_fc_io = (2 * L * B * model.d_model * el
                       / (INTER_NODE_BW_GBS * 1e9))

    # -------- combine (③ overlap) --------
    if sys.pingpong:
        t = max(t_attn, t_attn_io) + max(t_fc, t_fc_io)
    else:
        t = t_attn + t_attn_io + t_fc + t_fc_io

    # -------- pipeline bubbles + sync --------
    if sys.is_pim and sys.pp > 1:
        micro = max(1, min(B, 2 * sys.pp))
        eff = micro / (micro + sys.pp - 1)
        t = t / eff + sys.pp * HOST_SYNC_US * 1e-6
    if not sys.is_pim and sys.n_nodes > 1:
        ar = 2 * L * B * model.d_model * el * (sys.n_nodes - 1) / sys.n_nodes
        t += ar / (INTER_NODE_BW_GBS * 1e9)
    return {"t_step": t, "t_attn": t_attn, "t_attn_io": t_attn_io,
            "t_fc": t_fc, "t_fc_io": t_fc_io}


def swap_latency(model: LLM, n_tokens: float, *,
                 link_gbs: float | None = None) -> float:
    """Seconds to move ``n_tokens`` worth of KV across the host offload
    link — the cost of treating host-resident (or reclaimable) KV pages as
    admission capacity. Memory-aware admission adds this to a candidate's
    modelled cost so a swap-heavy hit only wins when it beats the prefill
    it replaces."""
    bw = (link_gbs if link_gbs is not None else HOST_LINK_GBS) * 1e9
    return n_tokens * model.kv_bytes_per_token / bw


def throughput(sys: System, model: LLM, *, avg_ctx: float, max_ctx: float,
               ctx_cv: float = 0.3, slots: int = 256) -> dict:
    B = max_batch(sys, model, avg_ctx, max_ctx, slots=slots)
    if B == 0:
        return {"tokens_per_s": 0.0, "batch": 0, "util": 0.0, "t_step": 0.0}
    lat = decode_latency(sys, model, B, avg_ctx, ctx_cv=ctx_cv)
    tput = B / lat["t_step"]
    # paper Table 8 utilization = achieved MACs / peak compute
    flops = B * (model.flops_per_token + 2 * avg_ctx
                 * model.kv_bytes_per_token / model.bytes_per_el)
    util = flops / lat["t_step"] / sys.agg_compute if sys.is_pim else \
        flops / lat["t_step"] / sys.agg_compute
    return {"tokens_per_s": tput, "batch": B, "util": min(util, 1.0), **lat}


def lol_pim(n_nodes: int, *, pp: int | None = None, level: int = 3,
            gpu_hybrid: bool = False) -> System:
    """level: 0=baseline PIM (HFA, static, no overlap), 1=+ITPP/PP,
    2=+DPA, 3=+ping-pong (full LoL-PIM)."""
    if pp is None:
        pp = max(1, n_nodes // 2) if level >= 1 else 1
    return System(PIM_NODE, n_nodes, pp=pp if level >= 1 else 1,
                  itpp=level >= 1, dpa=level >= 2, pingpong=level >= 3,
                  gpu_hybrid=gpu_hybrid)
