"""DPA-style paged KV cache: page pool + Va2Pa block tables.

The paper's Direct-PIM-Access controller keeps a Va2Pa table so a request's
KV-cache lives in lazily-allocated, non-contiguous chunks; PIM commands are
generated length-generically (Dyn-Loop) and resolve physical rows at dispatch.
The XLA analogue (DESIGN.md §2): a fixed page pool compiled once, with block
tables and context lengths as *runtime data* — one program serves every
context length, memory is allocated page-by-page as requests grow.

Device-side ops here are the single-shard reference semantics; the sharded
ITPP version lives in ``core/itpp.py`` and the TPU kernel in
``kernels/paged_attention.py``. All three agree (tested).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.layers import NEG_INF, decode_attention_ref


@dataclass(frozen=True)
class PoolSpec:
    """Static geometry of the paged pool (compile-time constants)."""
    n_layers: int          # attention layers holding KV
    n_pages: int           # total pages in the pool (divisible by shards)
    page_size: int         # tokens per page
    n_kv_heads: int
    d_head: int
    max_pages_per_req: int # block-table width
    dtype: str = "bfloat16"
    ring: bool = False     # sliding-window pool: table slots recycle mod width

    @property
    def tokens(self) -> int:
        return self.n_pages * self.page_size

    def bytes(self, bytes_per_el: int = 2) -> int:
        return (2 * self.n_layers * self.n_pages * self.page_size
                * self.n_kv_heads * self.d_head * bytes_per_el)


def init_pool(spec: PoolSpec):
    shape = (spec.n_layers, spec.n_pages, spec.page_size,
             spec.n_kv_heads, spec.d_head)
    z = jnp.zeros(shape, jnp.dtype(spec.dtype))
    return {"k": z, "v": z}


def pool_spec_for(cfg, shape, parallel, *, n_shards: int | None = None,
                  slack_pages: int = 0) -> PoolSpec:
    """Pool geometry for a (ModelConfig, ShapeConfig, ParallelConfig) cell."""
    kinds = cfg.block_kinds()
    n_attn = sum(1 for k in kinds if k in ("attn", "local"))
    if cfg.family == "encdec":
        n_attn = cfg.n_layers
    ps = parallel.page_size
    # sliding-window layers only ever need window+page live tokens; if ALL
    # attention layers are windowed the pool is a ring capped by the window.
    all_windowed = n_attn > 0 and all(
        k == "local" for k in kinds if k in ("attn", "local"))
    ring = bool(all_windowed and cfg.sliding_window
                and shape.seq_len > cfg.sliding_window)
    eff_len = min(shape.seq_len, cfg.sliding_window + ps) if ring \
        else shape.seq_len
    per_req = -(-eff_len // ps) + 1          # ceil + 1 growth page
    n_pages = shape.global_batch * per_req + slack_pages
    shards = n_shards or (parallel.dp * parallel.tp * parallel.pods)
    n_pages = -(-n_pages // shards) * shards
    return PoolSpec(n_layers=max(n_attn, 1), n_pages=n_pages, page_size=ps,
                    n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                    max_pages_per_req=per_req, dtype=cfg.dtype, ring=ring)


# ---------------------------------------------------------------------------
# reference device ops (single shard)
# ---------------------------------------------------------------------------

def write_token(pool_layer_k, pool_layer_v, k_new, v_new, page_ids, offsets):
    """Append one token's K/V per request.

    pool_layer_{k,v} [P, page, KVH, D]; k_new/v_new [B, KVH, D];
    page_ids/offsets [B] — physical page + in-page slot for each request's
    current token (allocator-provided; distinct requests never share a page).
    """
    pk = pool_layer_k.at[page_ids, offsets].set(k_new.astype(pool_layer_k.dtype),
                                                mode="drop")
    pv = pool_layer_v.at[page_ids, offsets].set(v_new.astype(pool_layer_v.dtype),
                                                mode="drop")
    return pk, pv


def write_prefill(pool_layer_k, pool_layer_v, k_seq, v_seq, block_table,
                  ctx_start=0, ring_width: int = 0, valid_len=None):
    """Scatter a whole prefilled sequence into the pool.

    k_seq/v_seq [B, S, KVH, D]; block_table [B, maxp]. Token t of request b
    goes to page block_table[b, (ctx_start+t)//page] slot (ctx_start+t)%page.
    ``ring_width``>0: sliding-window pools recycle table slots mod ring_width
    (later tokens overwrite expired pages — bounded KV, DPA-style reuse).
    ``valid_len`` [B]: only the first valid_len[b] tokens of request b are
    written (length-bucketed batched prefill pads prompts to a shared S; pad
    positions and -1 block-table entries route out of bounds and are dropped
    by the scatter).
    ``ctx_start`` may be a scalar or a [B] vector (per-request resume depth
    — prefix-cache suffix prefill batches requests with different matched
    prefixes into one call).
    """
    B, S = k_seq.shape[:2]
    n_pool = pool_layer_k.shape[0]
    page = pool_layer_k.shape[1]
    t = (jnp.reshape(jnp.asarray(ctx_start, jnp.int32), (-1, 1))
         + jnp.arange(S)[None])                           # [1|B, S]
    vpage = t // page
    if ring_width:
        vpage = vpage % ring_width
    off = t % page
    pids = jnp.take_along_axis(block_table,
                               jnp.broadcast_to(vpage, (B, S)), axis=1)
    pids = jnp.where(pids < 0, n_pool, pids)              # unallocated -> drop
    if valid_len is not None:
        pad = jnp.arange(S)[None] >= valid_len[:, None]   # [B, S]
        pids = jnp.where(pad, n_pool, pids)
    offs = jnp.broadcast_to(off, (B, S))
    pk = pool_layer_k.at[pids, offs].set(k_seq.astype(pool_layer_k.dtype),
                                         mode="drop")
    pv = pool_layer_v.at[pids, offs].set(v_seq.astype(pool_layer_v.dtype),
                                         mode="drop")
    return pk, pv


def gather_pages(pool_k, pool_v, page_ids):
    """Lift whole pages out of the pool (host-offload swap-out).

    pool_{k,v} [L, P, page, KVH, D]; page_ids [n] (entries == P are pads and
    gather page 0's data — the caller slices them off). Returns
    k, v [L, n, page, KVH, D].
    """
    safe = jnp.minimum(jnp.maximum(page_ids, 0), pool_k.shape[1] - 1)
    return pool_k[:, safe], pool_v[:, safe]


def scatter_pages(pool_k, pool_v, page_ids, k_data, v_data):
    """Write whole pages back into the pool (swap-in / CoW materialize).

    page_ids [n]; k_data/v_data [L, n, page, KVH, D]. Entries == P (pads)
    route out of bounds and are dropped.
    """
    pk = pool_k.at[:, page_ids].set(k_data.astype(pool_k.dtype), mode="drop")
    pv = pool_v.at[:, page_ids].set(v_data.astype(pool_v.dtype), mode="drop")
    return pk, pv


def copy_page(pool_k, pool_v, src, dst):
    """Device-side page copy (copy-on-write divergence): dst := src across
    all layers. src/dst are scalar page ids (traced — one compile serves
    every copy)."""
    pk = pool_k.at[:, dst].set(pool_k[:, src])
    pv = pool_v.at[:, dst].set(pool_v[:, src])
    return pk, pv


def gather_kv(pool_layer_k, pool_layer_v, block_table):
    """[B, maxp] -> contiguous [B, maxp*page, KVH, D] (reference only)."""
    B, maxp = block_table.shape
    safe = jnp.maximum(block_table, 0)
    k = pool_layer_k[safe]                                # [B, maxp, page, KVH, D]
    v = pool_layer_v[safe]
    page = k.shape[2]
    return (k.reshape(B, maxp * page, *k.shape[3:]),
            v.reshape(B, maxp * page, *v.shape[3:]))


def paged_decode_attention_ref(q, pool_layer_k, pool_layer_v, block_table,
                               ctx_len, *, window: int = 0):
    """Oracle: gather pages then dense decode attention.

    q [B, H, D]; ctx_len [B] counts tokens INCLUDING the current one (already
    written to the pool).
    """
    k, v = gather_kv(pool_layer_k, pool_layer_v, block_table)
    return decode_attention_ref(q, k, v, ctx_len, window=window)


def partial_decode_attention(q, k_pages, v_pages, token_valid, *,
                             window_lo=None, ctx_len=None):
    """Masked partial attention over gathered pages -> (o, l, m).

    q [B, H, D]; k_pages/v_pages [B, mp, page, KVH, D];
    token_valid [B, mp, page] bool — which gathered slots are real tokens of
    this request (ownership x ctx mask, computed by the caller).
    Returns fp32 partials: o [B, H, D], l [B, H], m [B, H] for the stable
    cross-shard merge (the EPU aggregation of ITPP).
    """
    B, mp, page, KVH, D = k_pages.shape
    H = q.shape[1]
    G = H // KVH
    # keep gathered pages in their storage dtype: the dot accumulates fp32
    # (preferred_element_type) without materializing fp32 copies of the KV
    # stream (EXPERIMENTS.md §Perf H2)
    qf = q.reshape(B, KVH, G, D)
    kf = k_pages.reshape(B, mp * page, KVH, D)
    vf = v_pages.reshape(B, mp * page, KVH, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, kf,
                   preferred_element_type=jnp.float32) / jnp.sqrt(
                       jnp.float32(D))
    mask = token_valid.reshape(B, 1, 1, mp * page)
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)                                    # [B,KVH,G]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_pages.dtype), vf,
                   preferred_element_type=jnp.float32)
    return (o.reshape(B, H, D), l.reshape(B, H), m.reshape(B, H))


def merge_partials(o, l, m, *, axis=None):
    """Stable softmax merge of shard partials (paper's ITPP/EPU aggregation).

    With ``axis`` (a mesh axis name or tuple) merges across shards via
    collectives; with axis=None merges a leading stacked dim instead
    (single-device reference; o [N, B, H, D] etc.).
    """
    if axis is None:
        mg = m.max(axis=0)
        corr = jnp.exp(m - mg[None])
        lg = (l * corr).sum(axis=0)
        og = (o * corr[..., None]).sum(axis=0)
    else:
        mg = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - mg)
        lg = jax.lax.psum(l * corr, axis)
        og = jax.lax.psum(o * corr[..., None], axis)
    return og / jnp.maximum(lg, 1e-30)[..., None]
