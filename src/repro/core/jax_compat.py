"""Version-portable wrappers for jax APIs that moved between releases.

The sharded runtime (core/itpp.py, distributed/*) is written against the
newer ``jax.shard_map``/``jax.make_mesh(axis_types=...)`` surface; on the
pinned jax (0.4.x) those live under ``jax.experimental.shard_map`` with
different keyword names (``check_rep``/``auto`` instead of
``check_vma``/``axis_names``) and ``jax.sharding.AxisType`` does not exist.
Everything in-repo goes through these wrappers so a jax upgrade is a no-op.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across its two constructor signatures:
    jax <= 0.4.x takes one ``((name, size), ...)`` tuple; newer jax takes
    ``(sizes, names)`` positionally."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
    except TypeError:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))


def shard_map(f, *, mesh=None, in_specs, out_specs, check_vma: bool = False,
              axis_names=None):
    """Portable ``shard_map``.

    ``axis_names`` is the newer partial-manual spelling (the set of axes the
    body is manual over); on older jax it maps onto ``auto = mesh axes -
    axis_names``, which requires an explicit mesh.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(in_specs=in_specs, out_specs=out_specs, check_vma=check_vma)
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    assert mesh is not None, \
        "jax<0.5 shard_map needs an explicit mesh (no ambient-mesh form)"
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)
