"""int8 weight-only quantization for serving.

Symmetric per-output-channel int8: a weight [.., D_in, D_out] becomes
``{"q": int8 [.., D_in, D_out], "s": f32 [.., 1, D_out]}``. Dequantization
happens per-layer inside the decode/prefill scan (the int8 tensor is what
streams from HBM — decode is weight-bandwidth-bound, so this is a ~2x
decode-throughput lever and the difference between mixtral-8x22b fitting a
single v5e pod (17.2 -> ~9.6 GiB/dev) or not; EXPERIMENTS.md §Perf Q1).

Quantized leaves keep the original pytree paths with a trailing "q"/"s" so
the sharding rules apply unchanged (distributed/sharding.py strips the
suffix when matching names).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# weight names worth quantizing (large matmul operands on the serve path)
QUANT_NAMES = {"wq", "wk", "wv", "wo", "w1", "w2", "w3", "wz", "wx",
               "wu", "wg", "out_proj", "down", "head", "up", "proj"}


def is_qtensor(w) -> bool:
    return isinstance(w, dict) and set(w) == {"q", "s"}


def quantize_tensor(w: jax.Array) -> dict:
    """[.., D_in, D_out] -> int8 + per-out-channel scale."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(wf / s), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s}


def dequantize_tensor(qw: dict, dtype=jnp.bfloat16) -> jax.Array:
    return (qw["q"].astype(jnp.float32) * qw["s"]).astype(dtype)


def quantize_params(params, *, names=QUANT_NAMES, min_size: int = 1 << 16):
    """Quantize matching >=2D weight leaves; everything else passes through."""
    def walk(node, key=None):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if (key in names and hasattr(node, "ndim") and node.ndim >= 2
                and node.size >= min_size):
            return quantize_tensor(node)
        return node
    return walk(params)


def quantized_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
