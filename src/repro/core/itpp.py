"""ITPP — intra-module token-parallel partitioning, on a TPU mesh.

The paper's §4.3: shard the K/V-cache over the *token* dimension (not heads,
not batch), compute partial attention per shard, and aggregate the softmax
*inside the module* with a numerically stable merge. Head count and batch
size never constrain parallelism — the fix for HFA's channel imbalance.

Here a "PIM module" is a mesh shard. The paged pool's page axis is sharded
over ``page_axes`` (usually ``('data','model')``); each shard

 1. writes the incoming token's K/V if it owns the target page,
 2. translates the global Va2Pa block table to its local pages (compaction),
 3. gathers its pages and computes masked partial attention (o, l, m),
 4. merges partials across ``merge_axes`` in log-sum-exp form
    (``merge_partials`` — the EPU aggregation).

Requests either stripe pages across a data-row's model shards (decode_32k:
batch also sharded over 'data', merge over 'model' only) or across the whole
pod (long_500k: batch=1 replicated, merge over both axes) — the allocator's
``row_affine`` / ``striped`` policies (core/allocator.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.jax_compat import shard_map
from repro.core.paged_kv import merge_partials, partial_decode_attention
from repro.kernels.backend import KernelConfig


@dataclass(frozen=True)
class ItppSpec:
    page_axes: tuple[str, ...]      # mesh axes sharding the pool's page dim
    merge_axes: tuple[str, ...]     # axes to merge partials over
    batch_axis: str | None          # axis sharding the request batch (or None)
    n_page_shards: int              # product of page_axes sizes
    stripe: int                     # shards each request stripes over
    page_size: int

    def max_local_pages(self, max_pages_per_req: int) -> int:
        return -(-max_pages_per_req // self.stripe) + 1


def _my_page_shard(spec: ItppSpec, mesh_axis_sizes: dict[str, int]):
    """Linear shard index over the page axes (row-major over page_axes)."""
    idx = jnp.int32(0)
    for ax in spec.page_axes:
        idx = idx * mesh_axis_sizes[ax] + jax.lax.axis_index(ax)
    return idx


def itpp_decode_attention_shard(q, k_new, v_new, pool_k, pool_v, block_table,
                                ctx_len, new_page, new_off, window=0, *,
                                spec: ItppSpec,
                                mesh_axis_sizes: dict[str, int],
                                max_pages_per_req: int,
                                ring_width: int = 0,
                                cond_window: int = 0,
                                kernels: KernelConfig | None = None):
    """shard_map body (or single-device when spec.page_axes == ()).

    q [B,H,D]; k_new/v_new [B,KVH,D]; pool_{k,v} [P_loc, page, KVH, D];
    block_table [B, maxp] (GLOBAL page ids, -1 pad); ctx_len [B] incl. the
    current token; new_page/new_off [B] global write target; ``window`` may
    be a traced scalar (0 = full attention).

    ``cond_window``: for mixed local:global stacks (gemma3), the per-layer
    traced ``window`` selects between two widths via lax.cond — windowed
    layers touch only the pages overlapping the (static-size) window
    instead of the full context (EXPERIMENTS.md §Perf H3).

    ``kernels``: when it resolves to ``use_pallas``, the shard-local compute
    is ``kernels.paged_attention.paged_attention_partials`` — K/V pages
    stream straight from the pool with dead pages (unowned / beyond ctx /
    out of window / unwritten ring slots) skipped in-kernel, so neither the
    [B, mp, page, KVH, D] gathered copy nor its HBM traffic exists. The
    incoming token's K/V scatter rides the same dispatch (the kernel reads
    the post-write pool). ``None`` (and off-TPU autodetect) keeps the
    gather-then-dense reference math below — identical semantics, tested.
    Returns (out [B,H,D], pool_k, pool_v).
    """
    B, maxp = block_table.shape
    P_loc, page = pool_k.shape[0], pool_k.shape[1]
    sharded = bool(spec.page_axes)
    my = _my_page_shard(spec, mesh_axis_sizes) if sharded else jnp.int32(0)

    # ---- 1. write the incoming token where owned --------------------------
    owned_w = (new_page // P_loc) == my
    loc_w = jnp.where(owned_w, new_page - my * P_loc, P_loc)     # OOB -> drop
    pool_k = pool_k.at[loc_w, new_off].set(k_new.astype(pool_k.dtype),
                                           mode="drop")
    pool_v = pool_v.at[loc_w, new_off].set(v_new.astype(pool_v.dtype),
                                           mode="drop")

    owned = (block_table >= 0) & ((block_table // P_loc) == my)  # [B,maxp]
    vpage = jnp.broadcast_to(jnp.arange(maxp, dtype=jnp.int32)[None], (B, maxp))
    w = jnp.asarray(window, jnp.int32)

    kc = kernels.resolve() if kernels is not None else None
    if kc is not None and kc.use_pallas:
        from repro.kernels.paged_attention import paged_attention_partials
        from repro.kernels.ref import combine_partials
        H = q.shape[1]
        KVH = pool_k.shape[2]
        bt_loc = jnp.where(owned, block_table - my * P_loc, -1)

        def kernel_partial(mp_width: int, window_only: bool):
            if window_only:
                # windowed gather bound (cond_window trick): pass only the
                # table slots overlapping the window; slot j resolves to
                # virtual page lo+j in-kernel with the SAME lo formula
                lo = jnp.maximum(ctx_len - w, 0) // page          # [B]
                sel = lo[:, None] + jnp.arange(mp_width,
                                               dtype=jnp.int32)[None]
                btk = jnp.take_along_axis(bt_loc,
                                          jnp.clip(sel, 0, maxp - 1), axis=1)
                btk = jnp.where(sel < maxp, btk, -1)
            else:
                btk = bt_loc
            o4, l4, m4 = paged_attention_partials(
                q.reshape(B, KVH, H // KVH, -1), pool_k, pool_v, btk,
                ctx_len, window=w, ring_width=ring_width,
                windowed_slice=window_only, n_splits=kc.n_splits,
                interpret=kc.interpret)
            o4, l4, m4 = combine_partials(o4, l4, m4)
            return (o4.reshape(B, H, -1), l4.reshape(B, H),
                    m4.reshape(B, H))

        if cond_window > 0:
            win_pages = min(cond_window // page + 2, maxp)
            o, l, m = jax.lax.cond(
                w > 0,
                lambda: kernel_partial(win_pages, True),
                lambda: kernel_partial(maxp, False))
        else:
            o, l, m = kernel_partial(maxp, False)
    else:
        def gather_partial(mp_width: int, window_only: bool):
            """Va2Pa compaction -> gather -> masked partials, static width."""
            # ---- 2. compaction: prioritize owned (and in-window) pages ----
            pri = owned
            if window_only:
                lo_page = jnp.maximum(ctx_len[:, None] - w, 0) // page
                pri = owned & (vpage >= lo_page)
            order = jnp.argsort(jnp.where(pri, vpage, maxp + vpage), axis=1,
                                stable=True)
            sel = order[:, :mp_width]
            bt_loc = jnp.take_along_axis(block_table, sel, axis=1) \
                - my * P_loc
            vp_loc = jnp.take_along_axis(vpage, sel, axis=1)
            ok_loc = jnp.take_along_axis(pri, sel, axis=1)       # [B,mp]
            bt_safe = jnp.where(ok_loc, bt_loc, 0)

            # ---- 3. gather + masked partial attention --------------------
            k_pages = pool_k[bt_safe]         # [B, mp, page, KVH, D]
            v_pages = pool_v[bt_safe]
            if ring_width:
                cur_vp = ((ctx_len - 1) // page)[:, None]
                abs_vp = cur_vp - ((cur_vp - vp_loc) % ring_width)
                ok_loc2 = ok_loc & (abs_vp >= 0)
                vp_eff = abs_vp
            else:
                ok_loc2, vp_eff = ok_loc, vp_loc
            tok = vp_eff[:, :, None] * page + jnp.arange(page)[None, None, :]
            valid = ok_loc2[:, :, None] & (tok < ctx_len[:, None, None])
            valid = valid & ((w <= 0)
                             | (tok >= (ctx_len[:, None, None] - w)))
            return partial_decode_attention(q, k_pages, v_pages, valid)

        mp_full = min(spec.max_local_pages(max_pages_per_req), maxp)
        if cond_window > 0:
            win_pages = cond_window // page + 2      # pages spanning a window
            mp_win = min(-(-win_pages // spec.stripe) + 1, maxp)
            o, l, m = jax.lax.cond(
                w > 0,
                lambda: gather_partial(mp_win, True),
                lambda: gather_partial(mp_full, False))
        else:
            o, l, m = gather_partial(mp_full, False)

    # ---- 4. stable merge (EPU aggregation) -------------------------------
    if sharded and spec.merge_axes:
        out = merge_partials(o, l, m, axis=spec.merge_axes)
    else:
        out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype), pool_k, pool_v


def make_prefill_writer(mesh, spec: ItppSpec, *, seq_axis: str):
    """Shard-LOCAL prefill pool writes (§Perf P1).

    With the allocator's ``blocked_chunk`` policy, virtual page v of a
    request lives on the shard owning sequence block v — exactly the shard
    that computed those tokens' K/V under sequence-parallel prefill. The
    scatter then never crosses shards: without this, XLA all-gathers the
    full K/V of every layer to every device (measured 992 GiB/device/step in
    fp32 for gemma3-27b prefill_32k).

    Returns f(pool_k_l, pool_v_l, k, v, bt) -> (pool_k_l, pool_v_l) where
    k/v are [B, S, KVH, D] sequence-sharded over ``seq_axis``.
    """
    sizes = dict(mesh.shape) if mesh is not None else {}
    n_seq = sizes.get(seq_axis, 1)

    def body(pool_k, pool_v, k, v, bt):
        B, S_loc = k.shape[0], k.shape[1]
        P_loc, page = pool_k.shape[0], pool_k.shape[1]
        my = _my_page_shard(spec, sizes) if spec.page_axes else jnp.int32(0)
        seq_i = jax.lax.axis_index(seq_axis) if spec.page_axes else 0
        t = seq_i * S_loc + jnp.arange(S_loc)
        vpage = t // page
        off = t % page
        pids = jnp.take_along_axis(
            bt, jnp.broadcast_to(vpage[None], (B, S_loc)), axis=1)
        owned = (pids >= 0) & ((pids // P_loc) == my)
        loc = jnp.where(owned, pids - my * P_loc, P_loc)        # OOB -> drop
        offs = jnp.broadcast_to(off[None], (B, S_loc))
        pool_k = pool_k.at[loc, offs].set(k.astype(pool_k.dtype), mode="drop")
        pool_v = pool_v.at[loc, offs].set(v.astype(pool_v.dtype), mode="drop")
        return pool_k, pool_v

    if mesh is None or not spec.page_axes:
        return body
    b = spec.batch_axis
    pool_spec = P(spec.page_axes, None, None, None)
    kv = P(b, seq_axis, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(pool_spec, pool_spec, kv, kv, P(b, None)),
        out_specs=(pool_spec, pool_spec), check_vma=False)


def make_itpp_attention(mesh, spec: ItppSpec, *, max_pages_per_req: int,
                        ring_width: int = 0, cond_window: int = 0,
                        kernels: KernelConfig | None = None):
    """Build the jit-composable sharded attention op.

    Returns f(q, k_new, v_new, pool_k, pool_v, bt, ctx, new_page, new_off,
    window) -> (out, pool_k, pool_v), wrapped in shard_map over the mesh (or
    plain when mesh is None — single-device tests). ``window`` may be traced.
    ``kernels`` picks the shard-local compute (see
    ``itpp_decode_attention_shard``).
    """
    sizes = dict(mesh.shape) if mesh is not None else {}
    body = partial(itpp_decode_attention_shard, spec=spec,
                   mesh_axis_sizes=sizes, max_pages_per_req=max_pages_per_req,
                   ring_width=ring_width, cond_window=cond_window,
                   kernels=kernels)
    if mesh is None or not spec.page_axes:
        return body

    b = spec.batch_axis
    qspec = P(b, None, None)
    kvspec = P(b, None, None)
    bspec = P(b, None)
    cspec = P(b)
    pool_spec = P(spec.page_axes, None, None, None)
    out_specs = (qspec, pool_spec, pool_spec)
    in_specs = (qspec, kvspec, kvspec, pool_spec, pool_spec, bspec, cspec,
                cspec, cspec, P())
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)
