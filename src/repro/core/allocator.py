"""Host-side lazy page allocator — the DPA controller's Va2Pa bookkeeping.

The paper's on-module dispatcher maps virtual KV-chunk indices to physical
DRAM rows and allocates chunks lazily as requests grow (§5.4). Here the
physical space is the device page pool (``core/paged_kv.py``), sharded over
mesh shards; the allocator hands out page ids so that

* a request's pages stripe **round-robin across shards** (ITPP balance), and
* under ``row_affine`` policy a request only uses pages owned by its data-row
  (decode batches sharded over the ``data`` axis), while ``striped`` uses the
  whole pod (long-context, batch=1).

Pages are **refcounted** so the prefix cache (``repro.kvcache``) can share
physical pages across requests and keep finished requests' KV alive in its
radix tree: ``admit_shared`` registers a request whose leading pages are
borrowed references, ``incref``/``decref`` manage extra owners, and a page
only returns to the free lists when its last owner lets go. A pluggable
``reclaimer`` hook (the cache) is consulted when the pool runs dry — cold
cached pages are evicted/offloaded on demand, and ``available_pages`` counts
them as admission capacity.

Pure numpy/host code — this runs in the serving loop between device steps,
exactly like the paper's host updating the Va2Pa table each iteration.
"""
from __future__ import annotations

import numpy as np


class PageAllocator:
    def __init__(self, n_pages: int, n_shards: int, page_size: int, *,
                 policy: str = "striped", n_rows: int = 1,
                 static_max_pages: int | None = None,
                 ring_pages: int | None = None,
                 blocked_chunk: int | None = None):
        assert n_pages % n_shards == 0, (n_pages, n_shards)
        assert policy in ("striped", "row_affine")
        assert n_shards % n_rows == 0
        self.n_pages = n_pages
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        self.page_size = page_size
        self.policy = policy
        self.n_rows = n_rows
        self.shards_per_row = n_shards // n_rows
        # static_max_pages: baseline-PIM behaviour — reserve the max-context
        # page count at admission (the paper's static allocation strawman).
        self.static_max_pages = static_max_pages
        # ring_pages: sliding-window pools — a request never needs more than
        # this many pages; virtual slots beyond it recycle (mod ring_pages)
        self.ring_pages = ring_pages
        # blocked_chunk: virtual page v targets shard cycle[(v//chunk) %
        # n_cycle] — contiguous runs per shard align page ownership with the
        # sequence-sharded prefill writes so the pool scatter is shard-LOCAL
        # (zero collectives; EXPERIMENTS.md §Perf P1). Balance across shards
        # is preserved (each shard still holds ~maxp/stripe pages/request).
        self.blocked_chunk = blocked_chunk
        # per-shard free lists (a page's shard = page // pages_per_shard,
        # matching jax's contiguous sharding of the pool's page axis)
        self._free: list[list[int]] = [
            list(range(s * self.pages_per_shard + self.pages_per_shard - 1,
                       s * self.pages_per_shard - 1, -1))
            for s in range(n_shards)]
        self._tables: dict[int, list[int]] = {}   # req -> Va2Pa (virtual order)
        self._rr: dict[int, int] = {}             # req -> round-robin cursor
        self._row: dict[int, int] = {}
        self._refs: dict[int, int] = {}           # page -> owner count (>0)
        # reclaimer: object with ``reclaimable() -> int`` and
        # ``reclaim(n) -> int`` (pages actually freed). Set by the prefix
        # cache; consulted on exhaustion before raising MemoryError and when
        # counting admission capacity.
        self.reclaimer = None

    # ------------------------------------------------------------------
    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    def row_of_request(self, req: int) -> int | None:
        return self._row.get(req)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - sum(len(f) for f in self._free)

    def free_pages_in_row(self, row: int) -> int:
        lo = row * self.shards_per_row
        return sum(len(self._free[s]) for s in range(lo, lo + self.shards_per_row))

    @property
    def free_page_count(self) -> int:
        return sum(len(f) for f in self._free)

    def available_pages(self, row: int | None = None) -> int:
        """Admission capacity: free pages plus whatever the reclaimer could
        evict on demand (cold cached pages). Row-affine counts only the
        row's free pages plus the global reclaimable pool (reclaim does not
        target a specific row, so this is an optimistic bound)."""
        free = self.free_pages_in_row(row) if row is not None \
            else self.free_page_count
        if self.reclaimer is not None:
            free += self.reclaimer.reclaimable()
        return free

    def ref_of(self, page: int) -> int:
        return self._refs.get(page, 0)

    def pages_of(self, req: int) -> list[int]:
        """The request's Va2Pa table (copy, virtual order)."""
        return list(self._tables[req])

    # ------------------------------------------------------------------
    def _shard_cycle(self, req: int) -> list[int]:
        if self.policy == "row_affine":
            row = self._row[req]
            lo = row * self.shards_per_row
            return list(range(lo, lo + self.shards_per_row))
        return list(range(self.n_shards))

    def can_admit(self, n_tokens: int, row: int | None = None,
                  cached_pages: int = 0) -> bool:
        """``cached_pages``: pages the request would borrow from the prefix
        cache instead of allocating (reduces the need)."""
        need = self._pages_for(n_tokens)
        if self.static_max_pages is not None:
            need = self.static_max_pages
        need = max(0, need - cached_pages)
        if self.policy == "row_affine":
            assert row is not None
        return self.available_pages(row if self.policy == "row_affine"
                                    else None) >= need

    def _pages_for(self, n_tokens: int) -> int:
        n = max(1, -(-n_tokens // self.page_size))
        return min(n, self.ring_pages) if self.ring_pages else n

    def admit(self, req: int, n_tokens: int, row: int | None = None) -> list[int]:
        """Allocate pages for a request's first n_tokens (the prefill).

        Under static mode reserves static_max_pages regardless of n_tokens —
        the baseline the paper's lazy allocation beats.
        """
        return self.admit_shared(req, (), n_tokens, row)

    def admit_shared(self, req: int, shared_pages, n_tokens: int,
                     row: int | None = None) -> list[int]:
        """Admit ``req`` whose leading pages are borrowed references to
        already-resident pages (a prefix-cache hit): each shared page gets an
        extra owner, and only the remainder of the prompt footprint is
        allocated fresh. With ``shared_pages=()`` this is plain ``admit``."""
        assert req not in self._tables, req
        shared = list(shared_pages)
        if shared:
            assert self.static_max_pages is None and self.ring_pages is None, \
                "prefix sharing is incompatible with static/ring allocation"
        if self.policy == "row_affine":
            assert row is not None
            self._row[req] = row
        self._tables[req] = []
        self._rr[req] = 0
        try:
            for p in shared:
                self.incref(p)
                self._tables[req].append(p)
            need = self._pages_for(n_tokens) - len(shared)
            if self.static_max_pages is not None:
                need = self.static_max_pages
            if need > 0:
                self._grow(req, need)
        except MemoryError:
            self.free(req)              # release borrowed refs + fresh pages
            raise
        if shared:
            self._notify_reclaimer()    # borrowed pages gained an owner
        return list(self._tables[req])

    def ensure(self, req: int, n_tokens: int, *,
               reclaim: bool = True) -> list[int]:
        """Lazy growth: make sure the request can hold n_tokens; returns any
        newly allocated pages (usually 0 or 1 per decode step). Shrink-safe:
        asking for fewer tokens than already covered is a no-op (pages are
        only released by ``free``), and non-positive token counts are treated
        as the minimum footprint. ``reclaim=False`` grows from the free
        lists only — a MemoryError then means "would have to evict cached
        pages", letting gentle horizon reservation degrade instead of
        churning the radix cache (committed per-token growth still
        reclaims)."""
        need = self._pages_for(n_tokens)
        have = len(self._tables[req])
        if self.static_max_pages is not None and need > have:
            raise MemoryError(
                f"req {req} exceeded static reservation ({need} > {have})")
        if need <= have:
            return []
        return self._grow(req, need - have, reclaim=reclaim)

    def _pop_page(self, req: int) -> int | None:
        """One page off the free lists, honoring placement policy; None when
        the request's shard cycle is exhausted."""
        cycle = self._shard_cycle(req)
        if self.blocked_chunk:
            v = len(self._tables[req])              # virtual page index
            start = (v // self.blocked_chunk) % len(cycle)
        else:
            start = self._rr[req]
        for i in range(len(cycle)):
            s = cycle[(start + i) % len(cycle)]
            if self._free[s]:
                page = self._free[s].pop()
                if not self.blocked_chunk:
                    self._rr[req] = (start + i + 1) % len(cycle)
                return page
        return None

    def _grow(self, req: int, count: int, *,
              reclaim: bool = True) -> list[int]:
        new = []
        for _ in range(count):
            page = self._pop_page(req)
            if page is None and reclaim and self.reclaimer is not None:
                # pool exhausted: ask the cache to evict/offload cold pages,
                # then retry (the paper's DPA never stalls on static waste;
                # here the capacity tier absorbs the overflow instead)
                if self.reclaimer.reclaim(count - len(new)) > 0:
                    page = self._pop_page(req)
            if page is None:
                # roll back this grow to keep state consistent
                for p in new:
                    self._tables[req].pop()
                    del self._refs[p]
                    self._free[self.shard_of(p)].append(p)
                raise MemoryError("page pool exhausted")
            self._refs[page] = 1
            self._tables[req].append(page)
            new.append(page)
        return new

    # ------------------------------------------------------------------
    def alloc_pages(self, count: int) -> list[int]:
        """Raw tree-owned allocation (no request table) — used by the prefix
        cache to back swap-ins. Consults the reclaimer on exhaustion like
        ``_grow`` (cold cached pages make room for hot swap-ins). Pages come
        back with refcount 1; the caller owns the reference and releases via
        ``decref``."""
        new: list[int] = []
        for _ in range(count):
            page = self._pop_any()
            if page is None and self.reclaimer is not None:
                if self.reclaimer.reclaim(count - len(new)) > 0:
                    page = self._pop_any()
            if page is None:
                for p in new:
                    del self._refs[p]
                    self._free[self.shard_of(p)].append(p)
                raise MemoryError("page pool exhausted")
            self._refs[page] = 1
            new.append(page)
        return new

    def _pop_any(self) -> int | None:
        for s in range(self.n_shards):
            if self._free[s]:
                return self._free[s].pop()
        return None

    def incref(self, page: int) -> None:
        """Add an owner to a resident page (prefix sharing / tree retention)."""
        if page not in self._refs:
            raise ValueError(f"incref of unallocated page {page}")
        self._refs[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one owner; frees the page when the last owner lets go.
        Returns True iff the page went back to the free lists."""
        ref = self._refs.get(page)
        if ref is None:
            raise ValueError(f"decref of free page {page} (double free?)")
        if ref > 1:
            self._refs[page] = ref - 1
            return False
        del self._refs[page]
        self._free[self.shard_of(page)].append(page)
        return True

    def free(self, req: int) -> int:
        """Release all of a finished request's page references (EOS). Pages
        shared with the prefix cache or other requests survive; exclusively
        owned ones return to the free lists. Returns the number of pages
        actually freed. Unknown / already-freed request ids raise — the
        serving loop must never double-free (it would silently hand a live
        request's pages to the next admission)."""
        if req not in self._tables:
            raise KeyError(
                f"PageAllocator.free: unknown or already-freed request {req}")
        pages = self._tables.pop(req)
        self._rr.pop(req, None)
        self._row.pop(req, None)
        freed = sum(1 for p in pages if self.decref(p))
        # pages the request shared with the cache just lost an owner — the
        # reclaimable-capacity memo must see the new refcounts
        self._notify_reclaimer()
        return freed

    def _notify_reclaimer(self) -> None:
        """Invalidate the reclaimer's capacity memo after a refcount
        change. Duck-typed: reclaimers without a ``_mutated`` hook (test
        stubs, custom policies) just recompute on the next query."""
        m = getattr(self.reclaimer, "_mutated", None)
        if m is not None:
            m()

    # ------------------------------------------------------------------
    def block_table(self, req: int, width: int) -> np.ndarray:
        """Va2Pa row for the device block table, -1-padded to ``width``."""
        t = self._tables[req]
        assert len(t) <= width, (len(t), width)
        out = np.full((width,), -1, np.int32)
        out[:len(t)] = t
        return out

    def shard_balance(self) -> np.ndarray:
        """Pages in use per shard — ITPP balance metric (tested: max-min <= small)."""
        used = np.full((self.n_shards,), self.pages_per_shard, np.int64)
        for s, f in enumerate(self._free):
            used[s] -= len(f)
        return used
