"""Host-side lazy page allocator — the DPA controller's Va2Pa bookkeeping.

The paper's on-module dispatcher maps virtual KV-chunk indices to physical
DRAM rows and allocates chunks lazily as requests grow (§5.4). Here the
physical space is the device page pool (``core/paged_kv.py``), sharded over
mesh shards; the allocator hands out page ids so that

* a request's pages stripe **round-robin across shards** (ITPP balance), and
* under ``row_affine`` policy a request only uses pages owned by its data-row
  (decode batches sharded over the ``data`` axis), while ``striped`` uses the
  whole pod (long-context, batch=1).

Pure numpy/host code — this runs in the serving loop between device steps,
exactly like the paper's host updating the Va2Pa table each iteration.
"""
from __future__ import annotations

import numpy as np


class PageAllocator:
    def __init__(self, n_pages: int, n_shards: int, page_size: int, *,
                 policy: str = "striped", n_rows: int = 1,
                 static_max_pages: int | None = None,
                 ring_pages: int | None = None,
                 blocked_chunk: int | None = None):
        assert n_pages % n_shards == 0, (n_pages, n_shards)
        assert policy in ("striped", "row_affine")
        assert n_shards % n_rows == 0
        self.n_pages = n_pages
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        self.page_size = page_size
        self.policy = policy
        self.n_rows = n_rows
        self.shards_per_row = n_shards // n_rows
        # static_max_pages: baseline-PIM behaviour — reserve the max-context
        # page count at admission (the paper's static allocation strawman).
        self.static_max_pages = static_max_pages
        # ring_pages: sliding-window pools — a request never needs more than
        # this many pages; virtual slots beyond it recycle (mod ring_pages)
        self.ring_pages = ring_pages
        # blocked_chunk: virtual page v targets shard cycle[(v//chunk) %
        # n_cycle] — contiguous runs per shard align page ownership with the
        # sequence-sharded prefill writes so the pool scatter is shard-LOCAL
        # (zero collectives; EXPERIMENTS.md §Perf P1). Balance across shards
        # is preserved (each shard still holds ~maxp/stripe pages/request).
        self.blocked_chunk = blocked_chunk
        # per-shard free lists (a page's shard = page // pages_per_shard,
        # matching jax's contiguous sharding of the pool's page axis)
        self._free: list[list[int]] = [
            list(range(s * self.pages_per_shard + self.pages_per_shard - 1,
                       s * self.pages_per_shard - 1, -1))
            for s in range(n_shards)]
        self._tables: dict[int, list[int]] = {}   # req -> Va2Pa (virtual order)
        self._rr: dict[int, int] = {}             # req -> round-robin cursor
        self._row: dict[int, int] = {}

    # ------------------------------------------------------------------
    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    def row_of_request(self, req: int) -> int | None:
        return self._row.get(req)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - sum(len(f) for f in self._free)

    def free_pages_in_row(self, row: int) -> int:
        lo = row * self.shards_per_row
        return sum(len(self._free[s]) for s in range(lo, lo + self.shards_per_row))

    @property
    def free_page_count(self) -> int:
        return sum(len(f) for f in self._free)

    # ------------------------------------------------------------------
    def _shard_cycle(self, req: int) -> list[int]:
        if self.policy == "row_affine":
            row = self._row[req]
            lo = row * self.shards_per_row
            return list(range(lo, lo + self.shards_per_row))
        return list(range(self.n_shards))

    def can_admit(self, n_tokens: int, row: int | None = None) -> bool:
        need = self._pages_for(n_tokens)
        if self.static_max_pages is not None:
            need = self.static_max_pages
        if self.policy == "row_affine":
            assert row is not None
            return self.free_pages_in_row(row) >= need
        return self.free_page_count >= need

    def _pages_for(self, n_tokens: int) -> int:
        n = max(1, -(-n_tokens // self.page_size))
        return min(n, self.ring_pages) if self.ring_pages else n

    def admit(self, req: int, n_tokens: int, row: int | None = None) -> list[int]:
        """Allocate pages for a request's first n_tokens (the prefill).

        Under static mode reserves static_max_pages regardless of n_tokens —
        the baseline the paper's lazy allocation beats.
        """
        assert req not in self._tables
        if self.policy == "row_affine":
            assert row is not None
            self._row[req] = row
        self._tables[req] = []
        self._rr[req] = 0
        need = self._pages_for(n_tokens)
        if self.static_max_pages is not None:
            need = self.static_max_pages
        self._grow(req, need)
        return list(self._tables[req])

    def ensure(self, req: int, n_tokens: int) -> list[int]:
        """Lazy growth: make sure the request can hold n_tokens; returns any
        newly allocated pages (usually 0 or 1 per decode step)."""
        need = self._pages_for(n_tokens)
        have = len(self._tables[req])
        if self.static_max_pages is not None and need > have:
            raise MemoryError(
                f"req {req} exceeded static reservation ({need} > {have})")
        return self._grow(req, need - have) if need > have else []

    def _grow(self, req: int, count: int) -> list[int]:
        new = []
        cycle = self._shard_cycle(req)
        for _ in range(count):
            placed = False
            if self.blocked_chunk:
                v = len(self._tables[req])          # virtual page index
                start = (v // self.blocked_chunk) % len(cycle)
            else:
                start = self._rr[req]
            for i in range(len(cycle)):
                s = cycle[(start + i) % len(cycle)]
                if self._free[s]:
                    page = self._free[s].pop()
                    self._tables[req].append(page)
                    if not self.blocked_chunk:
                        self._rr[req] = (start + i + 1) % len(cycle)
                    new.append(page)
                    placed = True
                    break
            if not placed:
                # roll back this grow to keep state consistent
                for p in new:
                    self._tables[req].pop()
                    self._free[self.shard_of(p)].append(p)
                raise MemoryError("page pool exhausted")
        return new

    def free(self, req: int) -> int:
        """Release all pages of a finished request (EOS). Returns page count."""
        pages = self._tables.pop(req)
        self._rr.pop(req, None)
        self._row.pop(req, None)
        for p in pages:
            self._free[self.shard_of(p)].append(p)
        return len(pages)

    # ------------------------------------------------------------------
    def block_table(self, req: int, width: int) -> np.ndarray:
        """Va2Pa row for the device block table, -1-padded to ``width``."""
        t = self._tables[req]
        assert len(t) <= width, (len(t), width)
        out = np.full((width,), -1, np.int32)
        out[:len(t)] = t
        return out

    def shard_balance(self) -> np.ndarray:
        """Pages in use per shard — ITPP balance metric (tested: max-min <= small)."""
        used = np.full((self.n_shards,), self.pages_per_shard, np.int64)
        for s, f in enumerate(self._free):
            used[s] -= len(f)
        return used
