"""Back-compat shim: the decode engine now lives in ``repro.serving``.

The monolithic DecodeEngine was split into a layered package —
``repro.serving.engine`` (orchestration), ``.prefill`` (slot / batched /
chunked strategies), ``.policies`` (admission), ``.sampling`` (jitted
samplers). Import from ``repro.serving`` in new code.
"""
from repro.serving.engine import DecodeEngine, EngineConfig  # noqa: F401

__all__ = ["DecodeEngine", "EngineConfig"]
