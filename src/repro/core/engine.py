"""Decode engine: continuous batching + lazy paged allocation + the model.

The host loop mirrors the paper's Fig. 2(c): each iteration the host updates
the "configuration buffer" (block tables, context lengths, write targets) and
dispatches one compiled decode step; EOS requests release their pages and
their slot refills from the queue (Fig. 2(b)). Prefill for newly admitted
requests runs on the same weights.

This engine is the single-host functional version (used by tests, examples
and the lazy-allocation benchmark); launch/serve.py wraps it with the mesh
sharding plan for the production layout.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import PageAllocator
from repro.core.paged_kv import PoolSpec
from repro.core.scheduler import ContinuousBatcher, Request
from repro.models import model as MDL


@dataclass
class EngineConfig:
    n_slots: int
    page_size: int
    n_pages: int
    max_context: int
    n_shards: int = 1
    n_rows: int = 1
    policy: str = "striped"           # striped | row_affine
    static_alloc: bool = False        # baseline-PIM static max-ctx allocation
    eos_token: int = 1
    max_prefill: int = 64             # engine pads prompts to this


class DecodeEngine:
    def __init__(self, cfg, ecfg: EngineConfig, params=None, rt=None,
                 *, sample: Callable | None = None):
        self.cfg = cfg
        self.ecfg = ecfg
        self.rt = rt or MDL.DEFAULT_RT
        self.params = params if params is not None else MDL.init_params(
            cfg, jax.random.PRNGKey(0), jnp.float32)
        kinds = cfg.block_kinds()
        n_attn = cfg.n_layers if cfg.family == "encdec" else \
            sum(1 for k in kinds if k in ("attn", "local"))
        maxp = -(-ecfg.max_context // ecfg.page_size) + 1
        self.pool_spec = PoolSpec(
            max(n_attn, 1), ecfg.n_pages, ecfg.page_size, cfg.n_kv_heads,
            cfg.d_head, maxp, dtype="float32")
        static_pages = maxp if ecfg.static_alloc else None
        self.alloc = PageAllocator(
            ecfg.n_pages, ecfg.n_shards, ecfg.page_size, policy=ecfg.policy,
            n_rows=ecfg.n_rows, static_max_pages=static_pages)
        self.batcher = ContinuousBatcher(
            self.alloc, ecfg.n_slots, max_context=ecfg.max_context,
            n_rows=ecfg.n_rows)
        self.state = MDL.init_decode_state(cfg, self.pool_spec, ecfg.n_slots,
                                           dtype="float32")
        self.tokens = np.zeros((ecfg.n_slots,), np.int32)
        self.prompts: dict[int, np.ndarray] = {}
        self.outputs: dict[int, list[int]] = {}
        self.sample = sample or (lambda logits: np.argmax(logits, -1))
        self._decode_jit = None

    # ------------------------------------------------------------------
    def submit(self, req_id: int, prompt: np.ndarray,
               max_new_tokens: int) -> None:
        self.prompts[req_id] = np.asarray(prompt, np.int32)
        self.outputs[req_id] = []
        self.batcher.submit(Request(req_id, len(prompt), max_new_tokens))

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Run the prompt through the model into this slot's pages.

        The functional prefill writes whole-batch; for slot-wise admission we
        run a batch-1 prefill and merge its cache rows into the engine state.
        """
        prompt = self.prompts[req.req_id]
        bt = self.alloc.block_table(req.req_id, self.pool_spec.max_pages_per_req)
        state1 = MDL.init_decode_state(self.cfg, self.pool_spec, 1,
                                       dtype="float32")
        # share the pool so pages written land in the engine pool
        if "pool" in self.state:
            state1["pool"] = self.state["pool"]
        logits, state1 = MDL.prefill(
            self.cfg, self.params, state1, jnp.asarray(prompt[None]),
            jnp.asarray(bt[None]), rt=self.rt,
            frames=(jnp.zeros((1, self.cfg.enc_seq, self.cfg.d_model),
                              jnp.float32)
                    if self.cfg.family == "encdec" else None))
        if "pool" in self.state:
            self.state["pool"] = state1["pool"]
        for key in ("mamba", "mlstm", "slstm", "cross_k", "cross_v"):
            if key in self.state:
                def put(dst, src):
                    return dst.at[:, slot].set(src[:, 0])
                self.state[key] = jax.tree.map(put, self.state[key],
                                               state1[key])
        self.tokens[slot] = int(self.sample(np.asarray(logits)[0]))
        self.outputs[req.req_id].append(int(self.tokens[slot]))

    # ------------------------------------------------------------------
    def step(self, finished_mask=None):
        """One engine tick: admit+prefill, then one batched decode step."""
        admitted, active = self.batcher.step(finished_mask)
        for slot, req in admitted:
            req.generated = 1          # prefill emits the first token
            self._prefill_slot(slot, req)
        if not active:
            return np.zeros((self.ecfg.n_slots,), bool)
        E = self.ecfg
        ctx = self.batcher.context_lens()
        bt = self.batcher.block_tables(self.pool_spec.max_pages_per_req)
        npage = np.zeros((E.n_slots,), np.int32)
        noff = np.zeros((E.n_slots,), np.int32)
        W = self.pool_spec.max_pages_per_req
        for s in active:
            t = ctx[s] - 1             # slot of the token being written
            vp = t // E.page_size
            if self.rt.ring_width:
                vp = vp % self.rt.ring_width
            row = self.alloc.block_table(self.batcher.slots[s].req_id, W)
            npage[s] = row[vp]
            noff[s] = t % E.page_size
        if self._decode_jit is None:
            def fn(params, state, tokens, bt, ctx, npage, noff):
                return MDL.decode_step(self.cfg, params, state, tokens, bt,
                                       ctx, npage, noff, rt=self.rt)
            self._decode_jit = jax.jit(fn)
        logits, self.state = self._decode_jit(
            self.params, self.state, jnp.asarray(self.tokens),
            jnp.asarray(bt), jnp.asarray(ctx), jnp.asarray(npage),
            jnp.asarray(noff))
        logits = np.asarray(logits)
        finished = np.zeros((E.n_slots,), bool)
        for s in active:
            req = self.batcher.slots[s]
            nxt = int(self.sample(logits[s]))
            self.tokens[s] = nxt
            self.outputs[req.req_id].append(nxt)
            if nxt == E.eos_token or req.generated >= req.max_new_tokens:
                finished[s] = True
        return finished

    def run(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        finished = None
        for _ in range(max_steps):
            if self.batcher.done():
                break
            finished = self.step(finished)
        return self.outputs
