"""Continuous-batching scheduler with EOS replacement (paper Fig. 2(b)).

Slot-based: the decode batch has ``n_slots`` positions; when a request emits
EOS (or hits its token budget) its pages are freed and the slot is refilled
from the waiting queue in the same scheduling tick — the paper's
"Request-1 ... replaced with Request-5" flow. Works with either lazy (DPA)
or static (baseline) allocation, which is how the lazy-allocation benchmark
reproduces the paper's batch-size growth (Fig. 4(b), §5.4).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.allocator import PageAllocator


@dataclass
class Request:
    req_id: int
    prompt_len: int
    max_new_tokens: int
    arrived_at: int = 0
    generated: int = 0

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.generated


@dataclass
class SchedulerStats:
    steps: int = 0
    occupied_slot_steps: int = 0
    completed: int = 0
    admitted: int = 0
    preempted: int = 0
    batch_trace: list = field(default_factory=list)

    @property
    def avg_batch(self) -> float:
        return self.occupied_slot_steps / max(1, self.steps)


class ContinuousBatcher:
    def __init__(self, allocator: PageAllocator, n_slots: int, *,
                 max_context: int, n_rows: int = 1):
        self.alloc = allocator
        self.n_slots = n_slots
        self.max_context = max_context
        self.n_rows = n_rows
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.stats = SchedulerStats()

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _row_of_slot(self, slot: int) -> int:
        return slot * self.n_rows // self.n_slots

    def _try_admit(self) -> list[tuple[int, Request]]:
        """Fill empty slots from the queue. Returns [(slot, request)] newly
        admitted (the engine must run prefill for these)."""
        admitted = []
        for s in range(self.n_slots):
            if self.slots[s] is not None or not self.queue:
                continue
            req = self.queue[0]
            row = self._row_of_slot(s) if self.alloc.policy == "row_affine" else None
            if not self.alloc.can_admit(req.prompt_len, row):
                continue   # head-of-line blocked on memory; try next tick
            self.queue.popleft()
            self.alloc.admit(req.req_id, req.prompt_len, row)
            self.slots[s] = req
            self.stats.admitted += 1
            admitted.append((s, req))
        return admitted

    def step(self, finished_mask: np.ndarray | None = None):
        """One decode tick.

        ``finished_mask`` [n_slots] — which active slots finished on the
        *previous* step (EOS sampled / budget reached). Frees their pages,
        refills slots, lazily grows every active request by one token.
        Returns (admitted, active_slots).
        """
        if finished_mask is not None:
            for s in range(self.n_slots):
                if finished_mask[s] and self.slots[s] is not None:
                    self.alloc.free(self.slots[s].req_id)
                    self.stats.completed += 1
                    self.slots[s] = None
        admitted = self._try_admit()
        active = []
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated += 1
            if req.total_len <= self.max_context:
                try:
                    self.alloc.ensure(req.req_id, req.total_len)
                except MemoryError:
                    # pool exhausted mid-decode: preempt (free pages, requeue
                    # at the front for re-prefill of prompt+generated) — the
                    # lazy-allocation analogue of vLLM preemption
                    self.alloc.free(req.req_id)
                    req.prompt_len = req.total_len
                    req.max_new_tokens = max(1, req.max_new_tokens
                                             - req.generated)
                    req.generated = 0
                    self.queue.appendleft(req)
                    self.slots[s] = None
                    self.stats.preempted += 1
                    continue
            active.append(s)
        self.stats.steps += 1
        self.stats.occupied_slot_steps += len(active)
        self.stats.batch_trace.append(len(active))
        return admitted, active

    # ------------------------------------------------------------------
    def block_tables(self, width: int) -> np.ndarray:
        """Device block-table snapshot [n_slots, width]."""
        out = np.full((self.n_slots, width), -1, np.int32)
        for s, req in enumerate(self.slots):
            if req is not None:
                out[s] = self.alloc.block_table(req.req_id, width)
        return out

    def context_lens(self) -> np.ndarray:
        return np.asarray([0 if r is None else r.total_len
                           for r in self.slots], np.int32)

    def done(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
