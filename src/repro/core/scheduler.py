"""Continuous-batching scheduler with EOS replacement (paper Fig. 2(b)).

Slot-based: the decode batch has ``n_slots`` positions; when a request emits
EOS (or hits its token budget) its pages are freed and the slot is refilled
from the waiting queue in the same scheduling tick — the paper's
"Request-1 ... replaced with Request-5" flow. Works with either lazy (DPA)
or static (baseline) allocation, which is how the lazy-allocation benchmark
reproduces the paper's batch-size growth (Fig. 4(b), §5.4).

Three serving hooks (repro.serving builds on these):

* ``policy`` — admission is pluggable: a policy object picks which queued
  request fills an open slot (FCFS / SJF / memory-aware live in
  ``repro.serving.policies``). ``policy=None`` keeps the seed strict
  head-of-line FCFS scan.
* incrementally-maintained host snapshots — the [n_slots, width] block-table
  matrix and the context-length vector are updated page-by-page as requests
  are admitted / grown / freed instead of being rebuilt from the allocator
  dict every tick, so the engine's per-tick "configuration buffer" update
  (paper Fig. 2(c)) is O(changes), not O(slots x width).
* ``cache`` — an optional ``repro.kvcache.PrefixCache``: admission borrows
  the matched prefix pages (``admit_shared``) and records the resume depth
  on the request (``cached_len``); finished *and preempted* requests insert
  their written KV into the cache before freeing, so a preempted request
  resumes from cached pages instead of re-prefilling. ``cache_tokens(req,
  finished)`` is the engine-provided token-sequence oracle (the batcher
  itself never sees token ids).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.allocator import PageAllocator
from repro.runtime.faults import NULL_FAULTS


@dataclass
class Request:
    req_id: int
    prompt_len: int
    max_new_tokens: int
    arrived_at: int = 0
    generated: int = 0
    # chunked_prefill: this request prefills in chunks (DCS-style
    # interleave); prefill_done is False while chunks are still streaming —
    # the slot is occupied but excluded from decode.
    chunked_prefill: bool = False
    prefill_done: bool = True
    # cached_len: tokens of KV borrowed from the prefix cache at admission;
    # prefill starts at this depth (0 = cold).
    cached_len: int = 0
    # kv_written: the prompt's KV pages actually hold computed values (set
    # by the prefillers once the prompt is through the model) — guards the
    # cache-insert paths against adopting never-written pages when a request
    # is admitted and preempted in the same tick.
    kv_written: bool = False
    # SLO scheduling surface (PR 10): priority tier (higher = more urgent),
    # submission timestamp in the engine's clock frame, and the immutable
    # client-facing submission spec (serving.Request) policies and the
    # tracker read SLO targets from. The scheduler itself only sorts on
    # these; it never mutates the spec.
    priority: int = 0
    submit_t: float = 0.0
    spec: object = None

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.generated


@dataclass
class SchedulerStats:
    steps: int = 0
    occupied_slot_steps: int = 0
    completed: int = 0
    admitted: int = 0
    preempted: int = 0
    dedup_deferred: int = 0
    # lifecycle-hardening counters (PR 8): requests torn down before their
    # natural finish (client abort / deadline / quarantine / load shed) and
    # requests drained off a dead serving row into re-queued prefills.
    aborted: int = 0
    migrated: int = 0
    # policy-driven preemptions (SLO tier starvation), a subset of
    # ``preempted`` — pool-exhaustion preemptions are the remainder
    priority_preempted: int = 0
    batch_trace: list = field(default_factory=list)

    @property
    def avg_batch(self) -> float:
        return self.occupied_slot_steps / max(1, self.steps)


class ContinuousBatcher:
    def __init__(self, allocator: PageAllocator, n_slots: int, *,
                 max_context: int, n_rows: int = 1, policy=None,
                 bt_width: int | None = None, cache=None, cache_tokens=None):
        self.alloc = allocator
        self.n_slots = n_slots
        self.max_context = max_context
        self.n_rows = n_rows
        self.policy = policy
        # injectable time source: policies compute queue-waiting times and
        # SLO budgets from this (the engine threads its own clock here, so
        # virtual-time replay is deterministic end to end)
        self.clock = time.perf_counter
        # prefix cache + token oracle (see module docstring)
        self.cache = cache
        self.cache_tokens = cache_tokens
        # same-tick prefix dedup (see _dedup_defer); engines may disable
        self.dedup = True
        # telemetry events hook: an object with ``on_admit(req, slot)`` /
        # ``on_preempt(req, slot)`` / ``on_finish(req, slot)`` called at the
        # exact bookkeeping points (repro.telemetry.RequestTracker). None
        # (the default) costs one identity check per event — disabled
        # telemetry adds no work and no allocation here.
        self.events = None
        # recurrent-state hook: ``rstate_hook(req, slot, finished)`` fires
        # when a slot's pages are about to be released — preemption
        # (finished=False: the engine snapshots the recurrent carry + the
        # written KV pages so re-admission restores instead of recomputing,
        # mirroring the kvcache swap story) and completion (finished=True:
        # the engine drops any stored snapshot).
        self.rstate_hook = None
        # fault injection (repro.runtime.faults): the engine threads its
        # injector here so the scheduler can model allocator exhaustion
        # deterministically. NULL_FAULTS is the shared disabled no-op —
        # one bool attribute check per growth step.
        self.faults = NULL_FAULTS
        # per-tick memo of (tokens, dev_pages, host_pages) per queued
        # candidate: can_admit's capacity estimate and the dedup check
        # share one token materialization + tree walk. ``prefetch_peeks``
        # lets the fused engine warm it in the overlap window (radix walks
        # run while the device computes); _peeks_fresh keeps step() from
        # discarding a prefetched memo.
        self._peek_memo: dict[int, tuple] = {}
        self._peeks_fresh = False
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.stats = SchedulerStats()
        # host-side snapshots, maintained incrementally (see module docstring)
        self._bt_width = bt_width
        self._bt = (np.full((n_slots, bt_width), -1, np.int32)
                    if bt_width else None)
        self._npages = np.zeros((n_slots,), np.int32)
        self._ctx = np.zeros((n_slots,), np.int32)
        # slots whose snapshot changed since the engine last mirrored them to
        # the device (admission / growth / free / chunk completion). The
        # fused-decode engine consumes this via ``take_dirty`` and patches
        # ONLY these rows of its device-resident slot state — per-tick
        # config-buffer traffic is O(changes), never a full rebuild.
        self.dirty: set[int] = set(range(n_slots))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _row_of_slot(self, slot: int) -> int:
        return slot * self.n_rows // self.n_slots

    # ---- snapshot maintenance ----------------------------------------
    def _snap_admit(self, s: int, req: Request, pages: list[int]) -> None:
        self._npages[s] = len(pages)
        self._ctx[s] = req.prompt_len if req.prefill_done else 0
        if self._bt is not None:
            self._bt[s, :len(pages)] = pages
        self.dirty.add(s)

    def _snap_grow(self, s: int, new: list[int]) -> None:
        if new:
            n = int(self._npages[s])
            self._npages[s] = n + len(new)
            if self._bt is not None:
                self._bt[s, n:n + len(new)] = new
            self.dirty.add(s)

    def _snap_clear(self, s: int) -> None:
        self._npages[s] = 0
        self._ctx[s] = 0
        if self._bt is not None:
            self._bt[s, :] = -1
        self.dirty.add(s)

    def take_dirty(self) -> list[int]:
        """Slots whose snapshot changed since the last call (sorted); clears
        the set. The engine patches exactly these rows of its device-resident
        block-table/ctx/token/budget arrays before dispatching a horizon."""
        out = sorted(self.dirty)
        self.dirty.clear()
        return out

    def _preempt(self, s: int, req: Request) -> None:
        """Pool exhausted mid-decode: free pages, requeue at the front for
        re-prefill of the reconstructable context — the lazy-allocation
        analogue of vLLM preemption.

        The reconstructable context is prompt + *written* generated tokens:
        when anything was generated, the last sampled token's KV was never
        written (it re-enters as the next decode input after re-prefill),
        and ``generated`` was already incremented this tick for a token
        never sampled — hence total_len - 1, not total_len. The remaining
        budget keeps the request's total emission where it would have been
        without preemption (``- generated + 1``: a fresh incarnation emits
        max_new + 1 tokens — prefill emits the first — while a resumed one
        emits exactly max_new, one per decode tick).

        With a prefix cache the written context is *inserted* before the
        pages are released: the tree keeps them alive (or offloads them to
        the host tier under pressure), so the re-admission's lookup resumes
        from cache instead of re-prefilling — the swap-in-on-resume path.
        For recurrent/enc-dec families the ``rstate_hook`` plays the same
        role for the dense carry (and its written KV pages): snapshot
        before release so resume = restore, not recompute."""
        if self.rstate_hook is not None:
            self.rstate_hook(req, s, False)
        if req.generated:
            req.prompt_len = req.total_len - 1
            req.max_new_tokens = max(1, req.max_new_tokens
                                     - req.generated + 1)
        req.generated = 0
        req.prefill_done = not req.chunked_prefill
        req.cached_len = 0
        self._release_pages(req, finished=False)
        self.queue.appendleft(req)
        self.slots[s] = None
        self._snap_clear(s)
        self.stats.preempted += 1
        if self.events is not None:
            self.events.on_preempt(req, s)

    def _release_pages(self, req: Request, *, finished: bool) -> None:
        """Free a request's pages; with a prefix cache, first record its
        written KV under the radix tree (the tree's references keep shared
        pages alive) and unpin its matched path."""
        if self.cache is not None:
            if req.kv_written:
                self.cache.insert(req.req_id,
                                  self.cache_tokens(req, finished))
            self.cache.release(req.req_id)
        self.alloc.free(req.req_id)

    def mark_prefill_done(self, s: int) -> bool:
        """Chunked prefill finished for slot ``s``: the request joins the
        decode batch with its first generated token counted (the engine sets
        ``generated=1`` before calling). Allocates the growth page the seed's
        admission-tick ``ensure`` would have grabbed; returns False (and
        preempts) if the pool is exhausted."""
        req = self.slots[s]
        req.prefill_done = True
        if req.total_len <= self.max_context:
            try:
                self._snap_grow(s, self.alloc.ensure(req.req_id,
                                                     req.total_len))
            except MemoryError:
                # the first token was sampled but never written/emitted:
                # requeue the bare prompt, not prompt+1
                req.generated = 0
                self._preempt(s, req)
                return False
        self._ctx[s] = req.total_len
        self.dirty.add(s)
        return True

    # ---- lifecycle hardening (PR 8) ----------------------------------
    def abort_slot(self, s: int, reason: str = "abort") -> Request:
        """Tear down a RUNNING request without a finish: its output is
        abandoned, so its written KV is NOT inserted into the prefix cache
        (already-shared prefix pages survive through the tree's own refs).
        Releases radix pins + pending swap ops (``cache.release`` →
        ``ops.cancel``) and frees the pages. Must only be called at a
        quiescent point — no decode horizon in flight over this slot's
        pages (the engine's ``_process_faults`` safe point)."""
        req = self.slots[s]
        if self.rstate_hook is not None:
            self.rstate_hook(req, s, True)   # drop any carry snapshot
        if self.cache is not None:
            self.cache.release(req.req_id)
        self.alloc.free(req.req_id)
        self.slots[s] = None
        self._snap_clear(s)
        self.stats.aborted += 1
        ev = getattr(self.events, "on_abort", None)
        if ev is not None:
            ev(req, s, reason)
        return req

    def abort_queued(self, req: Request, reason: str = "abort") -> None:
        """Drop a request still in the waiting queue. Queued requests hold
        no allocator or cache state (lookup/commit happen at admission, and
        preemption released everything before requeueing), so this is pure
        bookkeeping."""
        self.queue.remove(req)
        self._peek_memo.pop(req.req_id, None)
        self.stats.aborted += 1
        ev = getattr(self.events, "on_abort", None)
        if ev is not None:
            ev(req, -1, reason)

    def drain_slot(self, s: int) -> Request:
        """A serving row died under this slot: its written KV is garbage,
        so the request re-queues for a full re-prefill of the
        reconstructable context and the pages are freed WITHOUT a cache
        insert. Called at the engine's post-collect quiescent point, where
        ``generated`` counts only really-emitted tokens — so the written
        context is exactly ``total_len`` tokens (prompt + every consumed
        decode input; the newest sample re-enters as the first decode input
        after re-prefill) and the remaining budget is ``max_new -
        generated`` (unlike ``_preempt``'s mid-tick ``- generated + 1``
        frame, where ``generated`` was pre-incremented for an unsampled
        token)."""
        req = self.slots[s]
        if self.rstate_hook is not None:
            self.rstate_hook(req, s, True)   # carry snapshot is lost too
        if req.generated:
            req.prompt_len = req.total_len
            req.max_new_tokens = max(1, req.max_new_tokens - req.generated)
        req.generated = 0
        req.prefill_done = not req.chunked_prefill
        req.cached_len = 0
        req.kv_written = False
        if self.cache is not None:
            self.cache.release(req.req_id)
        self.alloc.free(req.req_id)
        self.queue.appendleft(req)
        self.slots[s] = None
        self._snap_clear(s)
        self.stats.migrated += 1
        if self.events is not None:
            self.events.on_preempt(req, s)
        return req

    def reserve_horizon(self, active, k: int, *,
                        gentle: bool = False) -> np.ndarray:
        """Best-effort page reservation for a fused ``k``-step decode
        horizon. ``step()`` already covered each active slot's next token;
        this grows the allocation to cover up to ``k`` consecutive tokens
        (clamped by the slot's remaining budget — a finished slot's final
        sample is never written, so ``prompt + max_new`` pages bound every
        horizon — and by ``max_context``, matching the per-token growth
        guard). On pool exhaustion a slot's allowance degrades to whatever
        its pages already cover instead of preempting: the device mask
        pauses it mid-horizon and the next tick resumes it, so reservation
        pressure never changes outputs. ``gentle=True`` additionally
        declines to evict radix-cached pages for SPECULATIVE growth (the
        horizon beyond the committed next token): under sharing-heavy load
        an aggressive k-token reservation would churn the prefix cache
        every tick for tokens that may never be accepted, so the horizon
        degrades first and only committed per-token growth reclaims.
        Returns ``allow`` [n_slots] int32 — decode steps each slot may run
        this horizon (0 = not active)."""
        allow = np.zeros((self.n_slots,), np.int32)
        for s in active:
            req = self.slots[s]
            steps = min(max(1, int(k)),
                        req.max_new_tokens - req.generated + 1)
            want = min(req.total_len + steps - 1, self.max_context)
            if steps > 1 and want > req.total_len:
                try:
                    self._snap_grow(s, self.alloc.ensure(
                        req.req_id, want, reclaim=not gentle))
                except MemoryError:
                    covered = int(self._npages[s]) * self.alloc.page_size
                    steps = max(1, min(steps, covered - req.total_len + 1))
            allow[s] = steps
        return allow

    # ------------------------------------------------------------------
    def _peek_cached(self, req: Request) -> tuple:
        """(tokens, dev_pages, host_pages) for a queued candidate, memoized
        for the current tick (peek is an estimate; within-tick staleness is
        fine and was already inherent to per-call peeks)."""
        ent = self._peek_memo.get(req.req_id)
        if ent is None:
            toks = self.cache_tokens(req, False)
            dev, host = self.cache.peek(toks)
            ent = self._peek_memo[req.req_id] = (toks, dev, host)
        return ent

    def prefetch_peeks(self, limit: int | None = None) -> None:
        """Warm the per-tick peek memo for the first ``limit`` queued
        candidates — the fused engine's overlap window runs these radix
        walks while the previous decode horizon is still computing on
        device. Peeks taken here predate the horizon's finish-inserts, an
        underestimate the memo's estimate semantics already tolerate."""
        if self.cache is None or not self.queue:
            return
        self._peek_memo.clear()
        self._peeks_fresh = True
        for req in list(self.queue)[:limit]:
            self._peek_cached(req)

    def cached_pages(self, req: Request) -> int:
        """Device pages a prefix-cache hit would let this queued request
        borrow instead of allocating (admission-capacity estimate).
        Host-resident matched pages do NOT reduce the need — their swap-in
        consumes a device page apiece."""
        if self.cache is None:
            return 0
        return self._peek_cached(req)[1]

    def _admit_one(self, req: Request, row: int | None) -> list[int] | None:
        """Allocate a request's prompt footprint, borrowing the cached
        prefix when a cache is attached. Returns the page table, or None if
        the pool could not cover it even after reclaim (the request stays
        queued)."""
        if self.cache is None:
            return self.alloc.admit(req.req_id, req.prompt_len, row)
        hit = self.cache.lookup(req.req_id, self.cache_tokens(req, False))
        try:
            pages = self.alloc.admit_shared(req.req_id, hit.pages,
                                            req.prompt_len, row)
        except MemoryError:
            self.cache.release(req.req_id)
            return None
        self.cache.commit(req.req_id, pages)
        req.cached_len = hit.matched
        return pages

    def _inflight_prefill_seqs(self) -> list[np.ndarray]:
        """Token sequences whose KV is being computed right now (admitted
        but not yet published to the prefix cache) — the same-tick dedup
        keys."""
        return [self.cache_tokens(r, False) for r in self.slots
                if r is not None and not r.kv_written]

    def _dedup_defer(self, req: Request, inflight) -> bool:
        """Same-tick prefix dedup: if an in-flight prefill already covers
        more page-aligned prefix of this request than the radix cache
        would, wait one tick — the leader publishes its prefix at prefill
        completion, so the deferred request admits with ``cached_len`` set
        and prefills only the suffix. A cold same-prefix burst then pays
        ONE full prefill instead of one per slot."""
        if self.cache is None or not self.dedup or not inflight:
            return False
        toks, dev, host = self._peek_cached(req)
        page = self.alloc.page_size
        best = 0
        for seq in inflight:
            n = min(len(seq), len(toks))
            if n <= best:
                continue
            eq = np.asarray(seq[:n]) == np.asarray(toks[:n])
            best = max(best, n if eq.all() else int(np.argmax(~eq)))
        if best // page == 0:
            return False
        return best // page > dev + host

    def _try_admit(self) -> list[tuple[int, Request]]:
        """Fill empty slots from the queue. Returns [(slot, request)] newly
        admitted (the engine must run prefill for these). With a policy the
        next request is whatever ``policy.select`` picks; the policy must
        only pick requests that pass ``alloc.can_admit``.

        Dedup-deferred requests are spliced out of the queue for the span
        of the admission pass (one verdict and one counter tick per
        request) and restored afterwards, so selection — FCFS or policy —
        moves on to admissible candidates instead of re-picking a waiting
        request once per free slot."""
        admitted = []
        dedup = self.cache is not None and self.dedup and bool(self.queue)
        inflight = self._inflight_prefill_seqs() if dedup else []
        deferred: list[tuple[int, Request]] = []
        for s in range(self.n_slots):
            if self.slots[s] is not None:
                continue
            row = self._row_of_slot(s) if self.alloc.policy == "row_affine" \
                else None
            while self.queue:
                if self.policy is not None:
                    idx = self.policy.select(self, row)
                    if idx is None:
                        break
                else:                  # seed behavior: strict head-of-line
                    if not self.alloc.can_admit(
                            self.queue[0].prompt_len, row,
                            self.cached_pages(self.queue[0])):
                        break  # head-of-line blocked on memory; next tick
                    idx = 0
                req = self.queue[idx]
                if inflight and self._dedup_defer(req, inflight):
                    self.stats.dedup_deferred += 1
                    deferred.append((idx + len(deferred), req))
                    del self.queue[idx]
                    continue           # re-select a candidate for this slot
                pages = self._admit_one(req, row)
                if pages is None:
                    break              # reclaim couldn't cover it; next tick
                del self.queue[idx]
                req.kv_written = False
                self.slots[s] = req
                self._snap_admit(s, req, pages)
                self.stats.admitted += 1
                admitted.append((s, req))
                if self.events is not None:
                    self.events.on_admit(req, s)
                if dedup:              # later candidates defer vs this leader
                    inflight.append(self.cache_tokens(req, False))
                break
        for i, req in sorted(deferred, key=lambda t: t[0]):
            self.queue.insert(min(i, len(self.queue)), req)
        return admitted

    def step(self, finished_mask: np.ndarray | None = None):
        """One decode tick.

        ``finished_mask`` [n_slots] — which active slots finished on the
        *previous* step (EOS sampled / budget reached). Frees their pages,
        refills slots, lazily grows every active request by one token.
        Slots still in chunked prefill are occupied but not active.
        Returns (admitted, active_slots).
        """
        if self._peeks_fresh:
            self._peeks_fresh = False
        else:
            self._peek_memo.clear()
        if finished_mask is not None:
            for s in np.flatnonzero(finished_mask):
                if self.slots[s] is not None:
                    if self.rstate_hook is not None:
                        self.rstate_hook(self.slots[s], s, True)
                    self._release_pages(self.slots[s], finished=True)
                    self.stats.completed += 1
                    if self.events is not None:
                        self.events.on_finish(self.slots[s], s)
                    self.slots[s] = None
                    self._snap_clear(s)
        admitted = self._try_admit()
        # policy-driven preemption (SLO tier starvation): ask the policy
        # for victim slots once per tick and route them through the SAME
        # mid-tick preempt frame as allocator exhaustion below — identical
        # requeue arithmetic, identical snapshot/restore resume, so a
        # priority preemption is token-identical for the victim
        victims: set = ()
        if self.policy is not None and self.queue:
            pv = getattr(self.policy, "preempt_victims", None)
            if pv is not None:
                victims = pv(self)
        active = []
        for s, req in enumerate(self.slots):
            if req is None or not req.prefill_done:
                continue
            req.generated += 1
            self._ctx[s] = req.total_len
            if s in victims:
                self.stats.priority_preempted += 1
                self._preempt(s, req)
                continue
            # injected pool exhaustion: behave exactly as if ensure() had
            # raised — same preempt path, same requeue arithmetic — so the
            # chaos plan exercises the real recovery machinery
            if self.faults.enabled and self.faults.fire("alloc_exhaust",
                                                        key=req.req_id):
                self._preempt(s, req)
                continue
            if req.total_len <= self.max_context:
                try:
                    self._snap_grow(s, self.alloc.ensure(req.req_id,
                                                         req.total_len))
                except MemoryError:
                    self._preempt(s, req)
                    continue
            active.append(s)
        # a page-aligned request can be admitted and preempted in the SAME
        # tick (its +1 growth page was the last straw) — it is back in the
        # queue, so it must not be prefilled
        admitted = [(s, r) for s, r in admitted if self.slots[s] is r]
        self.stats.steps += 1
        self.stats.occupied_slot_steps += len(active)
        self.stats.batch_trace.append(len(active))
        return admitted, active

    # ------------------------------------------------------------------
    def block_tables(self, width: int) -> np.ndarray:
        """Device block-table snapshot [n_slots, width]. When ``width``
        matches the maintained snapshot this is O(1) (the live array —
        treat as read-only); otherwise falls back to rebuilding."""
        if self._bt is not None and width == self._bt_width:
            return self._bt
        out = np.full((self.n_slots, width), -1, np.int32)
        for s, req in enumerate(self.slots):
            if req is not None:
                out[s] = self.alloc.block_table(req.req_id, width)
        return out

    def block_table_row(self, slot: int) -> np.ndarray:
        """One request's Va2Pa row (read-only view of the snapshot)."""
        if self._bt is not None:
            return self._bt[slot]
        return self.alloc.block_table(self.slots[slot].req_id,
                                      self._bt_width or 1)

    def context_lens(self) -> np.ndarray:
        return self._ctx.copy()

    def max_live_pages(self) -> int:
        """High-water mark of per-slot allocated pages — the live width the
        engine's decode-table bucketing needs."""
        return int(self._npages.max(initial=0))

    def done(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
