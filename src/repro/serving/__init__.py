"""Layered serving subsystem (paper Fig. 2 host loop, split by concern).

``engine`` orchestrates tick = schedule -> prefill -> decode -> sample;
``prefill`` holds the slot / batched / chunked strategies; ``policies`` the
pluggable admission policies; ``sampling`` the jitted samplers; ``cluster``
the disaggregated prefill/decode engine pool behind a fault-tolerant
router. See docs/serving.md for the mapping onto the paper's DCS/DPA
mechanisms.
"""
from repro.serving.cluster import ClusterConfig, EngineCluster, EngineHandle
from repro.serving.engine import DecodeEngine, EngineConfig, EngineTiming
from repro.serving.policies import (EDFPolicy, FCFSPolicy, MemoryAwarePolicy,
                                    SchedulingPolicy, SJFPolicy, SLOPolicy,
                                    available_policies, make_policy,
                                    register_policy, route_least_loaded)
from repro.serving.prefill import (BatchedPrefiller, ChunkedPrefiller,
                                   SlotPrefiller, make_prefiller)
from repro.serving.request import Request
from repro.serving.sampling import (Sampler, greedy_sample,
                                    make_callback_sampler, make_sampler,
                                    make_scan_sampler, make_verifier)

__all__ = [
    "DecodeEngine", "EngineConfig", "EngineTiming",
    "EngineCluster", "ClusterConfig", "EngineHandle",
    "Request",
    "SchedulingPolicy", "FCFSPolicy", "SJFPolicy", "MemoryAwarePolicy",
    "EDFPolicy", "SLOPolicy",
    "make_policy", "register_policy", "available_policies",
    "route_least_loaded",
    "SlotPrefiller", "BatchedPrefiller", "ChunkedPrefiller", "make_prefiller",
    "Sampler", "greedy_sample", "make_callback_sampler", "make_sampler",
    "make_scan_sampler", "make_verifier",
]
