"""Disaggregated serving: a router over a prefill/decode engine pool.

The multi-engine split the ROADMAP carries from L3/PAM: prefill-role
engines run each request through prefill to its first token(s), then the
finished-prefill KV pages + recurrent carry move to a decode-role engine
over a versioned, checksummed handoff blob (``kvcache/handoff.py``, built
on the engine's snapshot-entry frame), and the decode engine finishes the
request. Greedy outputs are bit-identical to a colocated single engine —
the handoff transfers the exact quiescent frame the crash-consistent
snapshots already round-trip.

The *router* owns the robustness policy (the cluster analogue of PR 8's
per-engine hardening):

* **crash-safe handoff**: a transfer is validated end-to-end before
  anything is applied; torn or corrupted blobs raise and are re-driven
  from the pristine in-router copy — bounded retries with capped
  exponential backoff, then a cold re-prefill on the destination
  (token-identical either way).
* **per-handoff timeouts**: a handoff whose destination never becomes
  deliverable (engine death) times out and is re-dispatched to another
  healthy decode engine.
* **health-checked engines**: a deterministic ``engine_death`` fault kind
  (``runtime/faults.py``) kills pool members at tick boundaries. A dead
  engine's in-flight requests are re-routed via the quiescent-frame cold
  re-prefill path or — when the engine kept serving snapshots — restored
  warm from its last snapshot into a replacement engine; token-identical
  either way.
* **backpressure**: when the decode pool is saturated the router sheds at
  submit (terminal, reason ``shed``) instead of queueing silently.
* **sticky degradation**: when a role has no healthy member left the
  cluster collapses to colocated mode (``runtime/elastic.py``'s
  ``plan_role_collapse``) — every survivor serves both stages; the rung
  never un-collapses mid-run.

Within one ``tick()`` the order is: fault clock + health/recovery, role
collapse, routing, engine ticks, output streaming, prefill extraction,
handoff delivery. Everything the router decides is a pure function of the
seeded fault plan and the submission order, so chaos runs replay exactly.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any

import numpy as np

from repro.kvcache import handoff as HO
from repro.runtime.elastic import plan_role_collapse
from repro.runtime.faults import make_faults
from repro.serving.engine import DecodeEngine
from repro.serving.policies import route_least_loaded
from repro.serving.request import Request as RequestSpec
from repro.telemetry import TelemetryConfig, make_telemetry


@dataclass
class ClusterConfig:
    """Fleet shape + router robustness policy. Tick-denominated windows
    (backoff, timeout, transfer) keep every decision replayable — the
    router never consults wall-clock."""
    n_prefill: int = 1
    n_decode: int = 1
    colocated: bool = False           # every engine serves both roles
    # ---- handoff state machine ----
    handoff_retries: int = 3          # transmissions before cold re-drive
    handoff_backoff: int = 1          # first retry delay (ticks), doubles
    handoff_backoff_cap: int = 8      # ... up to this cap
    handoff_timeout: int = 8          # ticks waiting on an undeliverable dst
    transfer_ticks: int = 0           # modeled transfer latency
    # ---- router backpressure ----
    # decode-pool saturation bound: submit() sheds once outstanding work
    # (live + queued + in-handoff requests) reaches this. 0 = unbounded.
    max_backlog: int = 0
    # per-engine admission-queue depth the router fills to (None = n_slots)
    route_queue_depth: int | None = None
    # ---- engine-death recovery ----
    # when set, every engine snapshots under <snapshot_dir>/e<ix> every
    # snapshot_every ticks and a dead engine is rebuilt warm from its last
    # snapshot; without it death recovery is the cold re-drive path
    snapshot_dir: str | None = None
    snapshot_every: int = 0
    # ---- cluster-level fault injection / telemetry ----
    faults: Any = None                # FaultConfig/FaultInjector for the
    telemetry: Any = None             # router's own clock (engine_death,
                                      # handoff_torn, handoff_corrupt)


@dataclass
class EngineHandle:
    ix: int
    role: str                         # "prefill" | "decode" | "both"
    eng: DecodeEngine
    alive: bool = True


@dataclass
class _PendingHandoff:
    """Router-side state for one in-flight handoff."""
    hid: int
    rid: int
    handoff: HO.Handoff               # pristine in-router copy
    dst_ix: int
    attempts: int = 0
    ready: int = 0                    # deliverable from this tick
    deadline: int = 0                 # dst-undeliverable timeout
    next_try: int = 0                 # backoff gate after a bad transfer


class EngineCluster:
    """Router + engine pool (see module docstring). Drive with
    ``submit`` + ``run``/``tick`` exactly like a single engine."""

    def __init__(self, cfg, ecfg, ccfg: ClusterConfig, params=None, *,
                 draft_params=None):
        self.cfg = cfg
        self.ecfg = ecfg
        self.ccfg = ccfg
        if params is None:
            import jax
            import jax.numpy as jnp
            from repro.models import model as MDL
            params = MDL.init_params(cfg, jax.random.PRNGKey(0),
                                     jnp.float32)
        self.params = params
        self.draft_params = draft_params
        self.faults = make_faults(ccfg.faults)
        if ccfg.colocated:
            roles = ["both"] * max(1, ccfg.n_prefill + ccfg.n_decode)
        else:
            if ccfg.n_prefill < 1 or ccfg.n_decode < 1:
                raise ValueError("disaggregated cluster needs >= 1 prefill "
                                 "and >= 1 decode engine")
            roles = (["prefill"] * ccfg.n_prefill
                     + ["decode"] * ccfg.n_decode)
        self.handles = [EngineHandle(ix, role, self._build_engine(ix, role))
                        for ix, role in enumerate(roles)]
        # rid -> {prompt, max_new, state, engine}; state machine:
        # routed -> prefill -> handoff -> decode -> done      (disagg)
        # routed -> colocated -> done                          (both-role)
        # any    -> aborted                                    (terminal)
        self.reqs: dict[int, dict] = {}
        self.queue: deque[int] = deque()     # router backlog (rids)
        self.outputs: dict[int, list[int]] = {}
        self.aborted: dict[int, str] = {}
        self._pending: list[_PendingHandoff] = []
        self._tick = 0
        self._next_hid = 0
        # sticky cluster degradation bitmask: 1 = collapsed to colocated
        self.degraded_mode = 0
        self.counters: dict[str, int] = {
            "handoffs": 0,            # handoff objects created
            "handoff_ok": 0,          # applied on a decode engine
            "handoff_retries": 0,     # torn/corrupt transmissions retried
            "handoff_timeouts": 0,    # dst-undeliverable deadlines fired
            "handoff_redispatches": 0,  # moved to a different dst engine
            "handoff_redrives": 0,    # gave up on warm: cold re-prefill
            "engine_deaths": 0,
            "engine_restores": 0,     # dead engine rebuilt warm
            "redispatched_requests": 0,  # re-routed off a dead engine
            "role_collapses": 0,
            "shed": 0,
        }
        self.tel = make_telemetry(ccfg.telemetry)
        self._bind_metrics()

    # ------------------------------------------------------------------
    def _build_engine(self, ix: int, role: str) -> DecodeEngine:
        E = self.ecfg
        tel = E.telemetry
        if isinstance(tel, TelemetryConfig):
            # per-engine registries: each pool member builds its OWN
            # facade, namespaced by index, so engine metrics never collide
            tel = replace(tel, namespace=f"{tel.namespace}_e{ix}")
        sd = self.ccfg.snapshot_dir or E.snapshot_dir
        ecfg = replace(
            E, role=role, telemetry=tel,
            snapshot_dir=str(Path(sd) / f"e{ix}") if sd else None,
            snapshot_every=(self.ccfg.snapshot_every or E.snapshot_every))
        return DecodeEngine(self.cfg, ecfg, self.params,
                            draft_params=self.draft_params)

    def _bind_metrics(self) -> None:
        r = self.tel.registry
        c = self.counters
        help_ = {
            "handoffs": "cross-engine KV handoffs created",
            "handoff_ok": "handoffs applied on a decode engine",
            "handoff_retries": "torn/corrupt handoff transmissions retried",
            "handoff_timeouts": "handoff destination timeouts fired",
            "handoff_redispatches": "handoffs moved to a new destination",
            "handoff_redrives": "handoffs degraded to cold re-prefill",
            "engine_deaths": "pool engines killed",
            "engine_restores": "dead engines rebuilt from snapshots",
            "redispatched_requests": "requests re-routed off dead engines",
            "role_collapses": "collapses to colocated mode",
            "shed": "submissions shed at the router (backpressure)",
        }
        for name, h in help_.items():
            r.bind(f"cluster_{name}_total", lambda n=name: c[n], h,
                   kind="counter")
        r.bind("cluster_engines_healthy",
               lambda: sum(1 for h in self.handles if h.alive),
               "pool engines currently alive")
        r.bind("cluster_router_queue_depth", lambda: len(self.queue),
               "requests waiting at the router")
        r.bind("cluster_pending_handoffs", lambda: len(self._pending),
               "handoffs in flight between engines")
        r.bind("cluster_degraded_mode", lambda: self.degraded_mode,
               "sticky cluster degradation bitmask (1=colocated collapse)")

    # ------------------------------------------------------------------
    # public API (mirrors DecodeEngine's submit/tick/run surface)
    # ------------------------------------------------------------------
    def submit(self, req: "RequestSpec | int", prompt=None,
               max_new_tokens: int | None = None) -> bool:
        """Route a request into the cluster, described by a
        ``serving.Request`` spec (the legacy positional form survives as a
        deprecated shim, mirroring ``DecodeEngine.submit``). Returns False
        when the decode pool is saturated and the request was shed at the
        router instead (terminal immediately, reason ``shed``, empty
        output). The spec rides the request record, so a re-route after an
        engine death re-submits with the same priority/SLO targets."""
        if not isinstance(req, RequestSpec):
            import warnings
            warnings.warn(
                "EngineCluster.submit(req_id, prompt, max_new_tokens) is "
                "deprecated; pass a serving.Request spec",
                DeprecationWarning, stacklevel=2)
            req = RequestSpec(req, prompt, max_new_tokens)
        req_id = req.req_id
        prompt = np.asarray(req.prompt, np.int32)
        self.outputs[req_id] = []
        if self.ccfg.max_backlog \
                and self._decode_load() >= self.ccfg.max_backlog:
            self.aborted[req_id] = "shed"
            self.counters["shed"] += 1
            self.reqs[req_id] = {"prompt": prompt,
                                 "max_new": req.max_new_tokens,
                                 "spec": req,
                                 "state": "aborted", "engine": None}
            return False
        self.reqs[req_id] = {"prompt": prompt,
                             "max_new": req.max_new_tokens,
                             "spec": req,
                             "state": "routed", "engine": None}
        self.queue.append(req_id)
        return True

    def tick(self) -> None:
        """One router tick (see module docstring for the order)."""
        self._tick += 1
        self.faults.on_tick()
        self._health()
        self._route()
        for h in self.handles:
            if h.alive:
                h.eng.tick()
        self._stream()
        self._extract()
        self._deliver()

    def run(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        for _ in range(max_ticks):
            if self.done():
                break
            self.tick()
        return self.outputs

    def done(self) -> bool:
        if self.queue or self._pending:
            return False
        for h in self.handles:
            if h.alive and not (h.eng.batcher.done()
                                and h.eng._inflight is None):
                return False
        return all(rec["state"] in ("done", "aborted")
                   for rec in self.reqs.values())

    # ------------------------------------------------------------------
    # health: engine death + recovery, sticky role collapse
    # ------------------------------------------------------------------
    def _health(self) -> None:
        for h in self.handles:
            if h.alive and self.faults.fire("engine_death", key=h.ix):
                self._kill(h)
        healthy = {h.ix for h in self.handles if h.alive}
        if not healthy:
            # nothing left to serve on: every non-terminal request aborts
            for rid, rec in self.reqs.items():
                if rec["state"] not in ("done", "aborted"):
                    rec["state"] = "aborted"
                    rec["engine"] = None
                    self.aborted[rid] = "engine_death"
            self.queue.clear()
            self._pending.clear()
            return
        plan = plan_role_collapse({h.ix: h.role for h in self.handles},
                                  healthy)
        if plan:
            self.degraded_mode |= 1
            self.counters["role_collapses"] += 1
            for h in self.handles:
                if h.ix in plan:
                    h.role = plan[h.ix]

    def _owned_by(self, h: EngineHandle) -> list[int]:
        return [rid for rid, rec in self.reqs.items()
                if rec["engine"] is h
                and rec["state"] in ("prefill", "decode", "colocated")]

    def _kill(self, h: EngineHandle) -> None:
        """An engine died at the tick boundary: its uncollected horizon is
        lost (never streamed, so nothing the client saw disappears). Try a
        warm rebuild from its last serving snapshot; whatever the snapshot
        does not cover is re-routed cold from the router's streamed-output
        frame — deterministic greedy makes both paths token-identical."""
        h.alive = False
        self.counters["engine_deaths"] += 1
        owned = self._owned_by(h)
        restored: set[int] = set()
        if h.eng.ecfg.snapshot_dir:
            eng2 = self._build_engine(h.ix, h.role)
            if eng2.restore_snapshot() is not None:
                # requests the cluster no longer routes here (handed off,
                # finished, re-routed) must not re-run on the rebuilt
                # engine: tear the stale restores down at the quiescent
                # start frame
                for rid in list(eng2.prompts):
                    if rid not in owned:
                        eng2._teardown(rid, "stale")
                h.eng = eng2
                h.alive = True
                self.counters["engine_restores"] += 1
                for rid in owned:
                    if rid in eng2.aborted:
                        continue
                    if eng2.outputs.get(rid) is None:
                        continue        # submitted after the snapshot
                    # rewind the stream cursor to the snapshot's frame;
                    # the resumed run regenerates the identical suffix
                    self.outputs[rid] = list(eng2.outputs[rid])
                    restored.add(rid)
        for rid in owned:
            if rid in restored:
                continue
            rec = self.reqs[rid]
            rec["engine"] = None
            if self._complete(rec, self.outputs[rid]):
                # the engine died after streaming the final token but
                # before retiring the slot — nothing left to regenerate
                rec["state"] = "done"
                continue
            rec["state"] = "routed"
            self.counters["redispatched_requests"] += 1
            self.queue.appendleft(rid)

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _engine_load(self, h: EngineHandle) -> int:
        return (sum(1 for r in h.eng.batcher.slots if r is not None)
                + len(h.eng.batcher.queue))

    def _decode_load(self) -> int:
        load = len(self.queue) + len(self._pending)
        for h in self.handles:
            if h.alive and h.role in ("decode", "both"):
                load += self._engine_load(h)
        return load

    def _pick(self, want: tuple[str, ...],
              bound: bool = False) -> EngineHandle | None:
        qd = self.ccfg.route_queue_depth or self.ecfg.n_slots
        loads = {h.ix: self._engine_load(h) for h in self.handles
                 if h.alive and h.role in want
                 and (not bound or len(h.eng.batcher.queue) < qd)}
        ix = route_least_loaded(loads)
        return None if ix is None else self.handles[ix]

    def _route(self) -> None:
        """Drain the router queue onto prefill-capable engines, least
        loaded first, bounded by the per-engine queue depth (requests the
        bound refuses wait HERE, visibly, not in an engine queue)."""
        while self.queue:
            h = self._pick(("prefill", "both"), bound=True)
            if h is None:
                return
            rid = self.queue.popleft()
            rec = self.reqs[rid]
            out = self.outputs[rid]
            if out and self._complete(rec, out):
                rec["state"] = "done"   # re-queued after its final token
                continue
            rec["engine"] = h
            rec["state"] = "colocated" if h.role == "both" else "prefill"
            if out:
                # re-drive of a partially-run request (engine death or
                # handoff give-up): cold quiescent-frame re-prefill of the
                # streamed context — mirrors drain_slot's arithmetic
                h.eng.adopt_request(rid, self._cold_entry(rec, out),
                                    rec["prompt"], out)
            else:
                h.eng.submit(rec.get("spec") or RequestSpec(
                    rid, rec["prompt"], rec["max_new"]))

    def _complete(self, rec: dict, out: list[int]) -> bool:
        """True when the streamed output is already the full response
        (budget spent or EOS sampled) — re-driving such a request would
        fabricate tokens past what the clean run produces."""
        return bool(out) and (len(out) > rec["max_new"]
                              or out[-1] == self.ecfg.eos_token)

    def _cold_entry(self, rec: dict, out: list[int]) -> dict:
        g = max(0, len(out) - 1)        # last sample's KV never landed
        ent = {"prompt_len": len(rec["prompt"]) + g,
               "max_new": max(1, rec["max_new"] - g), "state": "cold"}
        spec = rec.get("spec")
        if spec is not None and spec.priority:
            ent["priority"] = spec.priority
        return ent

    # ------------------------------------------------------------------
    # streaming + terminal detection
    # ------------------------------------------------------------------
    def _stream(self) -> None:
        for rid, rec in self.reqs.items():
            if rec["state"] not in ("prefill", "decode", "colocated"):
                continue
            h = rec["engine"]
            if h is None or not h.alive:
                continue
            eout = h.eng.outputs.get(rid)
            if eout is not None and len(eout) > len(self.outputs[rid]):
                self.outputs[rid] = list(eout)
            if rid in h.eng.aborted:
                reason = h.eng.aborted[rid]
                if reason != "handoff":       # handoff teardown is routing,
                    rec["state"] = "aborted"  # not a terminal outcome
                    self.aborted[rid] = reason
            elif h.eng._find_request(rid) == (None, None):
                rec["state"] = "done"

    # ------------------------------------------------------------------
    # prefill extraction -> handoff creation
    # ------------------------------------------------------------------
    def _extract(self) -> None:
        for rid, rec in self.reqs.items():
            if rec["state"] != "prefill":
                continue
            h = rec["engine"]
            if h is None or not h.alive:
                continue
            if h.role == "both":
                # collapsed mid-prefill: the survivor finishes it in place
                rec["state"] = "colocated"
                continue
            s, req = h.eng._find_request(rid)
            if req is None or s is None or not req.prefill_done \
                    or not h.eng.outputs.get(rid):
                continue
            res = h.eng.extract_request(rid)
            if res is None:
                continue                # finished during the quiesce
            ent, arrs = res
            self.outputs[rid] = [int(t) for t in np.asarray(arrs["out"])]
            dst = self._pick(("decode", "both"))
            if dst is None:
                # no decode-capable member (transient): re-drive cold
                rec["engine"] = None
                rec["state"] = "routed"
                self.counters["handoff_redrives"] += 1
                self.queue.appendleft(rid)
                continue
            hid = self._next_hid
            self._next_hid += 1
            t = self.ccfg.transfer_ticks
            self._pending.append(_PendingHandoff(
                hid, rid, HO.pack(rid, ent, arrs), dst.ix,
                ready=self._tick + t,
                deadline=self._tick + t + self.ccfg.handoff_timeout))
            self.counters["handoffs"] += 1
            rec["engine"] = None
            rec["state"] = "handoff"

    # ------------------------------------------------------------------
    # handoff delivery state machine
    # ------------------------------------------------------------------
    def _deliver(self) -> None:
        C = self.ccfg
        still: list[_PendingHandoff] = []
        for ho in self._pending:
            rec = self.reqs[ho.rid]
            if rec["state"] != "handoff":
                continue                # went terminal at the router
            if self._tick < ho.ready:
                still.append(ho)
                continue
            dst = self.handles[ho.dst_ix]
            if not dst.alive:
                if self._tick < ho.deadline:
                    still.append(ho)    # waiting out the timeout window
                    continue
                self.counters["handoff_timeouts"] += 1
                nd = self._pick(("decode", "both"))
                if nd is None:
                    self._redrive_routed(ho, rec)
                    continue
                ho.dst_ix = nd.ix
                ho.ready = self._tick + C.transfer_ticks
                ho.deadline = ho.ready + C.handoff_timeout
                self.counters["handoff_redispatches"] += 1
                still.append(ho)
                continue
            if self._tick < ho.next_try:
                still.append(ho)        # backing off after a bad transfer
                continue
            blob = HO.encode(ho.handoff)
            if self.faults.fire("handoff_torn", key=ho.hid):
                blob = HO.tear(blob, self._tick + ho.hid)
            if self.faults.fire("handoff_corrupt", key=ho.hid):
                blob = HO.flip(blob, self._tick + ho.hid)
            try:
                got = HO.decode(blob)
            except HO.HandoffError:
                ho.attempts += 1
                self.counters["handoff_retries"] += 1
                if ho.attempts > C.handoff_retries:
                    # give up on the warm path: the pristine frame re-
                    # drives as a cold re-prefill on the destination
                    self.counters["handoff_redrives"] += 1
                    self._apply_cold(ho, dst, rec)
                    continue
                back = min(C.handoff_backoff_cap,
                           C.handoff_backoff << (ho.attempts - 1))
                ho.next_try = self._tick + max(1, back)
                ho.deadline = max(ho.deadline, ho.next_try
                                  + C.handoff_timeout)
                still.append(ho)
                continue
            self._apply(got, dst, rec)
            self.counters["handoff_ok"] += 1
        self._pending = still

    def _apply(self, got: HO.Handoff, dst: EngineHandle, rec: dict) -> None:
        nested = HO.nested_arrays(got)
        kv = ((nested["kv_k"], nested["kv_v"])
              if "kv_k" in nested else None)
        rows = (dst.eng._rows_from_nested(nested["rows"])
                if "rows" in nested else None)
        dst.eng.adopt_request(got.req_id, got.entry, nested["prompt"],
                              [int(t) for t in nested["out"]],
                              kv=kv, rows=rows)
        rec["engine"] = dst
        rec["state"] = "decode"

    def _apply_cold(self, ho: _PendingHandoff, dst: EngineHandle,
                    rec: dict) -> None:
        """Adopt from the pristine in-router frame but cold: drop the KV/
        carry payload and re-prefill the streamed context on the
        destination (the entry's requeue arithmetic already matches)."""
        ent = dict(ho.handoff.entry)
        ent["state"] = "cold"
        out = [int(t) for t in np.asarray(ho.handoff.arrays["out"])]
        dst.eng.adopt_request(ho.rid, ent, ho.handoff.arrays["prompt"], out)
        rec["engine"] = dst
        rec["state"] = "decode"

    def _redrive_routed(self, ho: _PendingHandoff, rec: dict) -> None:
        """No decode-capable destination at all: hand the request back to
        the router queue for a cold re-drive wherever routing lands it."""
        self.counters["handoff_redrives"] += 1
        rec["engine"] = None
        rec["state"] = "routed"
        self.queue.appendleft(ho.rid)

    # ------------------------------------------------------------------
    def stats_dict(self) -> dict:
        out = dict(self.counters)
        out["engines_healthy"] = sum(1 for h in self.handles if h.alive)
        out["degraded_mode"] = self.degraded_mode
        out["router_queue_depth"] = len(self.queue)
        out["pending_handoffs"] = len(self._pending)
        return out
