"""Prefill strategies for the serving engine.

Three ways to get an admitted prompt into the paged pool:

* ``slot`` — the seed path: one batch-1 ``MDL.prefill`` per admitted
  request, recurrent/enc-dec states merged into the engine state. Works for
  every architecture family; pays one dispatch (and one compile per prompt
  length) per request.
* ``batched`` — length-bucketed batched prefill: all requests admitted in a
  tick are grouped into padded-length buckets and each bucket runs under ONE
  jitted call (``last_idx`` picks each request's true last position,
  ``valid_len`` masks pad writes). Uniform-attention stacks only (the
  decode state is just the shared pool); other families fall back to slot.
* ``chunked`` — DCS-style interleave: prompts are cut into fixed-size
  chunks and one chunk per prefilling slot runs per engine tick, between
  decode steps, via ``MDL.prefill_chunk`` (``write_prefill(ctx_start=...)``
  + gathered-pool attention). Decode latency for running requests stays
  bounded by the chunk, not the longest admitted prompt — the scheduling
  overlap the paper's DCS gets by pipelining data movement with compute.

``make_prefiller`` picks the implementation and silently degrades to
``slot`` when the engine's model family can't support the requested mode.

Fused-horizon interaction: each prefiller exposes ``max_horizon`` — the cap
it imposes on the engine's fused decode horizon this tick. Slot/batched
prefill never cap (``None``); chunked prefill caps to 1 while chunks are
streaming, so running requests decode exactly one step between consecutive
chunks and the DCS interleave granularity (and TTFT of the prefilling
request) is independent of ``decode_horizon``.

Prefix-cache hits (``req.cached_len > 0``) prefill only the *suffix* beyond
the matched depth in every mode: ``chunked`` simply starts its chunk cursor
there, while ``slot``/``batched`` route hits through the ``prefill_chunk``
path — batched groups hits into suffix-length buckets and passes the
per-request resume depths as a vector ``ctx_start``, so one jitted call
covers requests with different matched prefixes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL


def _make_batched_fn(cfg, rt):
    def fn(params, pool, tokens, bt, last_idx, valid_len):
        logits, state = MDL.prefill(cfg, params, {"pool": pool}, tokens, bt,
                                    last_idx=last_idx, valid_len=valid_len,
                                    rt=rt)
        return logits, state["pool"]
    return jax.jit(fn)


def _make_chunk_fn(cfg, rt):
    def fn(params, pool, tokens, bt, ctx_start, last_idx, valid_len):
        logits, state = MDL.prefill_chunk(cfg, params, {"pool": pool},
                                          tokens, bt, ctx_start,
                                          last_idx=last_idx,
                                          valid_len=valid_len, rt=rt)
        return logits, state["pool"]
    return jax.jit(fn)


def _suffix_bucket(n: int, cap: int) -> int:
    b = 8
    while b < n and b < cap:
        b *= 2
    return b if b >= n else -(-n // cap) * cap


def decode_table_bucket(live_pages: int, width: int) -> int:
    """Decode block-table width the engine dispatches for a live-page
    high-water mark: the prefill pow2 bucket with a 16-page floor, capped
    at the full table width. Shared by serving/engine.py (production) and
    benchmarks/kernel_bench.py (so the bench measures production widths)."""
    return min(width, _suffix_bucket(max(16, live_pages), width))


def prefill_suffix(eng, fn, grp) -> None:
    """One jitted ``prefill_chunk`` call covering a group of cache-hit
    requests: suffixes padded to a shared bucket length, per-request resume
    depths as the ``ctx_start`` vector. ``grp``: [(slot, req, seq, emit)]
    with equal bucket sizes; ``fn`` is a ``_make_chunk_fn`` jit."""
    cap = max(8, eng.ecfg.max_prefill)
    blen = max(_suffix_bucket(len(seq) - req.cached_len, cap)
               for _, req, seq, _ in grp)
    toks = np.zeros((len(grp), blen), np.int32)
    starts = np.zeros((len(grp),), np.int32)
    lens = np.zeros((len(grp),), np.int32)
    for i, (_, req, seq, _) in enumerate(grp):
        suf = seq[req.cached_len:]
        toks[i, :len(suf)] = suf
        starts[i] = req.cached_len
        lens[i] = len(suf)
    bts = np.stack([eng.batcher.block_table_row(slot) for slot, *_ in grp])
    # the chunk path gathers every block-table slot per layer: slice the
    # table to the pages this group's context actually spans (pow2-bucketed
    # so the jit cache stays small) instead of the max_context width
    need = -(-max(len(seq) for _, _, seq, _ in grp) // eng.ecfg.page_size) + 1
    bts = bts[:, :min(_suffix_bucket(need, need), bts.shape[1])]
    logits, pool = fn(
        eng.params, eng.state["pool"], jnp.asarray(toks), jnp.asarray(bts),
        jnp.asarray(starts), jnp.asarray(lens - 1), jnp.asarray(lens))
    eng.state["pool"] = pool
    emits = [emit for *_, emit in grp]
    first = eng._first_tokens(logits, emits)     # one batched sample call
    for i, (slot, req, _, emit) in enumerate(grp):
        req.generated = 1
        eng._emit_first(slot, req, int(first[i]), emit)


class SlotPrefiller:
    """Per-request whole-prompt prefill (seed semantics); prefix-cache hits
    take the batch-1 suffix path instead."""
    name = "slot"
    max_horizon = None                 # never caps the fused decode horizon

    def __init__(self, engine):
        self.eng = engine
        self._suffix_fn = _make_chunk_fn(engine.cfg, engine.rt) \
            if engine.chunkable else None

    @property
    def busy(self) -> bool:
        return False

    def run(self, admitted, active):
        for slot, req in admitted:
            if req.cached_len > 0:
                seq, emit = self.eng._prompt_seq(req)
                prefill_suffix(self.eng, self._suffix_fn,
                               [(slot, req, seq, emit)])
            else:
                self._prefill_slot(slot, req)
        return active

    def _prefill_slot(self, slot: int, req) -> None:
        """Run the prompt through the model into this slot's pages.

        The functional prefill writes whole-batch; for slot-wise admission we
        run a batch-1 prefill and merge its cache rows into the engine state.
        """
        eng = self.eng
        req.generated = 1              # prefill emits the first token
        prompt, emit = eng._prompt_seq(req)
        bt = eng.batcher.block_table_row(slot)
        state1 = MDL.init_decode_state(eng.cfg, eng.pool_spec, 1,
                                       dtype="float32")
        # share the pool so pages written land in the engine pool
        if "pool" in eng.state:
            state1["pool"] = eng.state["pool"]
        logits, state1 = MDL.prefill(
            eng.cfg, eng.params, state1, jnp.asarray(prompt[None]),
            jnp.asarray(bt[None]), rt=eng.rt,
            frames=(jnp.zeros((1, eng.cfg.enc_seq, eng.cfg.d_model),
                              jnp.float32)
                    if eng.cfg.family == "encdec" else None))
        if "pool" in eng.state:
            eng.state["pool"] = state1["pool"]
        for key in ("mamba", "mlstm", "slstm", "cross_k", "cross_v"):
            if key in eng.state:
                def put(dst, src):
                    return dst.at[:, slot].set(src[:, 0])
                eng.state[key] = jax.tree.map(put, eng.state[key],
                                              state1[key])
        eng._emit_first(slot, req,
                        int(eng._first_tokens(np.asarray(logits)[:1],
                                              [emit])[0]), emit)


class BatchedPrefiller:
    """Length-bucketed batched prefill: every bucket is one jitted call.
    Prefix-cache hits go through suffix-length buckets instead (vector
    ``ctx_start`` — one call per bucket, mixed resume depths)."""
    name = "batched"
    max_horizon = None

    def __init__(self, engine):
        self.eng = engine
        self._fn = _make_batched_fn(engine.cfg, engine.rt)
        self._suffix_fn = _make_chunk_fn(engine.cfg, engine.rt) \
            if engine.chunkable else None

    @property
    def busy(self) -> bool:
        return False

    def _bucket(self, n: int) -> int:
        return _suffix_bucket(n, max(8, self.eng.ecfg.max_prefill))

    def run(self, admitted, active):
        eng = self.eng
        if not admitted:
            return active
        groups: dict[int, list] = {}
        fresh: dict[int, bool] = {}
        sgroups: dict[int, list] = {}
        for slot, req in admitted:
            seq, emit = eng._prompt_seq(req)
            if req.cached_len > 0:
                sgroups.setdefault(
                    self._bucket(len(seq) - req.cached_len), []).append(
                        (slot, req, seq, emit))
                continue
            groups.setdefault(self._bucket(len(seq)), []).append(
                (slot, req, seq))
            fresh[slot] = emit
        for blen in sorted(sgroups):
            prefill_suffix(eng, self._suffix_fn, sgroups[blen])
        for blen in sorted(groups):
            grp = groups[blen]
            toks = np.zeros((len(grp), blen), np.int32)
            lens = np.zeros((len(grp),), np.int32)
            for i, (_, _, seq) in enumerate(grp):
                toks[i, :len(seq)] = seq
                lens[i] = len(seq)
            bts = np.stack([eng.batcher.block_table_row(slot)
                            for slot, _, _ in grp])
            logits, pool = self._fn(
                eng.params, eng.state["pool"], jnp.asarray(toks),
                jnp.asarray(bts), jnp.asarray(lens - 1), jnp.asarray(lens))
            eng.state["pool"] = pool
            first = eng._first_tokens(logits, [fresh[s] for s, _, _ in grp])
            for i, (slot, req, _) in enumerate(grp):
                req.generated = 1
                eng._emit_first(slot, req, int(first[i]), fresh[slot])
        return active


class ChunkedPrefiller:
    """Fixed-size chunk per prefilling slot per tick, interleaved with
    decode. Slots finishing their last chunk join this tick's decode batch
    (same (generated, ctx) trajectory as the seed's admission-tick decode,
    so greedy outputs are token-identical)."""
    name = "chunked"

    def __init__(self, engine):
        self.eng = engine
        self._fn = _make_chunk_fn(engine.cfg, engine.rt)
        self._pos: dict[int, int] = {}      # slot -> next ctx_start

    @property
    def busy(self) -> bool:
        return bool(self._pos)

    @property
    def max_horizon(self):
        """One decode step per tick while chunks stream (DCS granularity);
        uncapped once every prompt is through."""
        return 1 if self._pos else None

    def run(self, admitted, active):
        eng = self.eng
        for slot, req in admitted:
            # prefix-cache hits resume chunking at the matched depth
            self._pos[slot] = req.cached_len
        if not self._pos:
            return active
        C = max(1, eng.ecfg.prefill_chunk)
        completed = []
        for slot in sorted(self._pos):
            req = eng.batcher.slots[slot]
            if req is None or req.prefill_done:
                # slot freed or preempted out from under a mid-prefill
                # request; its re-admission re-registers from chunk 0
                del self._pos[slot]
                continue
            prompt, emit = eng._prompt_seq(req)
            start = self._pos[slot]
            valid = min(C, len(prompt) - start)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :valid] = prompt[start:start + valid]
            bt = eng.batcher.block_table_row(slot)[None]
            logits, pool = self._fn(
                eng.params, eng.state["pool"], jnp.asarray(chunk),
                jnp.asarray(bt), jnp.int32(start),
                jnp.asarray([valid - 1], jnp.int32),
                jnp.asarray([valid], jnp.int32))
            eng.state["pool"] = pool
            self._pos[slot] = start + valid
            if self._pos[slot] >= len(prompt):
                del self._pos[slot]
                req.generated = 1
                if eng.batcher.mark_prefill_done(slot):
                    eng._emit_first(
                        slot, req,
                        int(eng._first_tokens(np.asarray(logits)[:1],
                                              [emit])[0]), emit)
                    completed.append(slot)
                # else: pool exhausted at the finish line — the batcher
                # preempted and requeued the bare prompt
        return sorted(set(active) | set(completed)) if completed else active


def make_prefiller(mode: str, engine):
    """'slot' | 'batched' | 'chunked', degrading to 'slot' when the model
    family doesn't support the batched/chunked pool-only path."""
    if mode == "batched" and engine.batchable:
        return BatchedPrefiller(engine)
    if mode == "chunked" and engine.chunkable:
        return ChunkedPrefiller(engine)
    assert mode in ("slot", "batched", "chunked"), mode
    return SlotPrefiller(engine)
