"""Prefill strategies for the serving engine.

Three ways to get an admitted prompt into the paged pool / recurrent state:

* ``slot`` — the seed path: one batch-1 ``MDL.prefill`` per admitted
  request, recurrent/enc-dec states merged into the engine state. Works for
  every architecture family; pays one dispatch (and one compile per prompt
  length) per request. Kept as the recompute-everything reference.
* ``batched`` — length-bucketed batched prefill: all requests admitted in a
  tick are grouped into padded-length buckets and each bucket runs under ONE
  jitted call (``last_idx`` picks each request's true last position,
  ``valid_len`` masks pad writes AND stops each row's recurrent carry at its
  true last token). All families: attention stacks carry only the shared
  pool; recurrent/enc-dec families ride their per-slot state rows through
  the call (gathered from / scattered back to the engine state).
* ``chunked`` — DCS-style interleave: prompts are cut into fixed-size
  chunks and ONE batched ``MDL.prefill_chunk`` call per engine tick covers
  every prefilling slot (vector ``ctx_start`` — each row at its own chunk
  cursor), between decode steps. Recurrent state is the explicit carry: a
  chunk resumes exactly where the previous chunk's returned state left off,
  so decode latency for running requests stays bounded by the chunk, not
  the longest admitted prompt, for attention AND recurrent-hybrid families
  alike — the scheduling overlap the paper's DCS gets by pipelining data
  movement with compute.

``make_prefiller`` picks the implementation; only runtimes whose prefill
branches bypass ``valid_len`` masking (ring pools, sharded pool writers)
still degrade to ``slot``.

Fused-horizon interaction: each prefiller exposes ``max_horizon`` — the cap
it imposes on the engine's fused decode horizon this tick. Slot/batched
prefill never cap (``None``); chunked prefill caps to 1 while chunks are
streaming, so running requests decode exactly one step between consecutive
chunks and the DCS interleave granularity (and TTFT of the prefilling
request) is independent of ``decode_horizon``.

Resume depths (``batched``/``chunked``): prefix-cache hits
(``req.cached_len > 0``, attention stacks) and preemption snapshots of the
recurrent carry (``engine._take_snapshot``, recurrent/enc-dec families)
both mean prefill covers only the *suffix* beyond the resume depth.
``chunked`` starts its chunk cursor there; ``batched`` groups resumes into
suffix-length buckets and passes the per-request depths as a vector
``ctx_start``, so one jitted call covers mixed resume depths. A snapshot
whose depth already covers the whole reconstructable context (the common
decode-preemption case) restores without any model call at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL


def _make_batched_fn(cfg, rt):
    encdec = cfg.family == "encdec"

    def fn(params, state, tokens, bt, last_idx, valid_len):
        frames = (jnp.zeros((tokens.shape[0], cfg.enc_seq, cfg.d_model),
                            jnp.float32) if encdec else None)
        return MDL.prefill(cfg, params, state, tokens, bt,
                           last_idx=last_idx, valid_len=valid_len,
                           frames=frames, rt=rt)
    return jax.jit(fn)


def _make_chunk_fn(cfg, rt):
    def fn(params, state, tokens, bt, ctx_start, last_idx, valid_len):
        return MDL.prefill_chunk(cfg, params, state, tokens, bt, ctx_start,
                                 last_idx=last_idx, valid_len=valid_len,
                                 rt=rt)
    return jax.jit(fn)


def _suffix_bucket(n: int, cap: int) -> int:
    b = 8
    while b < n and b < cap:
        b *= 2
    return b if b >= n else -(-n // cap) * cap


def decode_table_bucket(live_pages: int, width: int) -> int:
    """Decode block-table width the engine dispatches for a live-page
    high-water mark: the prefill pow2 bucket with a 16-page floor, capped
    at the full table width. Shared by serving/engine.py (production) and
    benchmarks/kernel_bench.py (so the bench measures production widths)."""
    return min(width, _suffix_bucket(max(16, live_pages), width))


def _group_tables(eng, slots, span: int) -> np.ndarray:
    """Stacked Va2Pa rows for a prefill group, sliced to the pages the
    group's context actually spans (pow2-bucketed so the jit cache stays
    small) — the chunk path gathers every block-table slot per layer, so
    dispatching the max_context width would gather ~the whole pool."""
    bts = np.stack([eng.batcher.block_table_row(slot) for slot in slots])
    need = -(-span // eng.ecfg.page_size) + 1
    return bts[:, :min(_suffix_bucket(need, need), bts.shape[1])]


def prefill_suffix(eng, fn, grp) -> None:
    """One jitted ``prefill_chunk`` call covering a group of resumed
    requests: suffixes padded to a shared bucket length, per-request resume
    depths as the ``ctx_start`` vector, recurrent carries riding along as
    the group's state rows. ``grp``: [(slot, req, seq, emit, start)] with
    equal bucket sizes; ``fn`` is a ``_make_chunk_fn`` jit."""
    cap = max(8, eng.ecfg.max_prefill)
    blen = max(_suffix_bucket(len(seq) - start, cap)
               for _, _, seq, _, start in grp)
    toks = np.zeros((len(grp), blen), np.int32)
    starts = np.zeros((len(grp),), np.int32)
    lens = np.zeros((len(grp),), np.int32)
    for i, (_, _, seq, _, start) in enumerate(grp):
        suf = seq[start:]
        toks[i, :len(suf)] = suf
        starts[i] = start
        lens[i] = len(suf)
    slots = [slot for slot, *_ in grp]
    bts = _group_tables(eng, slots,
                        max(len(seq) for _, _, seq, _, _ in grp))
    logits, gstate = fn(
        eng.params, eng._group_prefill_state(slots), jnp.asarray(toks),
        jnp.asarray(bts), jnp.asarray(starts), jnp.asarray(lens - 1),
        jnp.asarray(lens))
    eng._merge_group_state(slots, gstate)
    emits = [emit for _, _, _, emit, _ in grp]
    first = eng._first_tokens(logits, emits)     # one batched sample call
    for i, (slot, req, _, emit, _) in enumerate(grp):
        req.generated = 1
        eng._emit_first(slot, req, int(first[i]), emit)


class SlotPrefiller:
    """Per-request whole-prompt prefill (seed semantics) — the recompute
    reference path: preemption snapshots are never consumed here, and only
    prefix-cache hits take the batch-1 suffix shortcut."""
    name = "slot"
    max_horizon = None                 # never caps the fused decode horizon

    def __init__(self, engine):
        self.eng = engine
        self._suffix_fn = _make_chunk_fn(engine.cfg, engine.rt) \
            if engine.chunkable else None

    @property
    def busy(self) -> bool:
        return False

    def run(self, admitted, active):
        for slot, req in admitted:
            if req.cached_len > 0:
                seq, emit = self.eng._prompt_seq(req)
                prefill_suffix(self.eng, self._suffix_fn,
                               [(slot, req, seq, emit, req.cached_len)])
            else:
                self._prefill_slot(slot, req)
        return active

    def _prefill_slot(self, slot: int, req) -> None:
        """Run the prompt through the model into this slot's pages.

        The functional prefill writes whole-batch; for slot-wise admission we
        run a batch-1 prefill and merge its cache rows into the engine state.
        """
        eng = self.eng
        req.generated = 1              # prefill emits the first token
        prompt, emit = eng._prompt_seq(req)
        bt = eng.batcher.block_table_row(slot)
        state1 = MDL.init_decode_state(eng.cfg, eng.pool_spec, 1,
                                       dtype="float32")
        # share the pool so pages written land in the engine pool
        if "pool" in eng.state:
            state1["pool"] = eng.state["pool"]
        logits, state1 = MDL.prefill(
            eng.cfg, eng.params, state1, jnp.asarray(prompt[None]),
            jnp.asarray(bt[None]), rt=eng.rt,
            frames=(jnp.zeros((1, eng.cfg.enc_seq, eng.cfg.d_model),
                              jnp.float32)
                    if eng.cfg.family == "encdec" else None))
        if "pool" in eng.state:
            eng.state["pool"] = state1["pool"]
        for key in MDL.RSTATE_KEYS:
            if key in eng.state:
                def put(dst, src):
                    return dst.at[:, slot].set(src[:, 0])
                eng.state[key] = jax.tree.map(put, eng.state[key],
                                              state1[key])
        eng._emit_first(slot, req,
                        int(eng._first_tokens(np.asarray(logits)[:1],
                                              [emit])[0]), emit)


class BatchedPrefiller:
    """Length-bucketed batched prefill: every bucket is one jitted call.
    Resumed requests (prefix-cache hits / preemption snapshots) go through
    suffix-length buckets instead (vector ``ctx_start`` — one call per
    bucket, mixed resume depths); snapshot-covered requests restore with no
    model call at all."""
    name = "batched"
    max_horizon = None

    def __init__(self, engine):
        self.eng = engine
        self._fn = _make_batched_fn(engine.cfg, engine.rt)
        self._suffix_fn = _make_chunk_fn(engine.cfg, engine.rt) \
            if engine.chunkable else None

    @property
    def busy(self) -> bool:
        return False

    def _bucket(self, n: int) -> int:
        return _suffix_bucket(n, max(8, self.eng.ecfg.max_prefill))

    def run(self, admitted, active):
        eng = self.eng
        if not admitted:
            return active
        groups: dict[int, list] = {}
        fresh: dict[int, bool] = {}
        sgroups: dict[int, list] = {}
        starts, _ = eng._begin_prefill_group(admitted)
        for slot, req in admitted:
            seq, emit = eng._prompt_seq(req)
            start = starts[slot]
            if start >= len(seq):      # snapshot covers everything: restored
                req.generated = 1
                eng._emit_first(slot, req, None, emit=False)
                continue
            if start > 0:
                sgroups.setdefault(
                    self._bucket(len(seq) - start), []).append(
                        (slot, req, seq, emit, start))
                continue
            groups.setdefault(self._bucket(len(seq)), []).append(
                (slot, req, seq))
            fresh[slot] = emit
        for blen in sorted(sgroups):
            prefill_suffix(eng, self._suffix_fn, sgroups[blen])
        for blen in sorted(groups):
            grp = groups[blen]
            toks = np.zeros((len(grp), blen), np.int32)
            lens = np.zeros((len(grp),), np.int32)
            for i, (_, _, seq) in enumerate(grp):
                toks[i, :len(seq)] = seq
                lens[i] = len(seq)
            slots = [slot for slot, _, _ in grp]
            bts = np.stack([eng.batcher.block_table_row(slot)
                            for slot in slots])
            logits, gstate = self._fn(
                eng.params, eng._group_prefill_state(slots),
                jnp.asarray(toks), jnp.asarray(bts), jnp.asarray(lens - 1),
                jnp.asarray(lens))
            eng._merge_group_state(slots, gstate)
            first = eng._first_tokens(logits, [fresh[s] for s in slots])
            for i, (slot, req, _) in enumerate(grp):
                req.generated = 1
                eng._emit_first(slot, req, int(first[i]), fresh[slot])
        return active


class ChunkedPrefiller:
    """Fixed-size chunk per prefilling slot per tick, interleaved with
    decode — ONE batched ``prefill_chunk`` call covers every streaming slot
    (vector chunk cursors), with each slot's recurrent carry gathered from
    and scattered back to the engine state rows. Slots finishing their last
    chunk join this tick's decode batch (same (generated, ctx) trajectory
    as the seed's admission-tick decode, so greedy outputs are
    token-identical)."""
    name = "chunked"

    def __init__(self, engine):
        self.eng = engine
        self._fn = _make_chunk_fn(engine.cfg, engine.rt)
        self._pos: dict[int, int] = {}      # slot -> next ctx_start

    @property
    def busy(self) -> bool:
        return bool(self._pos)

    @property
    def max_horizon(self):
        """One decode step per tick while chunks stream (DCS granularity);
        uncapped once every prompt is through."""
        return 1 if self._pos else None

    def run(self, admitted, active):
        eng = self.eng
        # resumes (prefix-cache hit / preemption snapshot) start the chunk
        # cursor at the covered depth
        starts, restored = eng._begin_prefill_group(admitted)
        self._pos.update(starts)
        fresh_cross = [s for s, _ in admitted
                       if eng.cfg.family == "encdec" and s not in restored]
        if fresh_cross:
            # enc-dec decoder chunks attend over carried cross-KV rows:
            # materialize them in ONE batched encoder pass per tick
            # (snapshot-restored slots brought their own rows back)
            eng._init_cross_rows(fresh_cross)
        if not self._pos:
            return active
        C = max(1, eng.ecfg.prefill_chunk)
        completed = []
        grp = []                            # (slot, req, prompt, emit, valid)
        for slot in sorted(self._pos):
            req = eng.batcher.slots[slot]
            if req is None or req.prefill_done:
                # slot freed or preempted out from under a mid-prefill
                # request; its re-admission re-registers from chunk 0
                del self._pos[slot]
                continue
            prompt, emit = eng._prompt_seq(req)
            if self._pos[slot] >= len(prompt):
                # snapshot covered the whole context: restored, no chunks.
                # kv_written is set BEFORE the growth-page grab: the
                # restored pages/state genuinely hold the context, so a
                # mark_prefill_done MemoryError re-snapshots instead of
                # silently degrading the next resume to full recompute
                del self._pos[slot]
                req.generated = 1
                req.kv_written = True
                if eng.batcher.mark_prefill_done(slot):
                    eng._emit_first(slot, req, None, emit=False)
                    completed.append(slot)
                continue
            grp.append((slot, req, prompt, emit,
                        min(C, len(prompt) - self._pos[slot])))
        if grp:
            toks = np.zeros((len(grp), C), np.int32)
            starts = np.zeros((len(grp),), np.int32)
            lens = np.zeros((len(grp),), np.int32)
            for i, (slot, _, prompt, _, valid) in enumerate(grp):
                start = self._pos[slot]
                toks[i, :valid] = prompt[start:start + valid]
                starts[i] = start
                lens[i] = valid
            slots = [slot for slot, *_ in grp]
            # attention reads nothing past the processed context, so the
            # table slice tracks the deepest cursor, not the full prompts
            bts = _group_tables(eng, slots, int((starts + lens).max()))
            logits, gstate = self._fn(
                eng.params, eng._group_prefill_state(slots),
                jnp.asarray(toks), jnp.asarray(bts), jnp.asarray(starts),
                jnp.asarray(lens - 1), jnp.asarray(lens))
            eng._merge_group_state(slots, gstate)
            fin = [(i, slot, req, emit)
                   for i, (slot, req, prompt, emit, valid) in enumerate(grp)
                   if starts[i] + valid >= len(prompt)]
            first = (eng._first_tokens(np.asarray(logits)[[i for i, *_ in
                                                           fin]],
                                       [e for *_, e in fin]) if fin else [])
            for j, (i, slot, req, emit) in enumerate(fin):
                del self._pos[slot]
                req.generated = 1
                # every chunk is through the model: the pages/state hold
                # the full context, so a finish-line preemption may
                # snapshot it (resume restores instead of re-chunking)
                req.kv_written = True
                if eng.batcher.mark_prefill_done(slot):
                    eng._emit_first(slot, req, int(first[j]), emit)
                    completed.append(slot)
                # else: pool exhausted at the finish line — the batcher
                # preempted and requeued the bare prompt, WITH a snapshot
                # when the family carries one
            for i, (slot, _, _, _, valid) in enumerate(grp):
                if slot in self._pos:
                    self._pos[slot] += valid
        return sorted(set(active) | set(completed)) if completed else active


def make_prefiller(mode: str, engine):
    """'slot' | 'batched' | 'chunked'. Every model family supports every
    mode (state-carrying chunk/batch prefill covers recurrent and enc-dec
    stacks); only runtimes whose prefill branches bypass ``valid_len``
    masking (ring pools, sharded pool writers) degrade to 'slot'."""
    if mode == "batched" and engine.batchable:
        return BatchedPrefiller(engine)
    if mode == "chunked" and engine.chunkable:
        return ChunkedPrefiller(engine)
    assert mode in ("slot", "batched", "chunked"), mode
    return SlotPrefiller(engine)
