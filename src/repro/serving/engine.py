"""Decode engine: thin orchestration of tick = schedule -> prefill ->
decode -> sample.

The host loop mirrors the paper's Fig. 2(c): each iteration the host updates
the "configuration buffer" (block tables, context lengths, write targets)
and dispatches one compiled decode step; EOS requests release their pages
and their slot refills from the queue (Fig. 2(b)). The layers are split so
each is replaceable:

* scheduling — ``core.scheduler.ContinuousBatcher`` with a pluggable
  admission policy (``serving.policies``: FCFS / SJF / memory-aware);
* prefill   — ``serving.prefill``: per-slot (seed), length-bucketed batched,
  or chunked DCS-style interleave with decode;
* sampling  — ``serving.sampling``: jitted greedy / temperature / top-k;
* KV reuse  — ``repro.kvcache.PrefixCache`` (optional): radix prefix
  sharing across requests plus a host-DRAM offload tier. Admission borrows
  matched pages, prefill starts at the matched depth, and the engine
  replays the cache's queued device ops (CoW copies, swap-in scatters)
  against the pool once per tick before prefill — the host side of the
  ping-pong.

Host bookkeeping (npage/noff/block-table assembly) is vectorized over the
slot axis against the batcher's incrementally-maintained snapshots — the
per-slot Python loops were the exact host-side bottleneck the paper's
host loop avoids. Idle slots route their decode KV write to an
out-of-bounds page so the scatter drops it (the seed pointed them at page
0, which silently corrupted whichever live request owned it).

This engine is the single-host functional version (used by tests, examples
and the lazy-allocation benchmark); launch/serve.py wraps it with the mesh
sharding plan for the production layout.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import PageAllocator
from repro.core.paged_kv import PoolSpec
from repro.core.scheduler import ContinuousBatcher, Request
from repro.models import model as MDL
from repro.serving.policies import make_policy
from repro.serving.prefill import make_prefiller
from repro.serving.sampling import make_sampler


@dataclass
class EngineConfig:
    n_slots: int
    page_size: int
    n_pages: int
    max_context: int
    n_shards: int = 1
    n_rows: int = 1
    policy: str = "striped"           # page placement: striped | row_affine
    static_alloc: bool = False        # baseline-PIM static max-ctx allocation
    eos_token: int = 1
    max_prefill: int = 64             # batched-prefill bucket cap
    prefill_mode: str = "batched"     # slot | batched | chunked
    prefill_chunk: int = 32           # tokens per chunk in chunked mode
    sched_policy: str = "fcfs"        # fcfs | sjf | memory_aware
    sampler: str = "greedy"           # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0
    sample_seed: int = 0
    # ---- KV-cache hierarchy (repro.kvcache) ----
    prefix_cache: bool = False        # radix prefix sharing across requests
    prefill_dedup: bool = True        # same-tick prefix dedup at admission
    host_pages: int = 0               # host offload tier capacity (0 = none)
    offload_high: float = 0.85        # device watermarks driving offload
    offload_low: float = 0.60
    cache_evict: str = "lru"
    # ---- decode hot path (kernels/backend.py KernelConfig) ----
    use_pallas: bool | None = None    # None = autodetect (pallas on TPU)
    kernel_interpret: bool | None = None
    kernel_splits: int = 1
    # pow2 bucketing of the decode block-table width by live-page count:
    # per-step attention work tracks actual context, not max_context, with
    # at most log2(maxp) extra jit specializations (engines with <=16-page
    # tables skip it — nothing to win there)
    decode_bucket: bool = True


@dataclass
class EngineTiming:
    """Wall-clock split of the serving loop (host bookkeeping vs device)."""
    steps: int = 0
    host_s: float = 0.0               # schedule + config-buffer assembly
    prefill_s: float = 0.0
    decode_s: float = 0.0             # compiled decode step + sampling

    def as_dict(self) -> dict:
        n = max(1, self.steps)
        return {"steps": self.steps, "host_us_per_step": 1e6 * self.host_s / n,
                "prefill_s": self.prefill_s, "decode_s": self.decode_s,
                "host_s": self.host_s}


class DecodeEngine:
    def __init__(self, cfg, ecfg: EngineConfig, params=None, rt=None,
                 *, sample: Callable | None = None, policy=None):
        self.cfg = cfg
        self.ecfg = ecfg
        if rt is None:
            from repro.kernels.backend import KernelConfig
            rt = MDL.Runtime(kernels=KernelConfig(
                use_pallas=ecfg.use_pallas,
                interpret=ecfg.kernel_interpret,
                n_splits=ecfg.kernel_splits))
        self.rt = rt
        self.params = params if params is not None else MDL.init_params(
            cfg, jax.random.PRNGKey(0), jnp.float32)
        kinds = cfg.block_kinds()
        n_attn = cfg.n_layers if cfg.family == "encdec" else \
            sum(1 for k in kinds if k in ("attn", "local"))
        maxp = -(-ecfg.max_context // ecfg.page_size) + 1
        self.pool_spec = PoolSpec(
            max(n_attn, 1), ecfg.n_pages, ecfg.page_size, cfg.n_kv_heads,
            cfg.d_head, maxp, dtype="float32")
        static_pages = maxp if ecfg.static_alloc else None
        self.alloc = PageAllocator(
            ecfg.n_pages, ecfg.n_shards, ecfg.page_size, policy=ecfg.policy,
            n_rows=ecfg.n_rows, static_max_pages=static_pages)
        self.batcher = ContinuousBatcher(
            self.alloc, ecfg.n_slots, max_context=ecfg.max_context,
            n_rows=ecfg.n_rows, policy=make_policy(policy or ecfg.sched_policy),
            bt_width=self.pool_spec.max_pages_per_req)
        self.state = MDL.init_decode_state(cfg, self.pool_spec, ecfg.n_slots,
                                           dtype="float32")
        self.tokens = np.zeros((ecfg.n_slots,), np.int32)
        self.prompts: dict[int, np.ndarray] = {}
        self.outputs: dict[int, list[int]] = {}
        # ``sample``: legacy per-row host callable (seed API); otherwise the
        # jitted batch sampler from the config.
        self.sample = sample
        self.sampler = make_sampler(ecfg.sampler, temperature=ecfg.temperature,
                                    top_k=ecfg.top_k, seed=ecfg.sample_seed)
        # batched/chunked prefill keep the whole decode state in the shared
        # pool; recurrent and enc-dec families need per-slot state merges,
        # and ring / sharded-writer runtimes use prefill branches that
        # ignore valid_len (pad-write masking) — all of those stay on the
        # slot path.
        self.batchable = "layers" in self.params and cfg.family != "encdec" \
            and not self.rt.ring_width and self.rt.write_pool is None
        self.chunkable = self.batchable
        # prefix cache: uniform-attention stacks with plain lazy allocation
        # only (static reservations and ring pools can't share pages, and
        # row-affine placement would break borrowing across rows)
        self.cache = None
        if ecfg.prefix_cache and self.chunkable and not ecfg.static_alloc \
                and ecfg.policy == "striped":
            from repro.kvcache import PrefixCache, WatermarkConfig, \
                make_cache_policy
            self.cache = PrefixCache(
                self.alloc,
                policy=make_cache_policy(ecfg.cache_evict,
                                         watermark=WatermarkConfig(
                                             ecfg.offload_high,
                                             ecfg.offload_low)),
                host_pages=ecfg.host_pages,
                pool_ref=lambda: self.state["pool"])
            self.batcher.cache = self.cache
            self.batcher.cache_tokens = self._cache_tokens
            self.batcher.dedup = ecfg.prefill_dedup
        self.prefiller = make_prefiller(ecfg.prefill_mode, self)
        self.timing = EngineTiming()
        self._decode_jit = None
        self._slot_ids = np.arange(ecfg.n_slots)

    # ------------------------------------------------------------------
    def submit(self, req_id: int, prompt: np.ndarray,
               max_new_tokens: int) -> None:
        self.prompts[req_id] = np.asarray(prompt, np.int32)
        self.outputs[req_id] = []
        req = Request(req_id, len(prompt), max_new_tokens)
        if self.prefiller.name == "chunked":
            req.chunked_prefill = True
            req.prefill_done = False
        self.batcher.submit(req)

    # ---- helpers shared with the prefillers ---------------------------
    def _prompt_seq(self, req) -> tuple[np.ndarray, bool]:
        """Token sequence to prefill and whether a first token should be
        emitted. After a preemption the re-prefill covers the original
        prompt plus every generated token except the last sampled one
        (whose KV was never written; it re-enters as the next decode
        input)."""
        prompt = self.prompts[req.req_id]
        out = self.outputs[req.req_id]
        if req.prompt_len == len(prompt):
            return prompt, True
        return np.concatenate(
            [prompt, np.asarray(out[:-1], np.int32)])[:req.prompt_len], False

    def _cache_tokens(self, req, finished: bool = False) -> np.ndarray:
        """Token-sequence oracle for the prefix cache (the batcher holds no
        token ids). ``finished=False``: the context a (re)admission must
        cover — exactly ``_prompt_seq``. ``finished=True``: every token
        whose KV was written — prompt plus all generated tokens except the
        final sample (EOS / budget hit), whose KV never landed."""
        if not finished:
            return self._prompt_seq(req)[0]
        prompt = self.prompts[req.req_id]
        out = np.asarray(self.outputs[req.req_id], np.int32)
        return np.concatenate([prompt, out])[:req.total_len - 1]

    def _emit_first(self, slot: int, req, logits_row: np.ndarray,
                    emit: bool) -> None:
        # the whole prompt's KV is in the pool now: publish the prefix to
        # the radix cache so later same-prefix admissions hit while this
        # request is still running
        req.kv_written = True
        if self.cache is not None:
            self.cache.insert(req.req_id, self._prompt_seq(req)[0])
        if emit:
            tok = int(self._sample_one(logits_row))
            self.tokens[slot] = tok
            self.outputs[req.req_id].append(tok)
        else:
            self.tokens[slot] = self.outputs[req.req_id][-1]

    def _sample_one(self, logits_row) -> int:
        if self.sample is not None:
            return int(self.sample(np.asarray(logits_row)))
        return int(self.sampler(logits_row))

    def _sample_rows(self, logits) -> np.ndarray:
        """[B, V] -> [B] int32, one device call for the whole batch (legacy
        per-row callables keep per-row semantics)."""
        if self.sample is not None:
            return np.asarray([self.sample(row) for row in np.asarray(logits)],
                              np.int32)
        return np.asarray(self.sampler(logits), np.int32)

    # ------------------------------------------------------------------
    def step(self, finished_mask=None):
        """One engine tick: schedule -> prefill -> decode -> sample."""
        E = self.ecfg
        t0 = time.perf_counter()
        admitted, active = self.batcher.step(finished_mask)
        if self.cache is not None:
            # drain last tick's swap-outs + watermark offload (ping-pong),
            # then replay queued device ops (swap-in scatters, CoW copies)
            # so prefill and decode read fully materialized pages
            self.cache.maintain()
            if self.cache.has_pending:
                self.state["pool"] = self.cache.apply_pending(
                    self.state["pool"])
        t1 = time.perf_counter()
        self.timing.host_s += t1 - t0
        if admitted or self.prefiller.busy:
            active = self.prefiller.run(admitted, active)
            t2 = time.perf_counter()
            self.timing.prefill_s += t2 - t1
        self.timing.steps += 1
        if not active:
            return np.zeros((E.n_slots,), bool)

        # ---- config-buffer assembly, vectorized over slots ------------
        t3 = time.perf_counter()
        ctx = self.batcher.context_lens()
        bt = self.batcher.block_tables(self.pool_spec.max_pages_per_req)
        W = self.pool_spec.max_pages_per_req
        active_mask = np.zeros((E.n_slots,), bool)
        active_mask[active] = True
        t = ctx - 1                    # slot of the token being written
        vp = np.clip(t, 0, None) // E.page_size
        if self.rt.ring_width:
            vp = vp % self.rt.ring_width
        # idle slots target page n_pages (out of bounds) -> scatter drops
        npage = np.where(active_mask,
                         bt[self._slot_ids, np.minimum(vp, W - 1)],
                         E.n_pages).astype(np.int32)
        noff = np.where(active_mask, np.clip(t, 0, None) % E.page_size,
                        0).astype(np.int32)
        # context-adaptive table width: slice the Va2Pa table to a pow2
        # bucket of the batch's live-page high-water mark so decode
        # attention (kernel grid or gathered width alike) tracks actual
        # context, not max_context (reuses the prefill bucketing)
        if E.decode_bucket and W > 16:
            from repro.serving.prefill import decode_table_bucket
            bt = bt[:, :decode_table_bucket(self.batcher.max_live_pages(), W)]
        if self._decode_jit is None:
            def fn(params, state, tokens, bt, ctx, npage, noff):
                return MDL.decode_step(self.cfg, params, state, tokens, bt,
                                       ctx, npage, noff, rt=self.rt)
            self._decode_jit = jax.jit(fn)
        t4 = time.perf_counter()
        self.timing.host_s += t4 - t3

        logits, self.state = self._decode_jit(
            self.params, self.state, jnp.asarray(self.tokens),
            jnp.asarray(bt), jnp.asarray(ctx), jnp.asarray(npage),
            jnp.asarray(noff))
        logits = np.asarray(logits)
        if self.sample is not None:    # legacy per-row callable: active only
            nxt = np.zeros((E.n_slots,), np.int32)
            for s in active:
                nxt[s] = int(self.sample(logits[s]))
        else:
            nxt = self._sample_rows(logits)
        t5 = time.perf_counter()
        self.timing.decode_s += t5 - t4

        # ---- EOS / budget bookkeeping, vectorized ----------------------
        gen = np.asarray([0 if r is None else r.generated
                          for r in self.batcher.slots], np.int32)
        budget = np.asarray([1 if r is None else r.max_new_tokens
                             for r in self.batcher.slots], np.int32)
        self.tokens = np.where(active_mask, nxt, self.tokens).astype(np.int32)
        finished = active_mask & ((nxt == E.eos_token) | (gen >= budget))
        for s in active:
            self.outputs[self.batcher.slots[s].req_id].append(int(nxt[s]))
        self.timing.host_s += time.perf_counter() - t5
        return finished

    def run(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        finished = None
        for _ in range(max_steps):
            if self.batcher.done():
                break
            finished = self.step(finished)
        return self.outputs
