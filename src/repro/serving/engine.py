"""Decode engine: thin orchestration of tick = schedule -> prefill ->
decode -> sample.

The host loop mirrors the paper's Fig. 2(c): each iteration the host updates
the "configuration buffer" (block tables, context lengths, write targets)
and dispatches compiled decode work; EOS requests release their pages and
their slot refills from the queue (Fig. 2(b)). The layers are split so each
is replaceable:

* scheduling — ``core.scheduler.ContinuousBatcher`` with a pluggable
  admission policy (``serving.policies``: FCFS / SJF / memory-aware);
* prefill   — ``serving.prefill``: per-slot (seed), length-bucketed batched,
  or chunked DCS-style interleave with decode;
* sampling  — ``serving.sampling``: jitted greedy / temperature / top-k;
* KV reuse  — ``repro.kvcache.PrefixCache`` (optional): radix prefix
  sharing across requests plus a host-DRAM offload tier.

Two decode paths share the scheduler and prefillers:

* ``step()`` — the per-token tick (seed semantics): rebuild the config
  buffers, dispatch ONE decode step, block on the logits, sample. Kept as
  the reference path and for callers driving the engine token-by-token.
* the fused multi-step path (``run()``) — ``EngineConfig.decode_horizon``
  decode steps run inside one jit (``models.model.decode_multi``): decode,
  on-device sampling (legacy per-row ``sample=`` callables ride along
  through an ordered host-callback adapter), KV
  write-position advance and per-slot EOS/budget masking all stay on
  device, so the host syncs once per horizon instead of once per token.
  The per-slot state (block table, context, current token, remaining
  budget) is device-resident, patched incrementally from the scheduler's
  dirty-set on admission/growth/preemption — never rebuilt per step — and
  the tick is pipelined DCS-style: the scan is dispatched asynchronously,
  the next tick's result-independent host work (cache ping-pong drain,
  radix peek prefetch) overlaps device compute, and the only host<->device
  rendezvous is the horizon's token readback. Greedy outputs are
  token-identical for every horizon (each slot replays the exact per-token
  trajectory; finished slots freeze and their KV writes drop).

This engine is the single-host functional version (used by tests, examples
and the lazy-allocation benchmark); launch/serve.py wraps it with the mesh
sharding plan for the production layout.
"""
from __future__ import annotations

import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import PageAllocator
from repro.core.paged_kv import PoolSpec
from repro.core.scheduler import ContinuousBatcher, Request
from repro.models import model as MDL
from repro.serving.policies import make_policy
from repro.serving.prefill import make_prefiller
from repro.serving.request import Request as RequestSpec
from repro.serving.sampling import make_sampler, make_scan_sampler


@dataclass
class EngineConfig:
    n_slots: int
    page_size: int
    n_pages: int
    max_context: int
    n_shards: int = 1
    n_rows: int = 1
    policy: str = "striped"           # page placement: striped | row_affine
    static_alloc: bool = False        # baseline-PIM static max-ctx allocation
    eos_token: int = 1
    max_prefill: int = 64             # batched-prefill bucket cap
    prefill_mode: str = "batched"     # slot | batched | chunked
    prefill_chunk: int = 32           # tokens per chunk in chunked mode
    sched_policy: str = "fcfs"        # fcfs | sjf | memory_aware
    sampler: str = "greedy"           # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0
    sample_seed: int = 0
    # ---- fused multi-step decode ----
    # decode steps run under ONE jit per tick (host syncs once per horizon).
    # 1 = per-token dispatch trajectory (still fused-path plumbing). Greedy
    # outputs are horizon-invariant; the cost of raising it is one extra jit
    # specialization per (horizon, table-bucket) pair and up to
    # decode_horizon-1 speculatively reserved pages per slot. Clamped to 1
    # while chunked prefill is streaming so the DCS interleave granularity
    # (one chunk between consecutive decode steps) is preserved.
    decode_horizon: int = 1
    # ---- speculative decoding (draft-propose, one-pass verify) ----
    # a small draft config (name or ModelConfig) proposes up to spec_horizon
    # tokens per slot per tick via its own fused scan and (smaller) paged KV
    # pool — indexed by the TARGET's block tables, so no second allocator —
    # and ONE multi-query target pass verifies them all (greedy acceptance
    # is token-identical to target-only decoding; stochastic uses residual
    # rejection sampling, distribution-exact). Supersedes decode_horizon on
    # the fused path: each tick still costs one host sync but can emit up
    # to spec_horizon + 1 tokens per slot. Attention-only stacks on both
    # sides (configs.base.validate_draft_pair enforces tokenizer compat and
    # rollback-ability at construction).
    draft_config: Any = None
    spec_horizon: int = 4
    # gentle horizon reservation: decline to evict radix-cached pages for
    # speculative (beyond-next-token) growth, degrading the horizon instead
    # — sharing-heavy load keeps its prefix cache, at worst costing horizon
    # depth, never correctness (committed per-token growth still reclaims)
    reserve_gentle: bool = False
    # ---- KV-cache hierarchy (repro.kvcache) ----
    prefix_cache: bool = False        # radix prefix sharing across requests
    prefill_dedup: bool = True        # same-tick prefix dedup at admission
    host_pages: int = 0               # host offload tier capacity (0 = none)
    offload_high: float = 0.85        # device watermarks driving offload
    offload_low: float = 0.60
    cache_evict: str = "lru"
    # ---- decode hot path (kernels/backend.py KernelConfig) ----
    use_pallas: bool | None = None    # None = autodetect (pallas on TPU)
    kernel_interpret: bool | None = None
    kernel_splits: int = 1
    # pow2 bucketing of the decode block-table width by live-page count:
    # per-step attention work tracks actual context, not max_context, with
    # at most log2(maxp) extra jit specializations (engines with <=16-page
    # tables skip it — nothing to win there)
    decode_bucket: bool = True
    # ---- recurrent-state preemption snapshots ----
    # recurrent/enc-dec families: preemption snapshots the per-slot carry
    # (SSM/xLSTM hidden + conv states, enc-dec cross KV) AND the written KV
    # pages to host memory, so re-admission restores instead of
    # re-prefilling — the kvcache swap story applied to dense state. Off =
    # seed semantics (full recompute on resume). Slot-mode prefill never
    # consumes snapshots (it is the recompute reference path).
    state_resume: bool = True
    # ---- telemetry (repro.telemetry) ----
    # None (default) = the shared no-op facade: no registry, no scheduler
    # events hook, no trace buffer — behavior and device-sync count are
    # identical to a build without telemetry. A TelemetryConfig (or an
    # already-built Telemetry, e.g. serve.py's) turns on the metrics
    # registry / per-request tracing / Perfetto tick timeline; all of it is
    # host-side bookkeeping riding the existing horizon readback.
    telemetry: Any = None
    # ---- fault injection (repro.runtime.faults) ----
    # None (default) = the shared no-op injector: fire() short-circuits on
    # one bool check, outputs and device-sync count are bit-identical to a
    # build without the subsystem. A FaultConfig (or a live FaultInjector,
    # e.g. shared across engines by a chaos driver) arms the seeded plan:
    # allocator exhaustion, swap-tier failure/stall, serving-row death,
    # NaN logits, slow ticks, client aborts — all replayable from the seed.
    faults: Any = None
    # ---- request lifecycle hardening ----
    # bounded admission queue: submit() load-sheds (terminal, reason
    # "shed") once this many requests are waiting. 0 = unbounded (seed
    # behavior).
    max_queue: int = 0
    # per-request wall-clock deadline applied when submit() is not given
    # one explicitly; 0 = none. Expired requests are torn down at the next
    # tick's safe point wherever they are (queued, prefilling, decoding).
    default_deadline_s: float = 0.0
    # injectable time source (zero-arg callable -> seconds). None = the
    # wall clock (time.perf_counter). Deadlines, submit/first-token
    # timestamps, the scheduler's SLO policies and the request tracker all
    # read THIS clock, so a runtime.clock.VirtualClock makes trace replay
    # and deadline expiry fully deterministic. Performance accounting
    # (EngineTiming, Perfetto slices) stays on the wall clock regardless —
    # it measures the machine, not the workload.
    clock: Any = None
    # ---- graceful degradation ----
    # faults observed (injected pressure, repeated swap failures, NaN
    # quarantines) before the engine downgrades a tier: spec decoding ->
    # plain fused decode, horizon -> 1, offload tier -> device-only.
    # Sticky bits land in DecodeEngine.degraded_mode. 0 disables the
    # ladder.
    degrade_after: int = 3
    # transient swap-in failures absorbed by retry + capped exponential
    # backoff (TierStats.swap_retries) before each one starts counting
    # toward the degrade_after ladder above (kvcache/cache.py)
    swap_retry_limit: int = 3
    swap_backoff_cap: int = 8
    # ---- crash-consistent serving snapshots ----
    # snapshot_every > 0: every N ticks run() writes a serving checkpoint
    # (scheduler + slot + written-KV + recurrent-carry state) under
    # snapshot_dir using the manifest-gated runtime/checkpoint.py layout;
    # restore_snapshot() on a fresh engine resumes and finishes in-flight
    # requests token-identically (greedy).
    snapshot_dir: str | None = None
    snapshot_every: int = 0
    snapshot_keep: int = 3
    # Real-logits NaN quarantine. None (auto) arms it together with fault
    # injection; True forces it on for hardened deployments. Off by
    # default because greedy argmax over a non-finite row is still
    # deterministic — callers that feed garbage ids (e.g. stress tests
    # with out-of-vocab prompts) keep the pre-hardening sample-as-is
    # behavior unless they opt in.
    nan_guard: bool | None = None
    # ---- disaggregated serving (serving/cluster.py) ----
    # the engine's role in a cluster: "prefill" engines run requests to
    # their first token and hand them off, "decode" engines adopt and
    # finish them, "both" is the colocated single-engine behavior. The
    # engine itself only records the role — the cluster's router enforces
    # it (a standalone engine ignores this field entirely).
    role: str = "both"


@dataclass
class EngineTiming:
    """Wall-clock split of the serving loop (host bookkeeping vs device)."""
    steps: int = 0
    host_s: float = 0.0               # schedule + config-buffer assembly
    prefill_s: float = 0.0
    decode_s: float = 0.0             # compiled decode step + sampling
    device_syncs: int = 0             # host<->device decode rendezvous
    decode_tokens: int = 0            # tokens emitted by decode dispatches

    def as_dict(self) -> dict:
        n = max(1, self.steps)
        return {"steps": self.steps, "host_us_per_step": 1e6 * self.host_s / n,
                "prefill_s": self.prefill_s, "decode_s": self.decode_s,
                "host_s": self.host_s, "device_syncs": self.device_syncs,
                "decode_tokens": self.decode_tokens,
                "syncs_per_token": self.device_syncs
                / max(1, self.decode_tokens)}


class DeviceSlotState:
    """Device-resident per-slot decode state for the fused multi-step path.

    Holds the block table [n_slots, W], context lengths, current tokens and
    remaining budgets as jax arrays plus the sampler's PRNG key chain. The
    fused scan advances them ON DEVICE; the host only patches the rows the
    scheduler marked dirty (admission / page growth / free) — the
    incremental "configuration buffer" update of the paper's host loop, at
    horizon rather than token granularity. Patch row-counts are pow2-padded
    (repeating the last entry — idempotent) so the donated-buffer scatter
    jit compiles O(log n_slots) variants.
    """

    def __init__(self, n_slots: int, width: int, seed: int, donate: bool):
        self.bt = jnp.full((n_slots, width), -1, jnp.int32)
        self.ctx = jnp.zeros((n_slots,), jnp.int32)
        self.tokens = jnp.zeros((n_slots,), jnp.int32)
        self.rem = jnp.zeros((n_slots,), jnp.int32)
        self.key = jax.random.PRNGKey(seed)
        self._patch = jax.jit(
            DeviceSlotState._patch_fn,
            donate_argnums=(0, 1, 2, 3) if donate else ())

    @staticmethod
    def _patch_fn(bt, ctx, tok, rem, idx, bt_rows, ctx_v, tok_v, rem_v):
        return (bt.at[idx].set(bt_rows), ctx.at[idx].set(ctx_v),
                tok.at[idx].set(tok_v), rem.at[idx].set(rem_v))

    def patch(self, slots: list[int], bt_rows, ctx_v, tok_v, rem_v) -> None:
        n, m = len(slots), 1
        while m < n:
            m *= 2
        pad = [slots[-1]] * (m - n)
        idx = np.asarray(slots + pad, np.int32)
        rep = [bt_rows[-1:]] * (m - n)
        self.bt, self.ctx, self.tokens, self.rem = self._patch(
            self.bt, self.ctx, self.tokens, self.rem, jnp.asarray(idx),
            jnp.asarray(np.concatenate([bt_rows] + rep) if pad else bt_rows),
            jnp.asarray(np.concatenate([ctx_v, ctx_v[-1:].repeat(m - n)])),
            jnp.asarray(np.concatenate([tok_v, tok_v[-1:].repeat(m - n)])),
            jnp.asarray(np.concatenate([rem_v, rem_v[-1:].repeat(m - n)])))


class DecodeEngine:
    def __init__(self, cfg, ecfg: EngineConfig, params=None, rt=None,
                 *, sample: Callable | None = None, policy=None,
                 draft_params=None):
        self.cfg = cfg
        self.ecfg = ecfg
        if rt is None:
            from repro.kernels.backend import KernelConfig
            rt = MDL.Runtime(kernels=KernelConfig(
                use_pallas=ecfg.use_pallas,
                interpret=ecfg.kernel_interpret,
                n_splits=ecfg.kernel_splits))
        self.rt = rt
        # draft/target compat is validated BEFORE any params are allocated:
        # a tokenizer (vocab) mismatch must fail here, loudly, not as a
        # shape error inside the compiled verify pass
        self.draft_cfg = None
        if ecfg.draft_config is not None:
            from repro.configs.base import validate_draft_pair
            dcfg = ecfg.draft_config
            if isinstance(dcfg, str):
                from repro.configs import get_config
                dcfg = get_config(dcfg)
            validate_draft_pair(cfg, dcfg)
            if rt.ring_width or rt.write_pool is not None:
                raise ValueError(
                    "speculative decode rides the fused batchable path; "
                    "ring-buffer / sharded-writer runtimes are per-slot")
            if sample is not None:
                raise ValueError(
                    "speculative decode needs the jitted sampler kinds "
                    "(greedy/temperature/top_k) so the draft's proposal "
                    "distribution is known to the verifier; legacy per-row "
                    "sample= callables cannot be verified against")
            self.draft_cfg = dcfg
        self.params = params if params is not None else MDL.init_params(
            cfg, jax.random.PRNGKey(0), jnp.float32)
        kinds = cfg.block_kinds()
        n_attn = cfg.n_layers if cfg.family == "encdec" else \
            sum(1 for k in kinds if k in ("attn", "local"))
        maxp = -(-ecfg.max_context // ecfg.page_size) + 1
        self.pool_spec = PoolSpec(
            max(n_attn, 1), ecfg.n_pages, ecfg.page_size, cfg.n_kv_heads,
            cfg.d_head, maxp, dtype="float32")
        static_pages = maxp if ecfg.static_alloc else None
        self.alloc = PageAllocator(
            ecfg.n_pages, ecfg.n_shards, ecfg.page_size, policy=ecfg.policy,
            n_rows=ecfg.n_rows, static_max_pages=static_pages)
        # behavioral time source (deadlines, SLO budgets, request
        # timestamps); see EngineConfig.clock
        self.clock = ecfg.clock if ecfg.clock is not None \
            else time.perf_counter
        self.batcher = ContinuousBatcher(
            self.alloc, ecfg.n_slots, max_context=ecfg.max_context,
            n_rows=ecfg.n_rows, policy=make_policy(policy or ecfg.sched_policy),
            bt_width=self.pool_spec.max_pages_per_req)
        self.batcher.clock = self.clock
        self.state = MDL.init_decode_state(cfg, self.pool_spec, ecfg.n_slots,
                                           dtype="float32")
        self.tokens = np.zeros((ecfg.n_slots,), np.int32)
        self.prompts: dict[int, np.ndarray] = {}
        self.outputs: dict[int, list[int]] = {}
        # TTFT bookkeeping (benchmarks): wall-clock of submit and of the
        # request's first emitted token
        self.submit_t: dict[int, float] = {}
        self.first_tok_t: dict[int, float] = {}
        # ``sample``: legacy per-row host callable (seed API); otherwise the
        # jitted batch sampler from the config. Legacy callables ride the
        # fused scan through the ordered host-callback adapter
        # (sampling.make_callback_sampler), so run() stays on the fused
        # multi-step path either way.
        self.sample = sample
        self.sampler = make_sampler(ecfg.sampler, temperature=ecfg.temperature,
                                    top_k=ecfg.top_k, seed=ecfg.sample_seed)
        # batched/chunked prefill: attention stacks keep the whole decode
        # state in the shared pool; recurrent and enc-dec families thread
        # their per-slot state rows through the group call as an explicit
        # carry (gather -> prefill -> scatter). Only ring / sharded-writer
        # runtimes stay on the slot path — their prefill branches ignore
        # valid_len (pad-write masking).
        self.batchable = not self.rt.ring_width and self.rt.write_pool is None
        self.chunkable = self.batchable
        # recurrent / cross-attention per-slot state rows ([L, n_slots, ...]
        # leaves of self.state) and their preemption snapshots
        self.has_rstate = bool(MDL.rstate_entries(self.state))
        self._zero_rows = (MDL.init_rstate(cfg, 1, dtype="float32")
                           if self.has_rstate else None)
        self.rsnaps: dict[int, dict] = {}   # req_id -> {len, rows, kv?}
        self.rstate_snapshots = 0
        self.rstate_restores = 0
        self.batcher.rstate_hook = self._rstate_hook
        # prefix cache: uniform-attention stacks with plain lazy allocation
        # only (static reservations and ring pools can't share pages,
        # row-affine placement would break borrowing across rows, and
        # recurrent/enc-dec families can't resume from shared pages without
        # the matching dense carry)
        self.cacheable = self.chunkable and "layers" in self.params \
            and cfg.family != "encdec"
        self.cache = None
        if ecfg.prefix_cache and self.cacheable and not ecfg.static_alloc \
                and ecfg.policy == "striped":
            from repro.kvcache import PrefixCache, WatermarkConfig, \
                make_cache_policy
            self.cache = PrefixCache(
                self.alloc,
                policy=make_cache_policy(ecfg.cache_evict,
                                         watermark=WatermarkConfig(
                                             ecfg.offload_high,
                                             ecfg.offload_low)),
                host_pages=ecfg.host_pages,
                pool_ref=lambda: self.state["pool"],
                swap_retry_limit=ecfg.swap_retry_limit,
                swap_backoff_cap=ecfg.swap_backoff_cap)
            self.batcher.cache = self.cache
            self.batcher.cache_tokens = self._cache_tokens
            self.batcher.dedup = ecfg.prefill_dedup
        self.prefiller = make_prefiller(ecfg.prefill_mode, self)
        self.timing = EngineTiming()
        self._decode_jit = None
        self._slot_ids = np.arange(ecfg.n_slots)
        # ---- fused multi-step decode machinery ----
        # buffer donation only where the runtime honors it (TPU/GPU); on CPU
        # it is a no-op that warns per compile
        self._donate = jax.default_backend() not in ("cpu",)
        self.dev = DeviceSlotState(ecfg.n_slots,
                                   self.pool_spec.max_pages_per_req,
                                   ecfg.sample_seed, self._donate)
        self._fused_jit = None
        # in-flight horizon: (toks, emit, fin, [(slot, req)], spec) — device
        # futures; collected at the next tick's sync point. ``spec`` is None
        # on plain horizons, (accept_len_device, nprop_host) on speculative
        # ones.
        self._inflight: tuple | None = None
        # finished mask collected by a drain outside the tick loop, consumed
        # by the next tick's scheduler call
        self._pending_fin: np.ndarray | None = None
        # snapshots taken as DEVICE futures at preempt time, drained to host
        # numpy in the next tick's overlap window (kvcache ping-pong style)
        self._snap_pending: list[int] = []
        # ---- speculative-decode machinery ----
        self.draft_params = None
        self._dstate = None
        if self.draft_cfg is not None:
            dcfg = self.draft_cfg
            # the draft pool is indexed by the TARGET's block tables — same
            # page ids, smaller per-page payload (draft layers/heads), no
            # second allocator. Draft KV at (page, offset) is a pure
            # function of the token prefix at that position, so pages
            # shared by the radix cache stay coherent: every borrower
            # recomputes bit-identical rows.
            self.draft_spec = PoolSpec(
                dcfg.n_layers, ecfg.n_pages, ecfg.page_size, dcfg.n_kv_heads,
                dcfg.d_head, maxp, dtype="float32")
            self.draft_params = draft_params if draft_params is not None \
                else MDL.init_params(dcfg, jax.random.PRNGKey(1), jnp.float32)
            self._dstate = MDL.init_decode_state(
                dcfg, self.draft_spec, ecfg.n_slots, dtype="float32")
            self._dkey = jax.random.PRNGKey(ecfg.sample_seed + 1)
            # req_id -> tokens the draft pool has absorbed (its KV covers
            # positions [0, dlen)); reset to 0 at every (re)admission —
            # swap-ins, CoW copies and preemption resumes only restore the
            # TARGET's pages, so the draft catches up by recomputing
            self._dlen: dict[int, int] = {}
            self._spec_jits = None
            self._catchup_jit = None
            self.spec_rounds = 0        # verify passes over running slots
            self.spec_proposed = 0      # draft tokens offered
            self.spec_accepted = 0      # draft tokens accepted
        # ---- fault injection + request lifecycle hardening (PR 8) ----
        # one injector threaded through scheduler and cache so every
        # subsystem's injection decisions share the seeded plan
        from repro.runtime.faults import make_faults
        self.faults = make_faults(ecfg.faults)
        self.nan_guard = (self.faults.enabled if ecfg.nan_guard is None
                          else ecfg.nan_guard)
        self.batcher.faults = self.faults
        if self.cache is not None:
            self.cache.faults = self.faults
        # terminal-but-not-finished requests: req_id -> reason
        # (client / deadline / nan / shed / chaos)
        self.aborted: dict[int, str] = {}
        self.abort_counts: dict[str, int] = {
            "client": 0, "deadline": 0, "nan": 0, "shed": 0, "chaos": 0,
            "handoff": 0}
        # aborts requested mid-tick; torn down at the next safe point (a
        # teardown while a horizon is in flight would free pages its KV
        # writes still target — re-admitted, they'd be corrupted)
        self._abort_req: dict[int, str] = {}
        # req_id -> absolute wall-clock deadline (perf_counter frame)
        self.deadline_t: dict[int, float] = {}
        # sticky degradation bitmask: 1 = horizon->1, 2 = spec off,
        # 4 = host tier dropped
        self.degraded_mode = 0
        # serving snapshot bookkeeping (save_snapshot / restore_snapshot)
        self.snapshot_saves = 0
        self.snapshot_restores = 0
        self.snapshot_rejects = 0       # torn/corrupt steps skipped
        self._tick_no = 0
        # ---- telemetry (must come last: bindings read everything above).
        # Disabled -> the shared NULL facade; the scheduler's events hook
        # stays None and every tel.* call below is a bound no-op.
        from repro.telemetry import make_telemetry
        self.tel = make_telemetry(ecfg.telemetry)
        self.tel.attach_engine(self)
        # (dispatch wall-clock, dispatch-time ctx snapshot, horizon seq) of
        # the in-flight horizon — feeds the inferred device span and the
        # modeled-bytes counter at collect; stays None when tel is off
        self._horizon_meta: tuple | None = None
        self._horizon_seq = 0

    # ---- unified timing/trace phase helper ----------------------------
    @contextmanager
    def _phase(self, acc: str, track: str | None = None,
               name: str | None = None):
        """Accumulate one timed segment into ``EngineTiming.<acc>`` —
        the SINGLE bookkeeping path both ``step()`` and the fused tick use,
        so host/prefill/decode splits stay consistent when the APIs
        interleave — and, when tracing, emit the segment as a Perfetto
        slice on ``track``."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            setattr(self.timing, acc, getattr(self.timing, acc) + dt)
            tr = self.tel.trace
            if tr is not None and track is not None:
                tr.slice(track, name or acc, t0, dt)

    # ------------------------------------------------------------------
    def submit(self, req: RequestSpec | int, prompt: np.ndarray = None,
               max_new_tokens: int | None = None, *,
               deadline_s: float | None = None) -> bool:
        """Enqueue a request described by a ``serving.Request`` spec.
        Returns False when the bounded queue is full and the request was
        load-shed instead (terminal immediately, reason ``shed``, empty
        output). ``spec.deadline_s`` (or the engine default) arms a
        deadline in the engine's clock frame; an expired request is torn
        down at the next tick wherever it is in its lifecycle. Priority
        and TTFT/TPOT targets ride the spec into the scheduling policies
        and the request tracker.

        The legacy positional form ``submit(req_id, prompt,
        max_new_tokens, deadline_s=...)`` survives as a deprecated shim.
        """
        if not isinstance(req, RequestSpec):
            warnings.warn(
                "Engine.submit(req_id, prompt, max_new_tokens, ...) is "
                "deprecated; pass a serving.Request spec",
                DeprecationWarning, stacklevel=2)
            req = RequestSpec(req, prompt, max_new_tokens,
                              deadline_s=deadline_s)
        spec = req
        req_id = spec.req_id
        prompt = np.asarray(spec.prompt, np.int32)
        self.prompts[req_id] = prompt
        self.outputs[req_id] = []
        now = self.submit_t[req_id] = self.clock()
        self.tel.on_submit(req_id, len(prompt), spec.max_new_tokens, now,
                           spec=spec)
        sreq = Request(req_id, len(prompt), spec.max_new_tokens,
                       priority=spec.priority, submit_t=now, spec=spec)
        E = self.ecfg
        if E.max_queue and len(self.batcher.queue) >= E.max_queue:
            self.aborted[req_id] = "shed"
            self.abort_counts["shed"] += 1
            self.tel.on_abort(sreq, -1, "shed")
            return False
        dl = E.default_deadline_s if spec.deadline_s is None \
            else spec.deadline_s
        if dl and dl > 0:
            self.deadline_t[req_id] = now + dl
        if self.prefiller.name == "chunked":
            sreq.chunked_prefill = True
            sreq.prefill_done = False
        self.batcher.submit(sreq)
        return True

    def abort(self, req_id: int, reason: str = "client") -> bool:
        """Client-side cancel. The teardown is DEFERRED to the next tick's
        safe point (post-collect quiescence): freeing a slot's pages while
        a decode horizon is still in flight would let its KV writes land in
        pages a re-admission now owns. Returns True if the request was
        live (queued or running) when the abort was recorded."""
        live = any(r is not None and r.req_id == req_id
                   for r in self.batcher.slots) \
            or any(r.req_id == req_id for r in self.batcher.queue)
        if live:
            self._abort_req.setdefault(req_id, reason)
        return live

    # ---- helpers shared with the prefillers ---------------------------
    def _prompt_seq(self, req) -> tuple[np.ndarray, bool]:
        """Token sequence to prefill and whether a first token should be
        emitted. After a preemption the re-prefill covers the original
        prompt plus every generated token except the last sampled one
        (whose KV was never written; it re-enters as the next decode
        input)."""
        prompt = self.prompts[req.req_id]
        out = self.outputs[req.req_id]
        if req.prompt_len == len(prompt):
            # emit only when no first token exists yet: a re-admission at
            # exactly prompt depth with output already streamed (a handoff
            # or engine-death re-drive at generated == 0) must not sample a
            # duplicate — the existing first token re-enters as the
            # pending decode input instead
            return prompt, not out
        return np.concatenate(
            [prompt, np.asarray(out[:-1], np.int32)])[:req.prompt_len], False

    def _cache_tokens(self, req, finished: bool = False) -> np.ndarray:
        """Token-sequence oracle for the prefix cache (the batcher holds no
        token ids). ``finished=False``: the context a (re)admission must
        cover — exactly ``_prompt_seq``. ``finished=True``: every token
        whose KV was written — prompt plus all generated tokens except the
        final sample (EOS / budget hit), whose KV never landed."""
        if not finished:
            return self._prompt_seq(req)[0]
        prompt = self.prompts[req.req_id]
        out = np.asarray(self.outputs[req.req_id], np.int32)
        return np.concatenate([prompt, out])[:req.total_len - 1]

    def _emit_first(self, slot: int, req, tok: int | None,
                    emit: bool) -> None:
        # the whole prompt's KV is in the pool now: publish the prefix to
        # the radix cache so later same-prefix admissions hit while this
        # request is still running
        req.kv_written = True
        if self.cache is not None:
            self.cache.insert(req.req_id, self._prompt_seq(req)[0])
        if emit:
            self.tokens[slot] = tok
            self.outputs[req.req_id].append(int(tok))
            self.first_tok_t.setdefault(req.req_id, self.clock())
            if self.tel.enabled:
                self.tel.on_tokens(req.req_id, 1,
                                   self.first_tok_t[req.req_id])
        else:
            self.tokens[slot] = self.outputs[req.req_id][-1]
        self.batcher.dirty.add(slot)

    def _sample_rows(self, logits) -> np.ndarray:
        """[B, V] -> [B] int32, one device call for the whole batch. Legacy
        per-row callables keep per-row semantics, but over a single
        host-gathered array (one transfer, not one per slot)."""
        if self.sample is not None:
            rows = np.asarray(logits)
            return np.fromiter((int(self.sample(r)) for r in rows),
                               np.int32, len(rows))
        return np.asarray(self.sampler(logits), np.int32)

    def _first_tokens(self, logits, emits) -> np.ndarray:
        """Sample the first token for a prefill group in ONE batched call
        (greedy-invariant; only rows that emit are sampled, preserving the
        resumed-request no-sample semantics)."""
        toks = np.zeros((len(emits),), np.int32)
        idx = [i for i, e in enumerate(emits) if e]
        if idx:
            toks[idx] = self._sample_rows(np.asarray(logits)[idx])
        return toks

    # ---- recurrent state rows: snapshots, restore, group gather ---------
    def _rstate_hook(self, req, slot: int, finished: bool) -> None:
        """Scheduler callback at page release. Preemption (finished=False)
        snapshots the slot's recurrent/cross state rows plus its written KV
        pages to host memory — the dense-state analogue of the kvcache
        swap-out — so re-admission restores instead of recomputing.
        Completion drops any stored snapshot."""
        if finished:
            self.rsnaps.pop(req.req_id, None)
            return
        if not (self.has_rstate and self.ecfg.state_resume
                and req.kv_written and self.prefiller.name != "slot"):
            return
        if not self.outputs.get(req.req_id):
            # no token ever sampled (prefill finished but the finish-line
            # growth page failed): a pure restore could never produce the
            # first token — no logits without a model call — so resume
            # must recompute; snapshotting would strand the request
            return
        # written context: the last sampled token's KV/state never landed
        # (it re-enters as the next decode input), and ``generated`` was
        # pre-incremented this tick — mirrors _preempt's total_len - 1
        depth = req.total_len - (1 if req.generated else 0)
        if depth <= 0:
            return
        # the gathers are DISPATCHED here (they must read the pool before
        # the released pages are rewritten — device-stream order guarantees
        # that) but NOT synced: the device arrays park in the snapshot and
        # the host copy happens in the next tick's overlap window
        # (_drain_snapshots), so snapshot latency hides under decode exactly
        # like the kvcache swap-out ping-pong. A restore that arrives
        # before the drain consumes the device arrays directly.
        snap = {"len": depth,
                "rows": MDL.gather_rstate(self.state, [slot])}
        if "pool" in self.state:
            from repro.core.paged_kv import gather_pages
            n = -(-depth // self.ecfg.page_size)
            pages = np.asarray(self.batcher.block_table_row(slot)[:n])
            k, v = gather_pages(self.state["pool"]["k"],
                                self.state["pool"]["v"], jnp.asarray(pages))
            snap["kv"] = (k, v)
        self.rsnaps[req.req_id] = snap
        self._snap_pending.append(req.req_id)
        self.rstate_snapshots += 1

    def _drain_snapshots(self) -> None:
        """Materialize pending preemption snapshots to host numpy (the
        drain half of the snapshot ping-pong). Snapshots restored before
        their drain were consumed as device arrays and are gone from
        ``rsnaps`` — skip them."""
        for rid in self._snap_pending:
            snap = self.rsnaps.get(rid)
            if snap is None:
                continue
            snap["rows"] = jax.tree.map(np.asarray, snap["rows"])
            if "kv" in snap:
                snap["kv"] = tuple(np.asarray(x) for x in snap["kv"])
        self._snap_pending.clear()

    def _take_snapshot(self, req) -> dict | None:
        if not self.ecfg.state_resume:
            return None
        return self.rsnaps.pop(req.req_id, None)

    def _begin_prefill_group(self, admitted) -> tuple[dict, set]:
        """Prepare the tick's admitted slots for prefill in ONE rows
        scatter: preemption snapshots restore the carry (and their KV
        pages) at their depth, everything else resets to zero so group
        prefill gathers a clean carry (the row may hold a freed request's
        state). Returns ``({slot: resume_depth}, {restored slots})`` —
        depth is the snapshot depth or the prefix-cache depth (0 when
        cold). Enc-dec cross-KV rows are NOT materialized here — batched
        prefill computes them inside the group call, chunked prefill
        batches them per tick (``_init_cross_rows``)."""
        starts: dict[int, int] = {}
        fresh: list[int] = []
        restores: list[tuple[int, dict]] = []
        for slot, req in admitted:
            snap = self._take_snapshot(req)
            if snap is not None:
                restores.append((slot, snap))
                starts[slot] = snap["len"]
            else:
                fresh.append(slot)
                starts[slot] = req.cached_len
        if self.has_rstate and (fresh or restores):
            parts = []
            if fresh:
                parts.append(jax.tree.map(
                    lambda z: jnp.repeat(z, len(fresh), axis=1),
                    self._zero_rows))
            parts += [jax.tree.map(jnp.asarray, snap["rows"])
                      for _, snap in restores]
            self.state = MDL.scatter_rstate(
                self.state, fresh + [s for s, _ in restores],
                jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                             *parts))
        for slot, snap in restores:
            if "kv" in snap:
                from repro.core.paged_kv import scatter_pages
                k, v = snap["kv"]
                pages = self.batcher.block_table_row(slot)[:k.shape[1]]
                pk, pv = scatter_pages(self.state["pool"]["k"],
                                       self.state["pool"]["v"],
                                       jnp.asarray(np.asarray(pages)),
                                       jnp.asarray(k), jnp.asarray(v))
                self.state["pool"] = {"k": pk, "v": pv}
        self.rstate_restores += len(restores)
        return starts, {s for s, _ in restores}

    def _init_cross_rows(self, slots: list[int]) -> None:
        """Encoder pass + cross-KV projection for enc-dec chunked prefill
        (stub zero frames, matching the slot path)."""
        frames = jnp.zeros((len(slots), self.cfg.enc_seq, self.cfg.d_model),
                           jnp.float32)
        enc_out = MDL.encode(self.cfg, self.params, frames)
        ck, cv = MDL.make_cross_kv(self.cfg, self.params, enc_out)
        self.state = MDL.scatter_rstate(self.state, slots,
                                        {"cross_k": ck, "cross_v": cv})

    def _group_prefill_state(self, slots: list[int]) -> dict:
        """State for a group prefill call: the shared pool plus the group's
        recurrent/cross rows gathered from the engine state (zeroed /
        restored by ``_begin_prefill``, or mid-stream carries for chunked
        prefill)."""
        gs: dict[str, Any] = {}
        if "pool" in self.state:
            gs["pool"] = self.state["pool"]
        if self.has_rstate:
            gs.update(MDL.gather_rstate(self.state,
                                        np.asarray(slots, np.int32)))
        return gs

    def _merge_group_state(self, slots: list[int], gstate: dict) -> None:
        """Fold a group prefill's result back: adopt the pool, scatter the
        group's state rows into their slots."""
        if "pool" in gstate:
            self.state["pool"] = gstate["pool"]
        if self.has_rstate:
            self.state = MDL.scatter_rstate(
                self.state, np.asarray(slots, np.int32),
                MDL.rstate_entries(gstate))

    # ---- fault processing + terminal teardown (the tick safe point) ----
    def _find_request(self, req_id: int):
        """``(slot, req)`` for a running request, ``(None, req)`` for a
        queued one, ``(None, None)`` when the id is not live."""
        for s, r in enumerate(self.batcher.slots):
            if r is not None and r.req_id == req_id:
                return s, r
        for r in self.batcher.queue:
            if r.req_id == req_id:
                return None, r
        return None, None

    def _teardown(self, req_id: int, reason: str) -> bool:
        """Terminal teardown of a live request: scheduler state (pages,
        pins, pending swap ops) via abort_slot/abort_queued, then every
        engine-side reference — carry snapshots, draft-pool coverage,
        deadline tracking. Zero leaks is the contract the robustness tests
        assert at drain."""
        s, req = self._find_request(req_id)
        if req is None:
            return False
        if s is not None:
            self.batcher.abort_slot(s, reason)
        else:
            self.batcher.abort_queued(req, reason)
        self.rsnaps.pop(req_id, None)
        if self._dstate is not None:
            self._dlen.pop(req_id, None)
        self.deadline_t.pop(req_id, None)
        self.aborted[req_id] = reason
        self.abort_counts[reason] = self.abort_counts.get(reason, 0) + 1
        return True

    def _process_row_death(self, finished) -> None:
        """Injected serving-row death: every non-finishing request whose
        pages live on a dead row (physical row under ``row_affine``
        placement, the slot's logical row group under striped) is drained —
        KV on a dead row is garbage, so ``drain_slot`` frees without a
        cache insert and requeues for a full re-prefill of the
        reconstructable context (``elastic.plan_request_migration`` picks
        the victims)."""
        E = self.ecfg
        dead = {row for row in range(max(1, E.n_rows))
                if self.faults.fire("row_death", key=row)}
        if not dead:
            return
        from repro.runtime.elastic import plan_request_migration
        row_of: dict[int, int] = {}
        slot_of: dict[int, int] = {}
        for s, r in enumerate(self.batcher.slots):
            if r is None or (finished is not None and finished[s]):
                continue                # finishing this tick: output is done
            row = self.alloc.row_of_request(r.req_id)
            if row is None:             # striped: logical serving rows
                row = self.batcher._row_of_slot(s)
            row_of[r.req_id] = row
            slot_of[r.req_id] = s
        for rid in plan_request_migration(row_of, dead):
            s = slot_of[rid]
            req = self.batcher.slots[s]
            out = self.outputs[rid]
            if req.prefill_done and out:
                # normalize to the really-emitted frame before the requeue
                # arithmetic (a zero-emission horizon can leave
                # ``generated`` pre-incremented for an unsampled token)
                P = len(self.prompts[rid])
                req.generated = min(
                    req.generated,
                    max(0, len(out) - 1 - (req.prompt_len - P)))
            else:
                req.generated = 0
            self.batcher.drain_slot(s)
            self.rsnaps.pop(rid, None)
            if self._dstate is not None:
                self._dlen.pop(rid, None)

    def _update_degradation(self) -> None:
        """Sticky degradation ladder, driven ONLY by injected pressure and
        real fault observations (never by ordinary preemption — a healthy
        loaded engine must keep its exact perf profile): at degrade_after
        faults, drop speculative decoding (or the horizon, draft-less); at
        2x, the horizon too; repeated swap-tier failures drop the host
        tier (cached host pages invalidated, device-only from then on)."""
        E = self.ecfg
        if not E.degrade_after:
            return
        inj = self.faults.counts
        pressure = (inj.get("alloc_exhaust", 0) + inj.get("row_death", 0)
                    + self.abort_counts.get("nan", 0))
        if pressure >= E.degrade_after:
            if self._dstate is not None:
                self.degraded_mode |= 2
            else:
                self.degraded_mode |= 1
        if pressure >= 2 * E.degrade_after:
            self.degraded_mode |= 1
        if (self.cache is not None and self.cache.host is not None
                and self.cache.stats.swap_in_fails >= E.degrade_after):
            self.cache.drop_host_tier()
            self.degraded_mode |= 4

    def _process_faults(self, finished) -> None:
        """The tick's SAFE POINT: post-collect quiescence (no horizon in
        flight, ``generated`` counts only really-emitted tokens for every
        slot that emitted), before the scheduler reuses anything. Advances
        the fault clock, injects this tick's plan (straggler sleeps,
        seeded client aborts, row deaths), expires deadlines, tears down
        every requested abort, and updates the degradation ladder.
        ``finished`` is the tick's natural-finish mask — a finish beats a
        same-tick abort (the output is already complete), except NaN
        quarantine, whose tokens are invalid by definition."""
        self._tick_no += 1
        F = self.faults
        F.on_tick()
        if F.enabled:
            if F.fire("slow_tick"):
                time.sleep(F.cfg.slow_tick_s)
            live = [r for r in self.batcher.slots if r is not None] \
                + list(self.batcher.queue)
            for r in live:
                if F.fire("client_abort", key=r.req_id):
                    self._abort_req.setdefault(r.req_id, "chaos")
            self._process_row_death(finished)
        if self.deadline_t:
            now = self.clock()
            for rid, t in list(self.deadline_t.items()):
                s, req = self._find_request(rid)
                if req is None or (s is not None and finished is not None
                                   and finished[s]):
                    # terminal, or finishing this very tick (the natural
                    # finish beats a same-tick expiry): stop tracking
                    self.deadline_t.pop(rid)
                elif now >= t:
                    self._abort_req.setdefault(rid, "deadline")
        if self._abort_req:
            for rid, reason in list(self._abort_req.items()):
                s, req = self._find_request(rid)
                if req is None:
                    continue               # already terminal
                if s is not None and finished is not None and finished[s] \
                        and reason != "nan":
                    continue               # natural finish this tick wins
                self._teardown(rid, reason)
            self._abort_req.clear()
        self._update_degradation()

    # ------------------------------------------------------------------
    def step(self, finished_mask=None):
        """One per-token engine tick: schedule -> prefill -> decode ->
        sample, blocking on the step's logits (seed semantics; the fused
        multi-step path in ``run()`` supersedes this on the hot path).

        Interleaves safely with the fused path: a pending fused finished
        mask is consumed when the caller passes none, active slots are
        marked dirty (this tick advances tokens/ctx host-side only, so the
        device mirror must re-sync before the next horizon), and the
        returned mask is also stashed for a later ``run()``."""
        E = self.ecfg
        with self._phase("host_s", "host", "schedule"):
            self._drain_snapshots()
            if self._pending_fin is not None:
                finished_mask = self._pending_fin if finished_mask is None \
                    else (np.asarray(finished_mask, bool) | self._pending_fin)
                self._pending_fin = None
            self._process_faults(finished_mask)
            admitted, active = self.batcher.step(finished_mask)
            if self.cache is not None:
                # drain last tick's swap-outs + watermark offload
                # (ping-pong), then replay queued device ops (swap-in
                # scatters, CoW copies) so prefill and decode read fully
                # materialized pages — unless an injected swap-tier stall
                # skips the drain for this tick
                if not (self.faults.enabled
                        and self.faults.fire("swap_stall")):
                    self.cache.maintain()
                if self.cache.has_pending:
                    self.state["pool"] = self.cache.apply_pending(
                        self.state["pool"])
        if admitted or self.prefiller.busy:
            with self._phase("prefill_s", "prefill", "prefill"):
                active = self.prefiller.run(admitted, active)
        self.timing.steps += 1
        if not active:
            return np.zeros((E.n_slots,), bool)

        # ---- config-buffer assembly, vectorized over slots ------------
        with self._phase("host_s", "host", "config"):
            ctx = self.batcher.context_lens()
            bt = self.batcher.block_tables(self.pool_spec.max_pages_per_req)
            W = self.pool_spec.max_pages_per_req
            active_mask = np.zeros((E.n_slots,), bool)
            active_mask[active] = True
            # host-numpy twin of kernels.ops.write_targets (the fused scan's
            # device-side resolution) — the two must stay bit-identical for
            # step() and run() to agree (regression: mixed step/run test)
            t = ctx - 1                # slot of the token being written
            vp = np.clip(t, 0, None) // E.page_size
            if self.rt.ring_width:
                vp = vp % self.rt.ring_width
            # idle slots target page n_pages (out of bounds) -> scatter drops
            npage = np.where(active_mask,
                             bt[self._slot_ids, np.minimum(vp, W - 1)],
                             E.n_pages).astype(np.int32)
            noff = np.where(active_mask, np.clip(t, 0, None) % E.page_size,
                            0).astype(np.int32)
            # context-adaptive table width: slice the Va2Pa table to a pow2
            # bucket of the batch's live-page high-water mark so decode
            # attention (kernel grid or gathered width alike) tracks actual
            # context, not max_context (reuses the prefill bucketing)
            if E.decode_bucket and W > 16:
                from repro.serving.prefill import decode_table_bucket
                bt = bt[:, :decode_table_bucket(self.batcher.max_live_pages(),
                                                W)]
            if self._decode_jit is None:
                def fn(params, state, tokens, bt, ctx, npage, noff, run):
                    return MDL.decode_step(self.cfg, params, state, tokens,
                                           bt, ctx, npage, noff, run=run,
                                           rt=self.rt)
                self._decode_jit = jax.jit(fn)

        # ``run`` masks the recurrent-state advance: idle and mid-chunk-
        # prefill slots must not absorb their stale pending token (their
        # attention KV writes already drop via the out-of-bounds npage)
        with self._phase("decode_s", "sync", "decode+sample"):
            logits, self.state = self._decode_jit(
                self.params, self.state, jnp.asarray(self.tokens),
                jnp.asarray(bt), jnp.asarray(ctx), jnp.asarray(npage),
                jnp.asarray(noff), jnp.asarray(active_mask))
            logits = np.asarray(logits)
            self.timing.device_syncs += 1
            if self.sample is not None:  # legacy per-row callable: active only
                nxt = np.zeros((E.n_slots,), np.int32)
                nxt[active] = self._sample_rows(logits[active])
            else:
                nxt = self._sample_rows(logits)

        # ---- EOS / budget bookkeeping, vectorized ----------------------
        with self._phase("host_s", "host", "bookkeep"):
            # invalid-logits quarantine (this path sees the real host
            # logits): a non-finite row — or an injected NaN plan — means
            # the sample is garbage; the token is not emitted and the
            # request goes terminal at the next tick's safe point
            quar = np.zeros((E.n_slots,), bool)
            finite = (np.isfinite(logits[active]).all(axis=1)
                      if self.nan_guard
                      else np.ones((len(active),), bool))
            for i, s in enumerate(active):
                rid = self.batcher.slots[s].req_id
                if (self.faults.enabled
                        and self.faults.fire("nan_logits", key=rid)) \
                        or not finite[i]:
                    quar[s] = True
                    self._abort_req.setdefault(rid, "nan")
            gen = np.asarray([0 if r is None else r.generated
                              for r in self.batcher.slots], np.int32)
            budget = np.asarray([1 if r is None else r.max_new_tokens
                                 for r in self.batcher.slots], np.int32)
            self.tokens = np.where(active_mask & ~quar, nxt,
                                   self.tokens).astype(np.int32)
            finished = active_mask & ~quar \
                & ((nxt == E.eos_token) | (gen >= budget))
            emitted = [s for s in active if not quar[s]]
            for s in emitted:
                self.outputs[self.batcher.slots[s].req_id].append(int(nxt[s]))
            self.timing.decode_tokens += len(emitted)
            if self.tel.enabled:
                tnow = self.clock()
                for s in emitted:
                    self.tel.on_tokens(self.batcher.slots[s].req_id, 1, tnow)
                self.tel.on_horizon(float(ctx[emitted].sum()))
            # the device slot mirror did not see this host-side advance; a
            # later fused run() must re-upload these rows (and process this
            # mask)
            self.batcher.dirty.update(active)
            self._pending_fin = finished
        return finished

    # ---- fused multi-step path ---------------------------------------
    def _make_fused(self):
        E, cfg, rt = self.ecfg, self.cfg, self.rt
        if self.sample is not None:
            # legacy per-row host callable: adapted into the scan-sampler
            # signature via an ordered host callback, so run() keeps the
            # fused multi-step path instead of pinning to per-token decode
            from repro.serving.sampling import make_callback_sampler
            sample = make_callback_sampler(self.sample)
        else:
            sample = make_scan_sampler(E.sampler, temperature=E.temperature,
                                       top_k=E.top_k)

        def fn(params, state, tokens, bt, ctx, rem, allow, key, *,
               horizon, width):
            return MDL.decode_multi(
                cfg, params, state, tokens, bt, ctx, rem, allow, key,
                horizon=horizon, table_width=width, page_size=E.page_size,
                n_pages=E.n_pages, eos_token=E.eos_token, sample=sample,
                rt=rt)

        donate = (1, 2, 4, 5, 7) if self._donate else ()
        return jax.jit(fn, static_argnames=("horizon", "width"),
                       donate_argnums=donate)

    # ---- speculative decode: propose / catch-up / verify ----------------
    def _make_spec(self):
        """Compile the speculative pair: the draft's proposal scan and the
        target's one-pass multi-query verify. Argument donation mirrors the
        fused scan but is split across the two dispatches: propose may only
        donate the draft state and key (tokens/ctx are re-read by verify);
        verify donates everything it replaces. Single-stream execution
        order (propose enqueued first) makes the verify-side aliasing of
        shared inputs safe."""
        from repro.serving.sampling import make_verifier
        E, rt = self.ecfg, self.rt
        dcfg = self.draft_cfg
        sample = make_scan_sampler(E.sampler, temperature=E.temperature,
                                   top_k=E.top_k)
        verifier = make_verifier(E.sampler, temperature=E.temperature,
                                 top_k=E.top_k)
        need_q = E.sampler != "greedy"

        def propose(dparams, dstate, tokens, bt, ctx, allow, dkey, *,
                    horizon, width):
            return MDL.draft_propose(
                dcfg, dparams, dstate, tokens, bt, ctx, allow, dkey,
                horizon=horizon, table_width=width, page_size=E.page_size,
                n_pages=E.n_pages, sample=sample, need_q=need_q, rt=rt)

        def verify(params, state, tokens, proposals, qlogits, bt, ctx, rem,
                   allow, key, *, horizon, width):
            return MDL.decode_verify(
                self.cfg, params, state, tokens, proposals, qlogits, bt,
                ctx, rem, allow, key, horizon=horizon, table_width=width,
                page_size=E.page_size, n_pages=E.n_pages,
                eos_token=E.eos_token, verifier=verifier, rt=rt)

        dp = (1, 6) if self._donate else ()
        dv = (1, 2, 4, 6, 7, 9) if self._donate else ()
        return (jax.jit(propose, static_argnames=("horizon", "width"),
                        donate_argnums=dp),
                jax.jit(verify, static_argnames=("horizon", "width"),
                        donate_argnums=dv))

    def _draft_catchup(self, active) -> None:
        """Bring the draft pool level with the target before proposing:
        batched draft prefill of every active slot's tokens in
        ``[dlen, ctx-1)`` (positions the draft has not absorbed — fresh
        admissions, preemption resumes, prefix-cache hits and post-swap-in
        pages all land here because (re)admission resets dlen to 0; steady
        state needs nothing or one token after a partially-accepted round).
        One async dispatch, shapes bucketed like ``prefill_suffix``."""
        from repro.serving.prefill import _make_chunk_fn, _suffix_bucket
        E = self.ecfg
        ctx = self.batcher.context_lens()
        needy, needs = [], []
        for s in active:
            req = self.batcher.slots[s]
            dlen = self._dlen.get(req.req_id, 0)
            need = int(ctx[s]) - 1 - dlen
            if need > 0:
                needy.append((s, req, dlen))
                needs.append(need)
        if not needy:
            return
        if self._catchup_jit is None:
            self._catchup_jit = _make_chunk_fn(self.draft_cfg, self.rt)
        blen = _suffix_bucket(max(needs), max(E.max_prefill, E.page_size))
        rows = 1
        while rows < len(needy):
            rows *= 2
        toks = np.zeros((rows, blen), np.int32)
        starts = np.zeros((rows,), np.int32)
        lens = np.zeros((rows,), np.int32)
        bt_rows = np.zeros((rows, self.pool_spec.max_pages_per_req),
                           np.int32)
        W = self.pool_spec.max_pages_per_req
        host_bt = self.batcher.block_tables(W)
        for i, ((s, req, dlen), need) in enumerate(zip(needy, needs)):
            full = np.concatenate(
                [self.prompts[req.req_id],
                 np.asarray(self.outputs[req.req_id], np.int32)])
            toks[i, :need] = full[dlen:dlen + need]
            starts[i] = dlen
            lens[i] = need
            bt_rows[i] = host_bt[s]
            self._dlen[req.req_id] = dlen + need
        # pad rows repeat the last real row with valid_len 0 — their pool
        # writes drop, exactly like group-prefill end padding
        for i in range(len(needy), rows):
            bt_rows[i] = bt_rows[len(needy) - 1]
        _, dstate = self._catchup_jit(
            self.draft_params, {"pool": self._dstate["pool"]},
            jnp.asarray(toks), jnp.asarray(bt_rows), jnp.asarray(starts),
            jnp.asarray(np.maximum(lens - 1, 0)), jnp.asarray(lens))
        self._dstate["pool"] = dstate["pool"]

    def _dispatch_spec(self, active, allow, K: int, width: int) -> None:
        """Dispatch one speculative round (draft scan + verify pass) without
        blocking — the tick's single sync stays at next tick's collect."""
        if self._spec_jits is None:
            self._spec_jits = self._make_spec()
        propose, verify = self._spec_jits
        G = K - 1
        allow_j = jnp.asarray(allow)
        prop, qlog, self._dstate, self._dkey = propose(
            self.draft_params, self._dstate, self.dev.tokens, self.dev.bt,
            self.dev.ctx, allow_j, self._dkey, horizon=G, width=width)
        toks, emit, fin, self.state, self.dev.tokens, self.dev.ctx, \
            self.dev.rem, self.dev.key, acc = verify(
                self.params, self.state, self.dev.tokens, prop, qlog,
                self.dev.bt, self.dev.ctx, self.dev.rem, allow_j,
                self.dev.key, horizon=G, width=width)
        nprop = np.clip(allow - 1, 0, G).astype(np.int32)
        self._inflight = (toks, emit, fin,
                          [(s, self.batcher.slots[s]) for s in active],
                          (acc, nprop))

    def _sync_device_slots(self) -> None:
        """Mirror the scheduler's dirty rows into the device-resident slot
        state — the incremental config-buffer update (rows touched by
        admission, page growth, chunk completion or frees; continuing slots
        were already advanced ON DEVICE by the previous horizon)."""
        dirty = self.batcher.take_dirty()
        if not dirty:
            return
        W = self.pool_spec.max_pages_per_req
        rows = np.ascontiguousarray(self.batcher.block_tables(W)[dirty])
        ctx_v = self.batcher.context_lens()[dirty]
        tok_v = self.tokens[dirty]
        rem_v = np.zeros((len(dirty),), np.int32)
        for i, s in enumerate(dirty):
            req = self.batcher.slots[s]
            if req is not None and req.prefill_done:
                rem_v[i] = max(0, req.max_new_tokens - req.generated + 1)
        self.dev.patch(dirty, rows, ctx_v.astype(np.int32),
                       tok_v.astype(np.int32), rem_v)

    def _collect_horizon(self):
        """Sync point: block on the in-flight horizon's token readback (the
        ONE host<->device rendezvous per K decode steps) and fold the
        emissions into outputs / request bookkeeping."""
        if self._inflight is None:
            return None
        toks, emit, fin, pairs, spec = self._inflight
        self._inflight = None
        meta, self._horizon_meta = self._horizon_meta, None
        with self._phase("decode_s", "sync", "collect"):
            toks, emit, fin = (np.asarray(toks), np.asarray(emit),
                               np.asarray(fin))
            acc = np.asarray(spec[0]) if spec is not None else None
        self.timing.device_syncs += 1
        tel = self.tel.enabled
        if tel and meta is not None and self.tel.trace is not None:
            # the horizon's device-busy window, inferred dispatch->readback:
            # an async span so overlapping host slices stay visible
            self.tel.trace.span("device", "horizon", meta[2], meta[0],
                                time.perf_counter(),
                                args={"slots": len(pairs)})
        # one readback timestamp for the whole horizon: every emission in
        # it became host-visible at the same sync, and the per-request
        # records must reproduce the first_tok_t-based TTFT exactly
        tnow = self.clock()
        tok_ctx = 0.0
        finished = np.zeros((self.ecfg.n_slots,), bool)
        for slot, req in pairs:
            ts = toks[emit[:, slot], slot]
            if not len(ts):            # pool-starved to zero steps
                continue
            # invalid-logits quarantine: an injected NaN plan, or sampled
            # ids outside the logits width (the fused path cannot see the
            # device-side logits, so garbage shows up as out-of-range ids).
            # The horizon's tokens are NOT folded — the request goes
            # terminal at this tick's safe point with reason "nan"
            if (self.faults.enabled
                    and self.faults.fire("nan_logits", key=req.req_id)) \
                    or (self.nan_guard
                        and (int(ts.min()) < 0
                             or int(ts.max()) >= self.cfg.padded_vocab)):
                self._abort_req.setdefault(req.req_id, "nan")
                continue
            self.outputs[req.req_id].extend(int(t) for t in ts)
            self.first_tok_t.setdefault(req.req_id, tnow)
            if tel:
                self.tel.on_tokens(req.req_id, int(len(ts)), tnow)
                if meta is not None:
                    tok_ctx += len(ts) * float(meta[1][slot])
            if spec is not None:
                # draft-pool coverage after the round: the draft absorbed
                # its proposals' KV up to the accepted/emitted frontier
                # (req.total_len is still the dispatch-time context here —
                # ``generated`` advances below)
                nprop = int(spec[1][slot])
                self._dlen[req.req_id] = req.total_len - 1 \
                    + min(len(ts), nprop)
                self.spec_rounds += 1
                self.spec_proposed += nprop
                self.spec_accepted += int(acc[slot])
                if tel:
                    self.tel.on_spec(req.req_id, nprop, int(acc[slot]))
            # the tick's step() already reserved one token; the rest of the
            # horizon's emissions land here
            req.generated += len(ts) - 1
            self.tokens[slot] = int(ts[-1])
            finished[slot] = bool(fin[slot])
            if fin[slot] and self._dstate is not None:
                self._dlen.pop(req.req_id, None)
            self.timing.decode_tokens += int(len(ts))
        if tel:
            self.tel.on_horizon(tok_ctx)
        return finished

    def _step_fused(self) -> None:
        """One pipelined tick of the fused multi-step path.

        Order is the DCS ping-pong applied to the host loop: with the
        previous horizon still in flight, do the host work that does NOT
        depend on its results (cache swap-out drain / watermark offload,
        radix-peek prefetch for queued candidates), only then sync, and end
        by dispatching the next horizon WITHOUT blocking on it.
        """
        E = self.ecfg
        # ---- overlap window: result-independent host work --------------
        with self._phase("host_s", "host", "overlap"):
            if self.cache is not None:
                # an injected swap-tier stall skips the maintenance drain
                # for the tick — pending swap-outs queue up, exactly the
                # back-pressure a stalled host DMA engine produces
                if not (self.faults.enabled
                        and self.faults.fire("swap_stall")):
                    self.cache.maintain()
            self._drain_snapshots()
            if self._inflight is not None and self.batcher.queue:
                self.batcher.prefetch_peeks(limit=2 * E.n_slots)

        # ---- sync: fold the horizon's tokens into host bookkeeping -----
        finished = self._collect_horizon()
        if finished is None:
            finished, self._pending_fin = self._pending_fin, None

        # ---- safe point: injection, deadlines, aborts, degradation -----
        with self._phase("host_s", "host", "faults"):
            self._process_faults(finished)

        # ---- schedule + prefill ----------------------------------------
        with self._phase("host_s", "host", "schedule"):
            admitted, active = self.batcher.step(finished)
            if self.cache is not None and self.cache.has_pending:
                # swap-in scatters / CoW copies queued by this tick's
                # admissions must land before prefill or decode read the
                # pages
                self.state["pool"] = self.cache.apply_pending(
                    self.state["pool"])
        if admitted or self.prefiller.busy:
            with self._phase("prefill_s", "prefill", "prefill"):
                active = self.prefiller.run(admitted, active)
        self.timing.steps += 1
        if not active:
            return

        # ---- horizon reservation + incremental config update -----------
        with self._phase("host_s", "host", "config"):
            # degradation bit 2 demotes speculative decode to the plain
            # fused scan (draft state parks; _dlen goes stale but is only
            # consulted behind ``spec``)
            spec = self._dstate is not None and not (self.degraded_mode & 2)
            if spec:
                # the draft must re-absorb any context it did not write —
                # every (re)admission starts from zero (swap-in / CoW /
                # snapshot restore only rebuild the target's pages)
                for _s, req in admitted:
                    self._dlen[req.req_id] = 0
                K = max(1, E.spec_horizon + 1)
            else:
                K = max(1, E.decode_horizon)
            cap = self.prefiller.max_horizon
            if cap is not None:
                K = min(K, cap)
            if (self.degraded_mode & 1) and not spec:
                K = 1              # bit 1: per-token trajectory, no reserve
            allow = self.batcher.reserve_horizon(active, K,
                                                 gentle=E.reserve_gentle)
            self._sync_device_slots()
            W = self.pool_spec.max_pages_per_req
            width = W
            if E.decode_bucket and W > 16:
                from repro.serving.prefill import decode_table_bucket
                width = decode_table_bucket(self.batcher.max_live_pages(), W)

        # ---- dispatch; do NOT block ------------------------------------
        with self._phase("decode_s", "dispatch",
                         "spec_dispatch" if spec else "dispatch"):
            if self.tel.enabled:
                self._horizon_seq += 1
                self._horizon_meta = (time.perf_counter(),
                                      self.batcher._ctx.copy(),
                                      self._horizon_seq)
            if spec:
                self._draft_catchup(active)
                self._dispatch_spec(active, allow, int(K), int(width))
            else:
                if self._fused_jit is None:
                    self._fused_jit = self._make_fused()
                toks, emit, fin, self.state, self.dev.tokens, self.dev.ctx, \
                    self.dev.rem, self.dev.key = self._fused_jit(
                        self.params, self.state, self.dev.tokens, self.dev.bt,
                        self.dev.ctx, self.dev.rem, jnp.asarray(allow),
                        self.dev.key, horizon=int(K), width=int(width))
                self._inflight = (toks, emit, fin,
                                  [(s, self.batcher.slots[s])
                                   for s in active],
                                  None)

    def tick(self) -> None:
        """One pipelined fused tick plus the serving-snapshot cadence
        (public driver API; chaos drivers call this instead of run() so
        they can kill the engine between ticks)."""
        self._step_fused()
        E = self.ecfg
        if E.snapshot_every and E.snapshot_dir \
                and self._tick_no % E.snapshot_every == 0:
            self.save_snapshot()

    def run(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        for _ in range(max_steps):
            if self._inflight is None and self.batcher.done():
                break
            self.tick()
        if self._inflight is not None:   # max_steps hit mid-horizon
            self._pending_fin = self._collect_horizon()
        return self.outputs

    # ---- cross-engine request movement (serving/cluster.py) -----------
    def quiesce(self) -> None:
        """Bring the engine to the post-collect quiescent frame: fold any
        in-flight horizon into host bookkeeping (its finish mask joins the
        pending one for the next scheduler step) and drain pending device
        snapshots. Snapshots, handoffs and teardowns all require this
        frame; costs one extra device sync when a horizon was in flight."""
        if self._inflight is not None:
            fin = self._collect_horizon()
            if fin is not None:
                self._pending_fin = fin if self._pending_fin is None \
                    else (self._pending_fin | fin)
        self._drain_snapshots()

    def extract_request(self, req_id: int):
        """Pull a live request out of this engine for a cross-engine
        handoff: quiesce, capture its snapshot-entry frame (written KV
        pages + recurrent carry when warm), then tear it down locally
        (reason ``handoff`` — its private pages free; prefixes it already
        published to the radix cache survive under the tree's own refs).
        Returns ``(entry, arrays)``, or None when the request is not live
        or finished during the quiesce (its output is already complete —
        nothing to move)."""
        self.quiesce()
        s, req = self._find_request(req_id)
        if req is None:
            return None
        if s is not None and self._pending_fin is not None \
                and self._pending_fin[s]:
            return None
        ent, arrs = self._snapshot_entry(req, s)
        self._teardown(req_id, "handoff")
        return ent, arrs

    def adopt_request(self, req_id: int, ent: dict, prompt, out, *,
                      kv=None, rows=None):
        """Register a request arriving from OUTSIDE the submit() path — a
        snapshot restore or a cross-engine handoff. ``ent`` is the scalar
        snapshot-entry frame (``_snapshot_entry``); ``out`` the tokens
        already streamed to the client. Warm entries seed the preemption-
        snapshot machinery so the prefiller restores the KV/carry instead
        of recomputing; slot-mode prefill (the recompute reference) and
        cold entries re-prefill deterministically — token-identical either
        way. Returns the constructed Request (already queued)."""
        self.prompts[req_id] = np.asarray(prompt, np.int32)
        self.outputs[req_id] = [int(t) for t in out]
        now = self.submit_t[req_id] = self.clock()
        # re-synthesize a minimal spec so policies/tracker see the adopted
        # request's tier (SLO latency targets don't survive a handoff —
        # the timestamps restart in the adopting engine's clock frame)
        spec = RequestSpec(req_id, self.prompts[req_id],
                           int(ent["max_new"]),
                           priority=int(ent.get("priority", 0)))
        self.tel.on_submit(req_id, len(self.prompts[req_id]),
                           int(ent["max_new"]), now, spec=spec)
        req = Request(req_id, int(ent["prompt_len"]), int(ent["max_new"]),
                      priority=spec.priority, submit_t=now, spec=spec)
        if self.prefiller.name == "chunked":
            req.chunked_prefill = True
            req.prefill_done = False
        warm_ok = self.ecfg.state_resume and self.prefiller.name != "slot"
        if ent.get("state") == "warm" and warm_ok:
            snap: dict[str, Any] = {"len": int(ent["depth"])}
            if kv is not None:
                snap["kv"] = tuple(kv)
            if rows is not None:
                snap["rows"] = rows
            self.rsnaps[req_id] = snap
        self.batcher.submit(req)
        return req

    # ---- crash-consistent serving snapshots ---------------------------
    def _snapshot_entry(self, req, s: int | None):
        """(scalar-manifest entry, array dict) for one live request.
        Running warm slots save their written KV pages (and recurrent
        carry) at the quiescent depth; the requeue arithmetic mirrors
        ``drain_slot`` — saved ``prompt_len`` equals the restore depth, so
        a warm restore rides the prefiller's full-restore path (no model
        call) and continues token-identically. Everything else is saved
        cold: a deterministic re-prefill of the reconstructable context."""
        E = self.ecfg
        rid = req.req_id
        out = self.outputs[rid]
        arrs: dict[str, Any] = {
            "prompt": self.prompts[rid],
            "out": np.asarray(out, np.int32)}
        ent = {"prompt_len": int(req.prompt_len),
               "max_new": int(req.max_new_tokens), "state": "cold"}
        if s is not None and req.prefill_done and out and req.kv_written:
            P = len(self.prompts[rid])
            g = min(int(req.generated),
                    max(0, len(out) - 1 - (req.prompt_len - P)))
            depth = req.prompt_len + g
            ent = {"prompt_len": int(depth),
                   "max_new": max(1, int(req.max_new_tokens) - g),
                   "state": "warm", "depth": int(depth)}
            if "pool" in self.state:
                from repro.core.paged_kv import gather_pages
                n = -(-depth // E.page_size)
                pages = np.asarray(self.batcher.block_table_row(s)[:n])
                k, v = gather_pages(self.state["pool"]["k"],
                                    self.state["pool"]["v"],
                                    jnp.asarray(pages))
                arrs["kv_k"], arrs["kv_v"] = np.asarray(k), np.asarray(v)
            if self.has_rstate:
                arrs["rows"] = jax.tree.map(
                    np.asarray, MDL.gather_rstate(self.state, [s]))
        elif s is None and req.req_id in self.rsnaps:
            # queued with a preemption snapshot (already host numpy after
            # the drain): persist it so the restore resumes, not recomputes
            snap = self.rsnaps[rid]
            ent = {"prompt_len": int(req.prompt_len),
                   "max_new": int(req.max_new_tokens),
                   "state": "warm", "depth": int(snap["len"])}
            if "kv" in snap:
                arrs["kv_k"], arrs["kv_v"] = snap["kv"]
            if "rows" in snap:
                arrs["rows"] = snap["rows"]
        if req.priority:
            ent["priority"] = int(req.priority)
        return ent, arrs

    def save_snapshot(self, ckpt_dir=None):
        """Write a crash-consistent serving checkpoint: every live
        request's prompt/output tokens plus, for warm slots, the written KV
        pages and recurrent carry at the quiescent depth — enough for a
        fresh engine to finish every in-flight request token-identically
        (greedy). Quiesces the in-flight horizon first (one extra sync on
        ticks that snapshot); uses the manifest-gated
        ``runtime/checkpoint.py`` layout, so a crash mid-save can never
        corrupt the latest restorable step."""
        E = self.ecfg
        d = ckpt_dir or E.snapshot_dir
        if d is None:
            return None
        self.quiesce()
        order: list[int] = []
        ents: dict[str, dict] = {}
        arrs: dict[str, dict] = {}
        for s, req in enumerate(self.batcher.slots):
            if req is None:
                continue
            if self._pending_fin is not None and self._pending_fin[s]:
                ent = {"state": "done", "max_new": int(req.max_new_tokens),
                       "prompt_len": int(req.prompt_len)}
                a = {"prompt": self.prompts[req.req_id],
                     "out": np.asarray(self.outputs[req.req_id], np.int32)}
            else:
                ent, a = self._snapshot_entry(req, s)
            order.append(req.req_id)
            ents[str(req.req_id)] = ent
            arrs[str(req.req_id)] = a
        for req in self.batcher.queue:
            ent, a = self._snapshot_entry(req, None)
            order.append(req.req_id)
            ents[str(req.req_id)] = ent
            arrs[str(req.req_id)] = a
        from repro.runtime import checkpoint as CKPT
        tree = {"reqs": arrs, "dev_key": np.asarray(self.dev.key)}
        path = CKPT.save(d, self._tick_no, tree,
                         extra={"order": order, "reqs": ents,
                                "tick": self._tick_no},
                         keep=E.snapshot_keep)
        self.snapshot_saves += 1
        return path

    def _rows_from_nested(self, nd):
        """Rebuild a one-slot recurrent-carry pytree from its "/"-keyed
        nested-dict form (a snapshot shard or handoff payload). The carry
        contains tuples/lists the nesting flattened to string indices —
        unflatten against a live one-slot gather so the structure
        round-trips exactly."""
        like = MDL.gather_rstate(self.state, [0])
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, _leaf in flat:
            d = nd
            for p in path:
                d = d[str(getattr(p, "key", getattr(p, "idx", p)))]
            leaves.append(d)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_snapshot(self, ckpt_dir=None, step: int | None = None):
        """Rebuild the serving state of the latest (or given) snapshot into
        THIS engine — call on a freshly constructed engine with the same
        model/engine config, then ``run()``: warm requests restore their KV
        (and carry) and continue mid-stream, cold ones re-prefill
        deterministically, done ones just republish their outputs.

        Every candidate step is FULLY validated (manifest parse, shard
        load, per-array crc32) before anything is applied: a torn or
        bit-flipped snapshot is counted in ``snapshot_rejects`` and
        skipped, falling back to the next-older step — restore degrades,
        it never half-applies. Returns the restored step, or None when no
        intact snapshot exists (the caller's cold-start path)."""
        from repro.runtime import checkpoint as CKPT
        d = ckpt_dir or self.ecfg.snapshot_dir
        if d is None:
            return None
        cands = ([step] if step is not None
                 else sorted(CKPT.valid_steps(d), reverse=True))
        for cand in cands:
            if not CKPT.verify_step(d, cand):
                self.snapshot_rejects += 1
                continue
            return self._restore_step(d, cand)
        return None

    def _restore_step(self, ckpt_dir, step: int):
        """Apply one verified snapshot step (see ``restore_snapshot``)."""
        import json as _json
        from pathlib import Path as _Path
        step_dir = _Path(ckpt_dir) / f"step_{step:08d}"
        extra = _json.loads(
            (step_dir / "manifest.json").read_text())["extra"]
        data = np.load(step_dir / "shard_00000.npz")
        nested: dict = {}
        for key in data.files:                 # "/"-joined tree paths back
            parts = key.split("/")             # into per-request dicts
            dd = nested
            for p in parts[:-1]:
                dd = dd.setdefault(p, {})
            dd[parts[-1]] = data[key]
        if "dev_key" in nested:
            self.dev.key = jnp.asarray(nested["dev_key"])
        reqs = nested.get("reqs", {})
        for rid_s in map(str, extra["order"]):
            ent = extra["reqs"][rid_s]
            rid = int(rid_s)
            a = reqs.get(rid_s, {})
            prompt = np.asarray(a["prompt"], np.int32)
            out = [int(t) for t in np.asarray(a.get("out", ()), np.int32)]
            if ent["state"] == "done":         # finished during quiesce:
                self.prompts[rid] = prompt     # republish, don't re-run
                self.outputs[rid] = out
                self.submit_t[rid] = self.clock()
                continue
            kv = (a["kv_k"], a["kv_v"]) if "kv_k" in a else None
            rows = (self._rows_from_nested(a["rows"])
                    if "rows" in a else None)
            self.adopt_request(rid, ent, prompt, out, kv=kv, rows=rows)
        self.snapshot_restores += 1
        return step
