"""Jitted token samplers for the serving engine.

The seed engine sampled on the host with ``np.argmax`` per slot; these run
the whole batch in one compiled call (greedy argmax, temperature, top-k) so
sampling rides the same dispatch as the decode step instead of adding a
per-slot Python loop. Stochastic samplers hold a PRNG-key chain seeded at
construction: the same seed and call sequence reproduce the same tokens.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def greedy_sample(logits):
    """logits [..., V] -> int32 token ids [...] (first-max tie-break, same
    as np.argmax)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("top_k",))
def stochastic_sample(key, logits, temperature=1.0, top_k: int = 0):
    """Temperature / top-k sampling. top_k=0 samples the full distribution."""
    logits = logits / jnp.maximum(jnp.asarray(temperature, logits.dtype), 1e-6)
    if top_k:
        vals, idx = jax.lax.top_k(logits, top_k)
        draw = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, draw[..., None], axis=-1)[..., 0].astype(jnp.int32)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


class Sampler:
    """Stateful batch sampler: ``sampler(logits)`` -> np.int32 tokens.

    Accepts [V] or [B, V] logits (np or jnp). Greedy is stateless;
    temperature/top_k split one key per call, so token streams are
    deterministic in (seed, call order).
    """

    def __init__(self, kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0):
        assert kind in ("greedy", "temperature", "top_k"), kind
        self.kind = kind
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(seed)

    def __call__(self, logits) -> np.ndarray:
        logits = jnp.asarray(logits)
        squeeze = logits.ndim == 1
        if squeeze:
            logits = logits[None]
        if self.kind == "greedy":
            out = greedy_sample(logits)
        else:
            self._key, sub = jax.random.split(self._key)
            out = stochastic_sample(sub, logits, self.temperature,
                                    self.top_k if self.kind == "top_k" else 0)
        out = np.asarray(out)
        return out[0] if squeeze else out


def make_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0) -> Sampler:
    return Sampler(kind, temperature=temperature, top_k=top_k, seed=seed)
