"""Jitted token samplers for the serving engine.

The seed engine sampled on the host with ``np.argmax`` per slot; these run
the whole batch in one compiled call (greedy argmax, temperature, top-k) so
sampling rides the same dispatch as the decode step instead of adding a
per-slot Python loop. Stochastic samplers hold a PRNG-key chain: the key is
split INSIDE the jitted call (one dispatch per batch, not a host-side split
plus a second dispatch), and the same seed and call sequence reproduce the
same tokens.

``make_scan_sampler`` builds the pure ``(key, logits) -> tokens`` function
the fused multi-step decode (``models.model.decode_multi``) threads through
its ``lax.scan`` — sampling then never leaves the device between steps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def greedy_sample(logits):
    """logits [..., V] -> int32 token ids [...] (first-max tie-break, same
    as np.argmax)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _stochastic(key, logits, temperature, top_k: int):
    """Un-jitted sampling core, shared by the eager wrapper and the fused
    decode scan. top_k=0 samples the full distribution."""
    logits = logits / jnp.maximum(jnp.asarray(temperature, logits.dtype), 1e-6)
    if top_k:
        vals, idx = jax.lax.top_k(logits, top_k)
        draw = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, draw[..., None], axis=-1)[..., 0].astype(jnp.int32)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("top_k",))
def stochastic_sample(key, logits, temperature=1.0, top_k: int = 0):
    """Temperature / top-k sampling. top_k=0 samples the full distribution."""
    return _stochastic(key, logits, temperature, top_k)


@partial(jax.jit, static_argnames=("top_k",))
def stochastic_sample_step(key, logits, temperature=1.0, top_k: int = 0):
    """One sampler call with the key chain threaded in-jit: splits ``key``,
    samples, and returns ``(new_key, tokens)`` in a single dispatch.
    Bit-identical to splitting on the host first (threefry is deterministic
    across the jit boundary)."""
    key, sub = jax.random.split(key)
    return key, _stochastic(sub, logits, temperature, top_k)


def make_callback_sampler(fn):
    """Adapt a legacy per-row host callable ``logits [V] -> token`` into
    the ``(key, logits [B, V], run [B]) -> tokens [B]`` scan-sampler
    signature, so engines constructed with the seed ``sample=`` API still
    run the fused multi-step decode path.

    The callable executes on the host through an *ordered* ``io_callback``
    (legacy samplers may be stateful), one callback per decode step; it is
    invoked for rows with ``run=True`` only, in ascending slot order —
    exactly the per-token path's active-rows-only invocation pattern, so
    stateful callables consume their state identically under both APIs.
    The PRNG key is unused; non-running rows return 0 (the fused scan
    masks them out on device)."""
    from jax.experimental import io_callback

    def rows(logits, run):
        arr = np.asarray(logits)
        live = np.asarray(run)
        out = np.zeros((len(arr),), np.int32)
        for i in np.flatnonzero(live):
            out[i] = int(fn(arr[i]))
        return out

    def sampler(key, logits, run):
        return io_callback(
            rows, jax.ShapeDtypeStruct((logits.shape[0],), jnp.int32),
            logits, run, ordered=True)
    # explicit opt-in marker (models.model.decode_multi) — signature
    # sniffing would misfire on samplers with defaulted extra params
    sampler.takes_run = True
    return sampler


def make_scan_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                      top_k: int = 0):
    """Pure ``(key, logits [B, V]) -> tokens [B]`` for use INSIDE jit/scan.

    The caller owns the key chain (split once per decode step inside the
    fused scan); greedy ignores the key so one signature serves all kinds.
    """
    assert kind in ("greedy", "temperature", "top_k"), kind
    if kind == "greedy":
        return lambda key, logits: jnp.argmax(logits, -1).astype(jnp.int32)
    tk = int(top_k) if kind == "top_k" else 0
    temp = float(temperature)
    return lambda key, logits: _stochastic(key, logits, temp, tk)


class Sampler:
    """Stateful batch sampler: ``sampler(logits)`` -> np.int32 tokens.

    Accepts [V] or [B, V] logits (np or jnp). Greedy is stateless;
    temperature/top_k thread one PRNG key through ``stochastic_sample_step``
    (split in-jit), so token streams are deterministic in (seed, call order).
    """

    def __init__(self, kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0):
        assert kind in ("greedy", "temperature", "top_k"), kind
        self.kind = kind
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(seed)

    def __call__(self, logits) -> np.ndarray:
        logits = jnp.asarray(logits)
        squeeze = logits.ndim == 1
        if squeeze:
            logits = logits[None]
        if self.kind == "greedy":
            out = greedy_sample(logits)
        else:
            self._key, out = stochastic_sample_step(
                self._key, logits, self.temperature,
                self.top_k if self.kind == "top_k" else 0)
        out = np.asarray(out)
        return out[0] if squeeze else out


def make_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0) -> Sampler:
    return Sampler(kind, temperature=temperature, top_k=top_k, seed=seed)
