"""Jitted token samplers for the serving engine.

The seed engine sampled on the host with ``np.argmax`` per slot; these run
the whole batch in one compiled call (greedy argmax, temperature, top-k) so
sampling rides the same dispatch as the decode step instead of adding a
per-slot Python loop. Stochastic samplers hold a PRNG-key chain: the key is
split INSIDE the jitted call (one dispatch per batch, not a host-side split
plus a second dispatch), and the same seed and call sequence reproduce the
same tokens.

``make_scan_sampler`` builds the pure ``(key, logits) -> tokens`` function
the fused multi-step decode (``models.model.decode_multi``) threads through
its ``lax.scan`` — sampling then never leaves the device between steps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def greedy_sample(logits):
    """logits [..., V] -> int32 token ids [...] (first-max tie-break, same
    as np.argmax)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _stochastic(key, logits, temperature, top_k: int):
    """Un-jitted sampling core, shared by the eager wrapper and the fused
    decode scan. top_k=0 samples the full distribution."""
    logits = logits / jnp.maximum(jnp.asarray(temperature, logits.dtype), 1e-6)
    if top_k:
        vals, idx = jax.lax.top_k(logits, top_k)
        draw = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, draw[..., None], axis=-1)[..., 0].astype(jnp.int32)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("top_k",))
def stochastic_sample(key, logits, temperature=1.0, top_k: int = 0):
    """Temperature / top-k sampling. top_k=0 samples the full distribution."""
    return _stochastic(key, logits, temperature, top_k)


@partial(jax.jit, static_argnames=("top_k",))
def stochastic_sample_step(key, logits, temperature=1.0, top_k: int = 0):
    """One sampler call with the key chain threaded in-jit: splits ``key``,
    samples, and returns ``(new_key, tokens)`` in a single dispatch.
    Bit-identical to splitting on the host first (threefry is deterministic
    across the jit boundary)."""
    key, sub = jax.random.split(key)
    return key, _stochastic(sub, logits, temperature, top_k)


def make_callback_sampler(fn):
    """Adapt a legacy per-row host callable ``logits [V] -> token`` into
    the ``(key, logits [B, V], run [B]) -> tokens [B]`` scan-sampler
    signature, so engines constructed with the seed ``sample=`` API still
    run the fused multi-step decode path.

    The callable executes on the host through an *ordered* ``io_callback``
    (legacy samplers may be stateful), one callback per decode step; it is
    invoked for rows with ``run=True`` only, in ascending slot order —
    exactly the per-token path's active-rows-only invocation pattern, so
    stateful callables consume their state identically under both APIs.
    The PRNG key is unused; non-running rows return 0 (the fused scan
    masks them out on device)."""
    from jax.experimental import io_callback

    def rows(logits, run):
        arr = np.asarray(logits)
        live = np.asarray(run)
        out = np.zeros((len(arr),), np.int32)
        for i in np.flatnonzero(live):
            out[i] = int(fn(arr[i]))
        return out

    def sampler(key, logits, run):
        return io_callback(
            rows, jax.ShapeDtypeStruct((logits.shape[0],), jnp.int32),
            logits, run, ordered=True)
    # explicit opt-in marker (models.model.decode_multi) — signature
    # sniffing would misfire on samplers with defaulted extra params
    sampler.takes_run = True
    return sampler


def make_scan_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                      top_k: int = 0):
    """Pure ``(key, logits [B, V]) -> tokens [B]`` for use INSIDE jit/scan.

    The caller owns the key chain (split once per decode step inside the
    fused scan); greedy ignores the key so one signature serves all kinds.
    """
    assert kind in ("greedy", "temperature", "top_k"), kind
    if kind == "greedy":
        return lambda key, logits: jnp.argmax(logits, -1).astype(jnp.int32)
    tk = int(top_k) if kind == "top_k" else 0
    temp = float(temperature)
    return lambda key, logits: _stochastic(key, logits, temp, tk)


def _probs(logits, temperature: float, top_k: int):
    """The sampling distribution ``_stochastic`` actually draws from, as
    explicit probabilities [..., V]: temperature-scaled softmax restricted
    to ``lax.top_k``'s EXACT winner set (same tie-break — a threshold mask
    would keep extra tied entries and skew the residual)."""
    z = logits / jnp.maximum(jnp.asarray(temperature, logits.dtype), 1e-6)
    if top_k:
        vals, idx = jax.lax.top_k(z, top_k)
        from repro.models.layers import NEG_INF
        z = jnp.full_like(z, NEG_INF).at[
            jnp.arange(z.shape[0])[:, None], idx].set(vals) \
            if z.ndim == 2 else None
        assert z is not None, "_probs expects [B, V] logits"
    return jax.nn.softmax(z.astype(jnp.float32), axis=-1)


def make_verifier(kind: str = "greedy", *, temperature: float = 1.0,
                  top_k: int = 0):
    """Speculative-decode acceptance rule for ``models.model.decode_verify``:
    ``(key, logits [B, C, V], qlogits [C-1, B, V] | None, proposals
    [B, C-1], nprop [B], run [B]) -> (key, candidates [B, C], accept [B])``.

    Greedy: candidates are the target argmax at every position; accept is
    the longest prefix where the draft proposed exactly those tokens —
    emitted output is token-identical to target-only greedy decoding.

    Stochastic (temperature / top_k): standard residual rejection sampling.
    Position i accepts draft token d_i iff ``u_i * q_i(d_i) <= p_i(d_i)``
    (p, q both built by ``_probs`` so the draw distributions match
    ``_stochastic`` exactly, including the top-k winner set); the first
    rejected position resamples from the residual ``max(p - q, 0)``, and a
    fully-accepted run samples the bonus token straight from ``p`` — the
    output distribution equals target-only sampling regardless of draft
    quality. Rows past ``nprop`` never accept (their qlogits are stale
    scan garbage and must not be read into the residual).
    """
    assert kind in ("greedy", "temperature", "top_k"), kind
    temp = float(temperature)
    tk = int(top_k) if kind == "top_k" else 0

    if kind == "greedy":
        def verifier(key, logits, qlogits, proposals, nprop, run):
            cand = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, C]
            B, C = cand.shape
            i = jnp.arange(C - 1)[None]
            match = (proposals == cand[:, :-1]) & (i < nprop[:, None])
            acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
            return key, cand, acc.astype(jnp.int32)
        return verifier

    def verifier(key, logits, qlogits, proposals, nprop, run):
        B, C, V = logits.shape
        p = jax.vmap(lambda z: _probs(z, temp, tk), 1, 1)(logits)  # [B,C,V]
        q = jax.vmap(lambda z: _probs(z, temp, tk))(qlogits)     # [C-1,B,V]
        q = q.transpose(1, 0, 2)                                 # [B,C-1,V]
        key, ku, kr = jax.random.split(key, 3)
        u = jax.random.uniform(ku, (B, C - 1), jnp.float32)
        prop = jnp.clip(proposals, 0, V - 1)
        rows = jnp.arange(B)[:, None]
        cols = jnp.arange(C - 1)[None]
        p_d = p[:, :-1][rows, cols, prop]
        q_d = q[rows, cols, prop]
        ok = (u * q_d <= p_d) & (cols < nprop[:, None])
        acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
        # distribution for the one non-draft token: the residual at the
        # first rejected position, or p itself after a full accept (q at
        # row nprop was never computed by the draft scan — do not read it)
        p_acc = p[jnp.arange(B), acc]                            # [B, V]
        q_pad = jnp.concatenate([q, jnp.zeros_like(q[:, :1])], axis=1)
        q_acc = q_pad[jnp.arange(B), acc]
        full = (acc == nprop)[:, None]
        resid = jnp.where(full, p_acc, jnp.maximum(p_acc - q_acc, 0.0))
        extra = jax.random.categorical(
            kr, jnp.log(resid + 1e-30), axis=-1).astype(jnp.int32)
        idx = jnp.arange(C)[None]
        prop_pad = jnp.concatenate(
            [prop, jnp.zeros((B, 1), jnp.int32)], axis=1)
        cand = jnp.where(idx < acc[:, None], prop_pad, extra[:, None])
        return key, cand.astype(jnp.int32), acc.astype(jnp.int32)
    return verifier


class Sampler:
    """Stateful batch sampler: ``sampler(logits)`` -> np.int32 tokens.

    Accepts [V] or [B, V] logits (np or jnp). Greedy is stateless;
    temperature/top_k thread one PRNG key through ``stochastic_sample_step``
    (split in-jit), so token streams are deterministic in (seed, call order).
    """

    def __init__(self, kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0):
        assert kind in ("greedy", "temperature", "top_k"), kind
        self.kind = kind
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(seed)

    def __call__(self, logits) -> np.ndarray:
        logits = jnp.asarray(logits)
        squeeze = logits.ndim == 1
        if squeeze:
            logits = logits[None]
        if self.kind == "greedy":
            out = greedy_sample(logits)
        else:
            self._key, out = stochastic_sample_step(
                self._key, logits, self.temperature,
                self.top_k if self.kind == "top_k" else 0)
        out = np.asarray(out)
        return out[0] if squeeze else out


def make_sampler(kind: str = "greedy", *, temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0) -> Sampler:
    return Sampler(kind, temperature=temperature, top_k=top_k, seed=seed)
