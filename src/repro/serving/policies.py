"""Pluggable admission/scheduling policies for the continuous batcher.

The seed batcher hard-coded a strict head-of-line FCFS scan; the paper's
host loop (Fig. 2) co-designs scheduling with the DPA allocator, so the
policy is a plug-in point on ``core.scheduler.ContinuousBatcher``.

Contract: ``select(batcher, row)`` is called once per open slot and returns
the index into ``batcher.queue`` of the request to admit, or None to leave
the slot empty this tick. A policy must only return requests that pass
``batcher.alloc.can_admit`` — the batcher admits whatever the policy picks.

A policy may additionally implement ``preempt_victims(batcher) -> set``:
the scheduler calls it once per tick (at the same mid-tick frame where
allocator exhaustion preempts) and routes every returned slot through the
existing ``_preempt`` snapshot/restore path — preemption is restore, not
recompute, so a preempted request's output is token-identical on resume.

Policies register by name (``@register_policy``) with a per-policy config
dataclass; ``make_policy`` resolves a name, a config instance, or a
ready-made policy object. ``launch/serve.py --sched-policy`` keys into the
same registry, so new policies plug in without touching engine code.

SLO fields (priority tier, TTFT target, deadline) are read from the
request's immutable submission spec (``serving.Request``, attached to the
scheduler request as ``req.spec``); timestamps come from ``batcher.clock``
so the SLO/EDF policies are deterministic under a virtual clock.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

from repro.core import pim_model as PM

#: name -> policy class; populated by @register_policy
POLICIES: dict[str, type] = {}
#: per-policy config dataclass -> policy class (make_policy accepts either)
_CONFIGS: dict[type, type] = {}


def register_policy(name: str):
    """Class decorator: register a SchedulingPolicy subclass under ``name``
    (and its ``Config`` dataclass, when it defines its own)."""
    def deco(cls):
        cls.name = name
        POLICIES[name] = cls
        cfg_t = cls.__dict__.get("Config")
        if cfg_t is not None:
            _CONFIGS[cfg_t] = cls
        return cls
    return deco


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(POLICIES))


class SchedulingPolicy:
    name = "base"

    @dataclass
    class Config:
        pass

    def __init__(self, cfg=None, **kw):
        if cfg is None:
            cfg = self.Config(**kw)
        elif kw:
            raise TypeError(f"{type(self).__name__}: pass a Config or "
                            f"kwargs, not both: {sorted(kw)}")
        self.cfg = cfg

    def select(self, batcher, row: int | None = None) -> int | None:
        raise NotImplementedError

    def _admissible(self, batcher, row):
        for i, req in enumerate(batcher.queue):
            if batcher.alloc.can_admit(req.prompt_len, row,
                                       batcher.cached_pages(req)):
                yield i, req


def _spec(req):
    return getattr(req, "spec", None)


def _effective_deadline(req) -> float:
    """Absolute urgency deadline of a queued request: the earlier of its
    hard deadline and its TTFT target (both anchored at submit). +inf when
    neither is set, so unconstrained requests sort last under EDF."""
    spec = _spec(req)
    dl = math.inf
    if spec is not None:
        if spec.deadline_s:
            dl = req.submit_t + spec.deadline_s
        if spec.ttft_slo_s:
            dl = min(dl, req.submit_t + spec.ttft_slo_s)
    return dl


@register_policy("fcfs")
class FCFSPolicy(SchedulingPolicy):
    """First-come-first-served with strict head-of-line blocking (the seed
    behavior): if the oldest request doesn't fit, nothing is admitted."""

    def select(self, batcher, row=None):
        q = batcher.queue
        if q and batcher.alloc.can_admit(q[0].prompt_len, row,
                                         batcher.cached_pages(q[0])):
            return 0
        return None


@register_policy("sjf")
class SJFPolicy(SchedulingPolicy):
    """Shortest-job-first: admit the admissible request with the smallest
    expected footprint. ``by='prompt'`` ranks on prompt length alone,
    ``by='total'`` on prompt + token budget (expected lifetime). Ties break
    FCFS (earlier arrival wins)."""

    @dataclass
    class Config:
        by: str = "total"

    def __init__(self, cfg=None, **kw):
        super().__init__(cfg, **kw)
        assert self.cfg.by in ("prompt", "total"), self.cfg.by
        self.by = self.cfg.by

    def _size(self, req) -> int:
        return req.prompt_len if self.by == "prompt" \
            else req.prompt_len + req.max_new_tokens

    def select(self, batcher, row=None):
        best, best_size = None, math.inf
        for i, req in self._admissible(batcher, row):
            if self._size(req) < best_size:
                best, best_size = i, self._size(req)
        return best


@register_policy("edf")
class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first: among admissible queued requests, admit the
    one whose effective deadline (hard ``deadline_s`` or TTFT target,
    whichever is earlier) is soonest. Requests with no deadline sort last;
    ties break FCFS. Classic EDF — optimal for meeting deadlines when the
    system is feasible, no notion of priority tiers (see SLOPolicy)."""

    def select(self, batcher, row=None):
        best, best_key = None, None
        for i, req in self._admissible(batcher, row):
            key = (_effective_deadline(req), req.submit_t, i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best


@register_policy("slo")
class SLOPolicy(SchedulingPolicy):
    """SLO-aware tiered scheduling: admission ranks by priority tier first
    (higher tier always beats lower), then EDF within a tier, and lower
    tiers backfill when no higher-tier candidate fits.

    Preemption: when the most urgent queued request (a) outranks a running
    one, (b) has burned ``starve_frac`` of its TTFT budget waiting, and
    (c) still cannot be admitted, the policy names a victim slot for the
    scheduler's snapshot/restore preemption path. Victims are lower-tier
    running requests; among them, one that is already *over budget*
    (elapsed time exceeds its own TTFT + generated x TPOT allowance — it
    cannot contribute goodput by continuing) is taken first, then the
    lowest tier, then the highest slot index. At most
    ``max_preempts_per_tick`` victims per tick bounds thrash; the
    preempted request re-queues at the front and resumes from its cached
    KV / recurrent-carry snapshot (restore, not recompute)."""

    @dataclass
    class Config:
        preempt: bool = True
        # preempt for a waiter once it has burned this fraction of its
        # TTFT budget in the queue (patience_s when it has no target)
        starve_frac: float = 0.5
        patience_s: float = 0.25
        max_preempts_per_tick: int = 1

    def _key(self, req, i):
        return (-getattr(req, "priority", 0), _effective_deadline(req),
                req.submit_t, i)

    def select(self, batcher, row=None):
        order = sorted((self._key(req, i), i, req)
                       for i, req in enumerate(batcher.queue))
        for _, i, req in order:
            if batcher.alloc.can_admit(req.prompt_len, row,
                                       batcher.cached_pages(req)):
                return i
        return None

    # ---- tick-level preemption hook ----------------------------------
    def _ttft_budget(self, req) -> float:
        spec = _spec(req)
        if spec is not None and spec.ttft_slo_s:
            return spec.ttft_slo_s
        return self.cfg.patience_s

    def _over_budget(self, req, now: float) -> bool:
        """A running request has blown its own SLO allowance so far:
        elapsed > TTFT target + generated tokens x TPOT target (or its
        hard deadline has passed). False when it has no targets."""
        spec = _spec(req)
        if spec is None:
            return False
        elapsed = now - req.submit_t
        if spec.deadline_s and elapsed > spec.deadline_s:
            return True
        if spec.ttft_slo_s and spec.tpot_slo_s:
            return elapsed > (spec.ttft_slo_s
                              + spec.tpot_slo_s * max(0, req.generated - 1))
        return False

    def preempt_victims(self, batcher) -> set[int]:
        if not self.cfg.preempt or not batcher.queue:
            return set()
        now = batcher.clock()
        # the most urgent starved waiter the batcher cannot place. The
        # hook runs right after admission, so anyone still queued is
        # blocked on slots or pages; only a waiter that BOTH has a free
        # slot and fits the page pool is skipped (transiently unplaced).
        free_slot = any(r is None for r in batcher.slots)
        waiter = None
        for i, req in enumerate(batcher.queue):
            waited = now - req.submit_t
            if waited < self.cfg.starve_frac * self._ttft_budget(req):
                continue
            if free_slot and batcher.alloc.can_admit(
                    req.prompt_len, None, batcher.cached_pages(req)):
                continue               # admissible on its own: no victim
            key = self._key(req, i)
            if waiter is None or key < waiter[0]:
                waiter = (key, req)
        if waiter is None:
            return set()
        wreq = waiter[1]
        wprio = getattr(wreq, "priority", 0)
        victims = []
        for s, r in enumerate(batcher.slots):
            if r is None or not r.prefill_done or r.generated <= 0:
                continue               # mid-prefill / just admitted: skip
            if getattr(r, "priority", 0) >= wprio:
                continue               # never preempt within/above the tier
            victims.append((0 if self._over_budget(r, now) else 1,
                            getattr(r, "priority", 0), -s))
        victims.sort()
        return {-v[2] for v in victims[:self.cfg.max_preempts_per_tick]}


@register_policy("memory_aware")
class MemoryAwarePolicy(SchedulingPolicy):
    """Admission control against request *lifetime* footprint, ranked by the
    analytic decode cost model (``core.pim_model.decode_latency``).

    A request is admissible only if pages for prompt + max_new_tokens fit
    the free pool with ``headroom_pages`` spare — unlike FCFS, which admits
    on prompt footprint alone and pays for it with mid-decode preemptions
    (the re-prefill the paper's DPA is designed to amortize away). Among
    admissible candidates the policy picks the one the cost model says
    yields the lowest per-token decode latency at the resulting batch.

    With a prefix cache attached the capacity side counts reclaimable cached
    pages (``alloc.available_pages``) and a candidate's need shrinks by its
    matched prefix — shared and host-offloaded KV are admission capacity.
    The price of the host-resident part, one swap-in over the host link, is
    added to the candidate's modelled cost (``pim_model.swap_latency``) so a
    swap-heavy hit only wins when it beats the prefill it replaces.

    When the system is idle and no candidate passes the lifetime check, the
    policy degrades to FCFS admission so a single oversized request cannot
    livelock the queue (it will run under preemption, as the seed did).
    """

    @dataclass
    class Config:
        system: Any = None
        model: Any = None
        headroom_pages: int = 0

    def __init__(self, cfg=None, **kw):
        super().__init__(cfg, **kw)
        self.system = self.cfg.system or PM.System(
            PM.PIM_NODE, n_nodes=1, itpp=True, dpa=True, pingpong=True)
        self.model = self.cfg.model or PM.QWEN_7B
        self.headroom = self.cfg.headroom_pages

    def _lifetime_pages(self, alloc, req) -> int:
        return -(-(req.prompt_len + req.max_new_tokens) // alloc.page_size)

    def _cached(self, batcher, req) -> tuple[int, int]:
        """(device, host) pages the prefix cache would cover."""
        if batcher.cache is None:
            return 0, 0
        return batcher.cache.peek(batcher.cache_tokens(req, False))

    def _cost(self, batcher, req, host_pages: int = 0) -> float:
        """Modelled seconds/token if ``req`` joins the current batch, plus
        the amortized swap-in of its host-resident prefix."""
        ctxs = [r.total_len for r in batcher.slots if r is not None]
        B = len(ctxs) + 1
        avg = (sum(ctxs) + req.prompt_len + req.max_new_tokens) / B
        cost = PM.decode_latency(self.system, self.model, B,
                                 max(avg, 1.0))["t_step"] / B
        if host_pages:
            swap = PM.swap_latency(self.model,
                                   host_pages * batcher.alloc.page_size)
            cost += swap / max(1, req.max_new_tokens)
        return cost

    def select(self, batcher, row=None):
        alloc = batcher.alloc
        free = alloc.available_pages(row if alloc.policy == "row_affine"
                                     else None)
        best, best_cost = None, math.inf
        fallback = None
        for i, req in self._admissible(batcher, row):
            if fallback is None:
                fallback = i
            dev, host = self._cached(batcher, req)
            # host-resident matched pages don't reduce the device need
            # (swap-in consumes a device page apiece) — they only shift
            # cost from prefill compute to the host link
            need = self._lifetime_pages(alloc, req) - dev
            if need + self.headroom > free:
                continue                    # would preempt mid-decode: refuse
            cost = self._cost(batcher, req, host)
            if cost < best_cost:
                best, best_cost = i, cost
        if best is None and fallback is not None \
                and all(r is None for r in batcher.slots):
            return fallback                 # idle system: degrade to FCFS
        return best


def route_least_loaded(loads: dict[int, float]) -> int | None:
    """Router-side engine pick for the cluster (``serving/cluster.py``):
    the candidate with the least outstanding work, ties broken toward the
    lowest engine index so routing is deterministic across replays."""
    if not loads:
        return None
    return min(loads, key=lambda ix: (loads[ix], ix))


def make_policy(name, **kw) -> SchedulingPolicy:
    """Resolve a policy: a registered name ('fcfs' | 'sjf' | 'edf' | 'slo'
    | 'memory_aware', plus kwargs for its Config), a per-policy Config
    instance, or a ready SchedulingPolicy passed through."""
    if isinstance(name, SchedulingPolicy):
        return name
    if type(name) in _CONFIGS:
        return _CONFIGS[type(name)](name)
    try:
        cls = POLICIES[name]
    except (KeyError, TypeError):
        raise KeyError(f"unknown policy {name!r}; registered: "
                       f"{', '.join(available_policies())}") from None
    return cls(**kw)
