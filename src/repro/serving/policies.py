"""Pluggable admission/scheduling policies for the continuous batcher.

The seed batcher hard-coded a strict head-of-line FCFS scan; the paper's
host loop (Fig. 2) co-designs scheduling with the DPA allocator, so the
policy is now a plug-in point on ``core.scheduler.ContinuousBatcher``.

Contract: ``select(batcher, row)`` is called once per open slot and returns
the index into ``batcher.queue`` of the request to admit, or None to leave
the slot empty this tick. A policy must only return requests that pass
``batcher.alloc.can_admit`` — the batcher admits whatever the policy picks.
"""
from __future__ import annotations

import math

from repro.core import pim_model as PM


class SchedulingPolicy:
    name = "base"

    def select(self, batcher, row: int | None = None) -> int | None:
        raise NotImplementedError

    def _admissible(self, batcher, row):
        for i, req in enumerate(batcher.queue):
            if batcher.alloc.can_admit(req.prompt_len, row,
                                       batcher.cached_pages(req)):
                yield i, req


class FCFSPolicy(SchedulingPolicy):
    """First-come-first-served with strict head-of-line blocking (the seed
    behavior): if the oldest request doesn't fit, nothing is admitted."""
    name = "fcfs"

    def select(self, batcher, row=None):
        q = batcher.queue
        if q and batcher.alloc.can_admit(q[0].prompt_len, row,
                                         batcher.cached_pages(q[0])):
            return 0
        return None


class SJFPolicy(SchedulingPolicy):
    """Shortest-job-first: admit the admissible request with the smallest
    expected footprint. ``by='prompt'`` ranks on prompt length alone,
    ``by='total'`` on prompt + token budget (expected lifetime). Ties break
    FCFS (earlier arrival wins)."""
    name = "sjf"

    def __init__(self, by: str = "total"):
        assert by in ("prompt", "total"), by
        self.by = by

    def _size(self, req) -> int:
        return req.prompt_len if self.by == "prompt" \
            else req.prompt_len + req.max_new_tokens

    def select(self, batcher, row=None):
        best, best_size = None, math.inf
        for i, req in self._admissible(batcher, row):
            if self._size(req) < best_size:
                best, best_size = i, self._size(req)
        return best


class MemoryAwarePolicy(SchedulingPolicy):
    """Admission control against request *lifetime* footprint, ranked by the
    analytic decode cost model (``core.pim_model.decode_latency``).

    A request is admissible only if pages for prompt + max_new_tokens fit
    the free pool with ``headroom_pages`` spare — unlike FCFS, which admits
    on prompt footprint alone and pays for it with mid-decode preemptions
    (the re-prefill the paper's DPA is designed to amortize away). Among
    admissible candidates the policy picks the one the cost model says
    yields the lowest per-token decode latency at the resulting batch.

    With a prefix cache attached the capacity side counts reclaimable cached
    pages (``alloc.available_pages``) and a candidate's need shrinks by its
    matched prefix — shared and host-offloaded KV are admission capacity.
    The price of the host-resident part, one swap-in over the host link, is
    added to the candidate's modelled cost (``pim_model.swap_latency``) so a
    swap-heavy hit only wins when it beats the prefill it replaces.

    When the system is idle and no candidate passes the lifetime check, the
    policy degrades to FCFS admission so a single oversized request cannot
    livelock the queue (it will run under preemption, as the seed did).
    """
    name = "memory_aware"

    def __init__(self, system: PM.System | None = None,
                 model: PM.LLM | None = None, headroom_pages: int = 0):
        self.system = system or PM.System(PM.PIM_NODE, n_nodes=1, itpp=True,
                                          dpa=True, pingpong=True)
        self.model = model or PM.QWEN_7B
        self.headroom = headroom_pages

    def _lifetime_pages(self, alloc, req) -> int:
        return -(-(req.prompt_len + req.max_new_tokens) // alloc.page_size)

    def _cached(self, batcher, req) -> tuple[int, int]:
        """(device, host) pages the prefix cache would cover."""
        if batcher.cache is None:
            return 0, 0
        return batcher.cache.peek(batcher.cache_tokens(req, False))

    def _cost(self, batcher, req, host_pages: int = 0) -> float:
        """Modelled seconds/token if ``req`` joins the current batch, plus
        the amortized swap-in of its host-resident prefix."""
        ctxs = [r.total_len for r in batcher.slots if r is not None]
        B = len(ctxs) + 1
        avg = (sum(ctxs) + req.prompt_len + req.max_new_tokens) / B
        cost = PM.decode_latency(self.system, self.model, B,
                                 max(avg, 1.0))["t_step"] / B
        if host_pages:
            swap = PM.swap_latency(self.model,
                                   host_pages * batcher.alloc.page_size)
            cost += swap / max(1, req.max_new_tokens)
        return cost

    def select(self, batcher, row=None):
        alloc = batcher.alloc
        free = alloc.available_pages(row if alloc.policy == "row_affine"
                                     else None)
        best, best_cost = None, math.inf
        fallback = None
        for i, req in self._admissible(batcher, row):
            if fallback is None:
                fallback = i
            dev, host = self._cached(batcher, req)
            # host-resident matched pages don't reduce the device need
            # (swap-in consumes a device page apiece) — they only shift
            # cost from prefill compute to the host link
            need = self._lifetime_pages(alloc, req) - dev
            if need + self.headroom > free:
                continue                    # would preempt mid-decode: refuse
            cost = self._cost(batcher, req, host)
            if cost < best_cost:
                best, best_cost = i, cost
        if best is None and fallback is not None \
                and all(r is None for r in batcher.slots):
            return fallback                 # idle system: degrade to FCFS
        return best


def route_least_loaded(loads: dict[int, float]) -> int | None:
    """Router-side engine pick for the cluster (``serving/cluster.py``):
    the candidate with the least outstanding work, ties broken toward the
    lowest engine index so routing is deterministic across replays."""
    if not loads:
        return None
    return min(loads, key=lambda ix: (loads[ix], ix))


def make_policy(name, **kw) -> SchedulingPolicy:
    """Resolve a policy by name ('fcfs' | 'sjf' | 'memory_aware') or pass a
    SchedulingPolicy instance through."""
    if isinstance(name, SchedulingPolicy):
        return name
    return {"fcfs": FCFSPolicy, "sjf": SJFPolicy,
            "memory_aware": MemoryAwarePolicy}[name](**kw)
