"""The public request-submission spec (``serving.Request``).

PRs 1-9 accreted kwargs onto ``Engine.submit(req_id, prompt,
max_new_tokens, deadline_s=...)``; the SLO layer needs several more
(priority tier, TTFT/TPOT targets, tenant + shared-prefix group), so
submission is now one spec object. ``engine.submit()`` and
``cluster.submit()`` accept it; scheduling policies and the request
tracker read from it (the scheduler's internal ``core.scheduler.Request``
carries a ``spec`` back-reference). The old positional signature survives
as a thin deprecated shim — exercised only by the back-compat test.

The spec is the *immutable submission record*: the scheduler mutates its
own bookkeeping fields (``prompt_len`` shrinks budget arithmetic across
preemptions) but never the spec, so SLO accounting always sees what the
client asked for.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any


@dataclass
class Request:
    """One client request.

    SLO semantics (all optional, seconds in the engine's clock frame):

    * ``deadline_s``   — hard wall budget from submit; the engine tears the
      request down (reason ``deadline``) when it expires, wherever it is in
      its lifecycle.
    * ``ttft_slo_s``   — target submit -> first token. A finished request
      over this target counts as an SLO miss for goodput.
    * ``tpot_slo_s``   — target mean inter-token time after the first.
    * ``priority``     — scheduling tier, higher = more urgent. The SLO
      policy admits strictly by tier and may preempt a lower-tier running
      request for a starved higher-tier one.
    * ``tenant`` / ``prefix_group`` — workload identity: which traffic
      class this request belongs to and which shared-prefix family its
      prompt was drawn from (the workload generator keys shared prompt
      prefixes on ``prefix_group``; the radix cache does the actual
      sharing by token content).
    """
    req_id: int
    prompt: Any                          # token ids (array-like of int)
    max_new_tokens: int
    deadline_s: float | None = None
    priority: int = 0
    ttft_slo_s: float | None = None
    tpot_slo_s: float | None = None
    tenant: str | None = None
    prefix_group: int | None = None
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        self.req_id = int(self.req_id)
        self.max_new_tokens = int(self.max_new_tokens)
