"""Serving scenario: continuous batching over a LongBench-statistics trace,
lazy (DPA) vs static allocation — the paper's §5.4 experiment end to end —
plus the chunked-prefill (DCS-style) overlap and the KV-cache hierarchy
(radix prefix sharing + host offload, repro.kvcache) on a shared
system-prompt workload.

  PYTHONPATH=src python examples/serve_longbench.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    # memory-constrained regime: static allocation must reserve
    # max_context/page = 32 pages per request -> the 72-page pool holds just
    # 2 static requests, while lazy admission fits many short ones
    common = ["--requests", "10", "--slots", "6", "--page", "8",
              "--pages", "72", "--max-context", "256", "--mean-new", "10"]
    print("=== lazy (DPA ②) ===")
    lazy = serve_main(common)
    print("=== static (baseline PIM) ===")
    static = serve_main(common + ["--static"])
    print(f"\navg-batch gain from lazy allocation: "
          f"{lazy / max(static, 1e-9):.2f}x (paper Fig. 4(b): up to 3.8x "
          f"in the memory-constrained regime)")
    print("=== lazy + chunked prefill (DCS-style overlap) ===")
    serve_main(common + ["--prefill-mode", "chunked", "--chunk", "16"])

    # multi-tenant shared-system-prompt traffic: 90% of every prompt is the
    # same system prefix. With the prefix cache the engine prefills it once
    # and later admissions borrow the pages (prefill O(suffix)); the host
    # tier keeps evicted prefixes one swap away instead of recomputing.
    shared = ["--requests", "10", "--slots", "6", "--page", "8",
              "--pages", "72", "--max-context", "256", "--mean-new", "10",
              "--shared-frac", "0.9"]
    print("\n=== shared system prompt, no sharing (baseline) ===")
    serve_main(shared)
    print("=== shared system prompt + radix prefix cache ===")
    serve_main(shared + ["--prefix-cache"])
    print("=== + host offload tier (64 host pages) ===")
    serve_main(shared + ["--prefix-cache", "--host-pages", "64"])
