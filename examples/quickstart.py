"""Quickstart: build an assigned arch, run forward / prefill / paged decode.

  PYTHONPATH=src python examples/quickstart.py [arch]

Walks the public API end to end on CPU with a reduced config: tokens ->
logits, then the serving path (prefill fills the DPA paged KV pool; decode
steps run ITPP attention against it) and checks the two agree.
"""
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.allocator import PageAllocator
from repro.core.paged_kv import PoolSpec
from repro.models import model as MDL
from repro.serving import Request as Req

arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b"
cfg = replace(reduced(get_config(arch)), dtype="float32")
print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
      f"d_model={cfg.d_model}")

params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
B, S, page = 2, 12, 4
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

# ---- 1. full-sequence forward ----
logits, _ = MDL.forward(cfg, params, tokens)
print("forward:", logits.shape, "finite:", bool(jnp.isfinite(logits).all()))

# ---- 2. serving path: prefill 8 tokens, decode 4 more ----
S_pre = 8
n_attn = cfg.n_layers if cfg.family == "encdec" else sum(
    1 for k in cfg.block_kinds() if k in ("attn", "local"))
spec = PoolSpec(max(n_attn, 1), 32, page, cfg.n_kv_heads, cfg.d_head,
                S // page + 1, dtype="float32")
state = MDL.init_decode_state(cfg, spec, B, dtype="float32")
alloc = PageAllocator(32, 1, page)
bts = []
for b in range(B):
    alloc.admit(b, S)                       # lazy Va2Pa pages
    bts.append(alloc.block_table(b, spec.max_pages_per_req))
bt = jnp.asarray(np.stack(bts))
frames = (jax.random.normal(jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
          if cfg.family == "encdec" else None)
last, state = MDL.prefill(cfg, params, state, tokens[:, :S_pre], bt,
                          frames=frames)
print("prefill logits match forward:",
      bool(np.allclose(last, logits[:, S_pre - 1], atol=1e-3)))

for t in range(S_pre, S):
    ctx = jnp.full((B,), t + 1, jnp.int32)
    npage = jnp.asarray([bts[b][t // page] for b in range(B)])
    noff = jnp.full((B,), t % page, jnp.int32)
    lg, state = MDL.decode_step(cfg, params, state, tokens[:, t], bt, ctx,
                                npage, noff)
    ok = np.allclose(lg, logits[:, t], atol=5e-3)
    print(f"decode t={t}: argmax={int(jnp.argmax(lg[0]))} matches forward: {ok}")

# ---- 3. serving engine: continuous batching over the same model ----
# (the layered repro.serving subsystem: batched prefill + FCFS admission +
# jitted greedy sampling; see docs/serving.md)
if cfg.family != "encdec":
    from repro.serving import DecodeEngine, EngineConfig
    ecfg = EngineConfig(n_slots=2, page_size=page, n_pages=64, max_context=32,
                        eos_token=-1, prefill_mode="batched")
    eng = DecodeEngine(cfg, ecfg, params)
    rng = np.random.default_rng(0)
    for r in range(3):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size, size=6), 4))
    outs = eng.run(100)
    print(f"serving: completed={eng.batcher.stats.completed} "
          f"prefill={eng.prefiller.name} "
          f"outputs={[list(v) for v in outs.values()]}")
print("done.")
