"""End-to-end driver: train a ~100M-param llama-style model for a few
hundred steps with checkpoint/restart (deliverable (b), training flavor).

  PYTHONPATH=src python examples/train_100m.py [--steps 200]

~100M params: d_model=768, 12 layers, 8k vocab. On this 1-core CPU container
a full run takes a while; --steps trims it. The loss should fall from ~9 to
well under 7 within the first tens of steps.
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()
    train_main([
        "--arch", "llama3.2-1b",
        "--steps", str(args.steps),
        "--seq", "256",
        "--batch", "8",
        "--d-model", "768",
        "--layers", "12",
        "--vocab", "8192",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
    ])
