"""AOT-compile one production cell and print its roofline — the multi-pod
dry-run as a 20-line script.

  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]

(The full 68-cell sweep: PYTHONPATH=src python -m repro.launch.dryrun --all
 --both-meshes.)
"""
import sys

# NOTE: repro.launch.dryrun sets XLA_FLAGS for 512 host devices as its very
# first statement — import it before anything touches jax.
from repro.launch.dryrun import run_cell

arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b"
shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"

res = run_cell(arch, shape, multi_pod=True, save=False)
if res["ok"]:
    r, m = res["roofline"], res["memory"]
    print(f"\ncell {res['cell']} on {res['devices']} devices:")
    print(f"  peak memory/device : {m['peak_bytes_tpu_adjusted'] / 2**30:.2f} GiB")
    print(f"  compute term       : {r['t_compute'] * 1e3:.2f} ms")
    print(f"  memory term        : {r['t_memory'] * 1e3:.2f} ms")
    print(f"  collective term    : {r['t_collective'] * 1e3:.2f} ms")
    print(f"  bottleneck         : {r['bottleneck']}")
else:
    print("FAILED:", res["error"])
