"""Speculative decoding on the fused horizon scan: draft-propose + one-pass
multi-query verify (serving.engine draft plumbing, models.model
draft_propose/decode_verify, sampling.make_verifier).

The load-bearing invariant: GREEDY speculative output is token-identical to
target-only decoding for ANY draft — acceptance is longest-matching-prefix
against the target's own argmax, and every rejected proposal's KV is dead
(masked then overwritten) by construction. The matrix below drives that
through every prefill mode, EOS/budget truncation mid-round, preemption +
resume, prefix sharing and a never-accepting draft (pure rollback).
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import reduced, validate_draft_pair
from repro.models import model as MDL
from repro.serving import DecodeEngine, EngineConfig
from repro.serving import Request as Req

BUDGETS = [3, 12, 5, 12, 2, 9]


@functools.lru_cache(maxsize=None)
def _setup():
    cfg = reduced(get_config("llama3.2-1b"))
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@functools.lru_cache(maxsize=None)
def _draft_setup():
    """A REAL small draft: 1 layer vs the target's 2, independent weights."""
    cfg, _ = _setup()
    dcfg = reduced(get_config("llama3.2-1b"), layers=1)
    dparams = MDL.init_params(dcfg, jax.random.PRNGKey(7), jnp.float32)
    return dcfg, dparams


def _prompts(nreq=6, shared=0, seed=3):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, 256, size=shared).astype(np.int32) if shared else None
    out = []
    for _ in range(nreq):
        p = rng.integers(0, 256, size=int(rng.integers(3, 20))).astype(np.int32)
        out.append(np.concatenate([pre, p]) if shared else p)
    return out


def _run(mode="batched", *, draft=None, spec_horizon=3, n_pages=96,
         cache=False, eos=-1, budgets=None, nreq=6, sampler="greedy",
         seed=0, shared=0, gentle=False):
    cfg, params = _setup()
    dcfg = dparams = None
    if draft == "real":
        dcfg, dparams = _draft_setup()
    elif draft == "oracle":          # draft == target: accepts everything
        dcfg, dparams = cfg, params
    ecfg = EngineConfig(
        n_slots=3, page_size=4, n_pages=n_pages, max_context=64,
        prefill_mode=mode, prefill_chunk=5, eos_token=eos, sampler=sampler,
        temperature=0.8, top_k=8 if sampler == "top_k" else 0,
        sample_seed=seed, prefix_cache=cache, reserve_gentle=gentle,
        decode_horizon=spec_horizon + 1 if dcfg is None else 1,
        draft_config=dcfg, spec_horizon=spec_horizon)
    eng = DecodeEngine(cfg, ecfg, params=params, draft_params=dparams)
    for i, (p, b) in enumerate(zip(_prompts(nreq, shared),
                                   budgets or BUDGETS[:nreq])):
        eng.submit(Req(i, p, b))
    out = eng.run()
    return {k: list(v) for k, v in out.items()}, eng


# ---------------------------------------------------------------------------
# greedy equivalence matrix: every prefill mode x draft quality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["slot", "batched", "chunked"])
@pytest.mark.parametrize("draft", ["real", "oracle"])
def test_greedy_token_identity(mode, draft):
    base, _ = _run(mode)
    spec, eng = _run(mode, draft=draft)
    assert spec == base, (mode, draft)
    assert eng.spec_rounds > 0
    if draft == "oracle":            # identical logits -> full acceptance
        assert eng.spec_accepted == eng.spec_proposed > 0
    assert eng.alloc.pages_in_use == 0


def test_spec_sync_budget():
    """One host sync per speculative round — the draft scan, catch-up and
    verify ride the same dispatch window, so syncs-per-token beats the
    non-spec engine at equal horizon when the draft accepts."""
    _, base = _run("batched", spec_horizon=3)
    _, spec = _run("batched", draft="oracle", spec_horizon=3)
    assert spec.timing.device_syncs <= base.timing.device_syncs
    assert spec.timing.decode_tokens == base.timing.decode_tokens


# ---------------------------------------------------------------------------
# mid-round EOS / budget truncation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft", ["real", "oracle"])
def test_mid_round_eos(draft):
    probe, _ = _run("batched")
    eos = probe[1][2]                # forces an EOS mid-stream for req 1
    base, _ = _run("batched", eos=eos)
    spec, eng = _run("batched", draft=draft, eos=eos)
    assert spec == base
    # truncation means the round emitted fewer tokens than it accepted —
    # outputs stop AT the EOS token
    assert spec[1][-1] == eos and eos not in spec[1][:-1]


def test_budget_truncation_exact():
    """Budgets cut rounds mid-acceptance (BUDGETS has 2/3/5-token runs
    against a 4-token round); emitted counts must equal the engine's
    budget + 1 convention (prefill's first token + max_new decode steps),
    exactly as the non-spec path does."""
    spec, eng = _run("batched", draft="oracle")
    for rid, b in enumerate(BUDGETS):
        assert len(spec[rid]) == b + 1, rid
    assert eng.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# preemption + resume, prefix sharing, rejection rollback
# ---------------------------------------------------------------------------

def test_preemption_resume():
    kw = dict(n_pages=12, nreq=3, budgets=[12, 12, 12])
    base, _ = _run("batched", **kw)
    spec, eng = _run("batched", draft="oracle", **kw)
    assert spec == base
    assert eng.batcher.stats.preempted > 0
    assert eng.alloc.pages_in_use == 0
    # re-admission reset the draft coverage and caught up from scratch
    assert eng.spec_accepted == eng.spec_proposed > 0


def test_prefix_sharing():
    base, _ = _run("batched", cache=True, shared=38)
    spec, eng = _run("batched", draft="oracle", cache=True, shared=38)
    assert spec == base
    assert eng.cache.stats.hits > 0
    # shared radix pages get bit-identical draft KV from every borrower
    assert eng.spec_accepted == eng.spec_proposed > 0


def test_rejection_rollback():
    """A draft that never matches (random weights, greedy target) exercises
    the full-rollback path every round: stale KV beyond the accepted prefix
    must never leak into later logits, and no pages may leak."""
    kw = dict(n_pages=12, nreq=3, budgets=[12, 12, 12])
    base, _ = _run("batched", **kw)
    spec, eng = _run("batched", draft="real", **kw)
    assert spec == base
    assert eng.spec_proposed > 0
    assert eng.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# accept-length bookkeeping
# ---------------------------------------------------------------------------

def test_accept_counters_deterministic():
    """Oracle draft: acceptance is total and the counters are an exact
    function of the trajectory — every round accepts its full proposal, and
    mean accept length exceeds 1 (the CI bench gate's invariant)."""
    r1 = _run("batched", draft="oracle")[1]
    r2 = _run("batched", draft="oracle")[1]
    assert (r1.spec_rounds, r1.spec_proposed, r1.spec_accepted) == \
           (r2.spec_rounds, r2.spec_proposed, r2.spec_accepted)
    assert r1.spec_accepted == r1.spec_proposed > 0
    mean_accept = 1 + r1.spec_accepted / r1.spec_rounds
    assert mean_accept > 1.5
    # tokens emitted = sum over rounds of (accept + 1), minus truncation:
    # never more than the counters allow
    assert r1.timing.decode_tokens <= r1.spec_rounds + r1.spec_accepted \
        + sum(1 for _ in BUDGETS)    # + one first token per request


# ---------------------------------------------------------------------------
# stochastic verification (residual rejection sampling)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampler", ["temperature", "top_k"])
def test_stochastic_deterministic_and_exact_on_match(sampler):
    s1, e1 = _run("batched", draft="oracle", sampler=sampler)
    s2, e2 = _run("batched", draft="oracle", sampler=sampler)
    assert s1 == s2                  # seed-deterministic
    # p == q -> u*q <= p always -> acceptance is total even stochastically
    assert e1.spec_accepted == e1.spec_proposed > 0


def test_stochastic_mismatched_draft_runs():
    """Residual resampling path (acc < nprop): must produce valid tokens
    and stay deterministic; qlogits row at the rejection point is used, the
    stale row beyond it never is."""
    s1, e1 = _run("batched", draft="real", sampler="top_k")
    s2, e2 = _run("batched", draft="real", sampler="top_k")
    assert s1 == s2
    assert e1.spec_rounds > 0
    assert all(0 <= t < 256 for ts in s1.values() for t in ts)
    assert e1.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# construction-time validation (the tokenizer-compat bugfix)
# ---------------------------------------------------------------------------

def test_vocab_mismatch_fails_at_construction():
    """Full-size cross-family configs (genuinely different tokenizers) must
    fail in EngineConfig validation BEFORE any params are allocated — not
    as a shape error inside the verify jit."""
    target = get_config("qwen1.5-7b")
    draft = get_config("llama3.2-1b")
    with pytest.raises(ValueError, match="tokenizer mismatch"):
        validate_draft_pair(target, draft)
    ecfg = EngineConfig(n_slots=2, page_size=4, n_pages=16, max_context=32,
                        draft_config=draft)
    with pytest.raises(ValueError, match="tokenizer mismatch"):
        DecodeEngine(target, ecfg)   # full 7B config: must not init params


def test_recurrent_draft_rejected():
    cfg, _ = _setup()
    with pytest.raises(ValueError, match="attention-only"):
        validate_draft_pair(cfg, reduced(get_config("xlstm-350m")))
    with pytest.raises(ValueError, match="attention-only"):
        validate_draft_pair(reduced(get_config("zamba2-1.2b")), cfg)


def test_draft_by_registry_name():
    cfg, params = _setup()
    ecfg = EngineConfig(n_slots=2, page_size=4, n_pages=48, max_context=32,
                        eos_token=-1, draft_config="llama3.2-1b")
    with pytest.raises(ValueError, match="tokenizer mismatch"):
        # reduced target (vocab 256) vs full registry draft (128256)
        DecodeEngine(cfg, ecfg, params=params)


# ---------------------------------------------------------------------------
# gentle horizon reservation
# ---------------------------------------------------------------------------

def test_gentle_reservation_spares_cache():
    """gentle=True must never call the reclaimer for speculative growth —
    the horizon degrades instead — while aggressive reservation does."""
    from repro.core.allocator import PageAllocator
    from repro.core.scheduler import ContinuousBatcher, Request

    class Reclaimer:
        def __init__(self):
            self.calls = 0

        def reclaimable(self):
            return 4

        def reclaim(self, n):
            self.calls += 1
            return 0

    def batcher():
        alloc = PageAllocator(8, 1, 4)
        alloc.reclaimer = Reclaimer()
        b = ContinuousBatcher(alloc, 2, max_context=256, bt_width=8)
        b.submit(Request(0, 10, 50))
        b.submit(Request(1, 10, 50))
        b.step(None)
        for r in b.slots:
            r.prefill_done = True
        b.step(None)
        return b, alloc

    b, alloc = batcher()
    allow = b.reserve_horizon([0, 1], 8, gentle=True)
    assert alloc.reclaimer.calls == 0
    assert allow[0] >= 1 and allow[1] >= 1      # degraded, never starved
    b2, alloc2 = batcher()
    b2.reserve_horizon([0, 1], 8, gentle=False)
    assert alloc2.reclaimer.calls > 0


def test_gentle_end_to_end_identical():
    """Degrading the horizon never changes tokens (greedy horizons are
    trajectory-invariant), with or without a draft."""
    base, _ = _run("batched", n_pages=12, nreq=3, budgets=[12, 12, 12])
    for draft in (None, "oracle"):
        gentle, eng = _run("batched", draft=draft, gentle=True,
                           n_pages=12, nreq=3, budgets=[12, 12, 12])
        assert gentle == base, draft
        assert eng.alloc.pages_in_use == 0


# ---------------------------------------------------------------------------
# async recurrent-carry snapshots (dispatch at preempt, drain next tick)
# ---------------------------------------------------------------------------

def test_snapshot_async_drain():
    """The preemption hook must store DEVICE arrays (no sync at preempt
    time); the drain converts them to host numpy within a tick. Outputs
    stay identical to the ample-pool run (covered by
    test_recurrent_prefill); here we pin the asynchrony itself."""
    cfg = reduced(get_config("xlstm-350m"))
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(n_slots=2, page_size=4, n_pages=9, max_context=64,
                        eos_token=-1, prefill_mode="batched")
    eng = DecodeEngine(cfg, ecfg, params)

    seen = {"device": 0, "drained": 0}
    orig = DecodeEngine._drain_snapshots

    def spy(self):
        for rid in self._snap_pending:
            snap = self.rsnaps.get(rid)
            if snap is not None:
                leaves = jax.tree.leaves(snap["rows"])
                if leaves and isinstance(leaves[0], jax.Array):
                    seen["device"] += 1
        orig(self)
        for snap in self.rsnaps.values():
            leaves = jax.tree.leaves(snap["rows"])
            if leaves and isinstance(leaves[0], np.ndarray):
                seen["drained"] += 1

    eng._drain_snapshots = spy.__get__(eng)
    for i, p in enumerate(_prompts(2)):
        eng.submit(Req(i, p, 12))
    eng.run()
    assert eng.batcher.stats.preempted > 0
    assert eng.rstate_snapshots > 0
    assert seen["device"] > 0        # parked as device futures at preempt
    assert seen["drained"] > 0       # materialized by the overlap drain
    assert not eng._snap_pending
