"""int8 weight-only quantization (core/quant.py) — serving path."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import quant as Q
from repro.models import model as MDL
from repro.models.layers import dense


def test_roundtrip_error_bounded():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 0.05
    qw = Q.quantize_tensor(w)
    deq = Q.dequantize_tensor(qw, jnp.float32)
    rel = float(jnp.abs(deq - w).max() / jnp.abs(w).max())
    assert rel < 1.0 / 127 + 1e-3
    assert qw["q"].dtype == jnp.int8 and qw["s"].shape == (1, 128)


def test_dense_qtensor_matches_dequantized():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32)) * 0.1
    qw = Q.quantize_tensor(w)
    y_q = dense(x, qw)
    y_deq = dense(x, Q.dequantize_tensor(qw, jnp.float32))
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_deq),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x22b"])
def test_quantized_decode_close_to_fp(arch):
    cfg = replace(reduced(get_config(arch)), dtype="float32")
    if cfg.is_moe:
        cfg = replace(cfg, capacity_factor=8.0)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    qparams = Q.quantize_params(params, min_size=1)
    assert Q.quantized_bytes(qparams) < 0.65 * Q.quantized_bytes(params)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lg_fp, _ = MDL.forward(cfg, params, toks)
    lg_q, _ = MDL.forward(cfg, qparams, toks)
    fp = np.asarray(lg_fp)
    qq = np.asarray(lg_q)
    # per-channel int8 keeps logits within a small fraction of their spread
    assert np.abs(qq - fp).max() < 0.12 * (fp.max() - fp.min())
    # and greedy decisions overwhelmingly agree
    agree = (fp.argmax(-1) == qq.argmax(-1)).mean()
    assert agree >= 0.75, agree


def test_quantize_params_skips_small_and_norms():
    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    qp = Q.quantize_params(params)  # default min_size keeps smoke weights fp
    kinds = {type(x) for x in jax.tree.leaves(
        qp, is_leaf=Q.is_qtensor) if Q.is_qtensor(x)}
    # embed table must never be quantized (gather path)
    assert not Q.is_qtensor(qp["embed"])
