"""Fault-tolerance integration: a crashed-and-restarted training run must
reproduce the uninterrupted run exactly (checkpoint + per-step-seeded data).
This is the restart contract the 1000-node design relies on
(runtime/checkpoint.py + data/pipeline.py; DESIGN.md §4)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import TrainPipeline
from repro.models import model as MDL
from repro.runtime import checkpoint as CK
from repro.training import optimizer as OPT
from repro.training.train import make_train_step


def test_crash_restart_resumes_exactly(tmp_path):
    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params0 = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt_cfg = OPT.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn = jax.jit(make_train_step(cfg, MDL.DEFAULT_RT, opt_cfg))
    pipe = TrainPipeline(cfg.vocab_size, seq_len=16, global_batch=4)

    def run(params, opt, start, stop, ckpt_every=None):
        losses = []
        for s in range(start, stop):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
            if ckpt_every and (s + 1) % ckpt_every == 0:
                CK.save(tmp_path, s, {"params": params, "opt": opt})
        return params, opt, losses

    # uninterrupted reference: 10 steps
    p_ref, o_ref, loss_ref = run(params0, OPT.init(params0), 0, 10)

    # crashed run: 6 steps with checkpoints every 3, then "crash"
    run(params0, OPT.init(params0), 0, 6, ckpt_every=3)
    latest = CK.latest_step(tmp_path)
    assert latest == 5
    state = CK.restore(tmp_path, latest,
                       {"params": params0, "opt": OPT.init(params0)})
    # restart from the checkpoint and finish
    p_res, o_res, loss_res = run(state["params"], state["opt"], latest + 1, 10)

    np.testing.assert_allclose(loss_res, loss_ref[6:], rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_restart_on_smaller_mesh_plan():
    """Elastic contract: after failures the remesh plan keeps the model axis
    and the checkpoint restores into the new (smaller) data-parallel world."""
    from repro.runtime.elastic import MeshPlan, plan_remesh
    cur = MeshPlan(pods=1, data=4, model=4)
    new = plan_remesh(cur, failed_devices=[5])   # kills data-row 1
    assert new.model == 4 and new.data == 3
    # data-axis shrink only rescales throughput; params/opt are data-replicated
    # or re-shardable on load (checkpoint stores full arrays per host shard)
