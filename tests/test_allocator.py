"""Property tests for the DPA allocator (Va2Pa bookkeeping invariants)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.allocator import PageAllocator


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_no_double_allocation_and_full_release(data):
    n_shards = data.draw(st.sampled_from([1, 2, 4, 8]))
    pages_per_shard = data.draw(st.integers(2, 8))
    n_pages = n_shards * pages_per_shard
    page_size = data.draw(st.sampled_from([2, 4, 16]))
    alloc = PageAllocator(n_pages, n_shards, page_size)
    live: dict[int, set[int]] = {}
    next_req = 0
    for _ in range(data.draw(st.integers(1, 40))):
        action = data.draw(st.sampled_from(["admit", "grow", "free"]))
        if action == "admit" and alloc.free_page_count > 0:
            toks = data.draw(st.integers(1, alloc.free_page_count * page_size))
            pages = alloc.admit(next_req, toks)
            live[next_req] = set(pages)
            next_req += 1
        elif action == "grow" and live:
            r = data.draw(st.sampled_from(sorted(live)))
            have = len(live[r])
            want = data.draw(st.integers(have * page_size,
                                         have * page_size + page_size))
            try:
                new = alloc.ensure(r, want)
            except MemoryError:
                continue
            live[r] |= set(new)
        elif action == "free" and live:
            r = data.draw(st.sampled_from(sorted(live)))
            alloc.free(r)
            del live[r]
        # invariant: no page owned twice
        seen: set[int] = set()
        for pages in live.values():
            assert not (pages & seen)
            seen |= pages
        assert alloc.pages_in_use == len(seen)
    for r in sorted(live):
        alloc.free(r)
    assert alloc.pages_in_use == 0


@settings(max_examples=30, deadline=None)
@given(n_reqs=st.integers(1, 6), toks=st.integers(1, 64))
def test_striped_balance(n_reqs, toks):
    """ITPP balance: striped allocation keeps per-shard usage within 1 page
    per request of each other (the paper's channel-balance claim)."""
    alloc = PageAllocator(256, 8, 4, policy="striped")
    for r in range(n_reqs):
        alloc.admit(r, toks)
    bal = alloc.shard_balance()
    assert bal.max() - bal.min() <= n_reqs


def test_row_affinity():
    alloc = PageAllocator(64, 8, 4, policy="row_affine", n_rows=4)
    alloc.admit(0, 24, row=2)
    for p in alloc._tables[0]:
        assert alloc.shard_of(p) in (4, 5)       # row 2 owns shards 4,5
    with pytest.raises(AssertionError):
        alloc.can_admit(8, None)


def test_static_mode_reserves_max_and_rejects_overflow():
    alloc = PageAllocator(32, 1, 4, static_max_pages=8)
    alloc.admit(0, 4)                            # 1 page of actual need
    assert alloc.pages_in_use == 8               # but reserves 8 (baseline)
    assert alloc.ensure(0, 32) == []             # within reservation
    with pytest.raises(MemoryError):
        alloc.ensure(0, 33)                      # beyond static reservation


def test_ring_mode_caps_pages():
    alloc = PageAllocator(32, 1, 4, ring_pages=3)
    alloc.admit(0, 4)
    alloc.ensure(0, 1000)                        # unbounded tokens...
    assert len(alloc._tables[0]) == 3            # ...bounded pages (SWA)


def test_free_rejects_unknown_and_double_free():
    alloc = PageAllocator(16, 1, 4)
    alloc.admit(0, 8)
    with pytest.raises(KeyError):
        alloc.free(99)                           # never admitted
    assert alloc.free(0) == 2
    with pytest.raises(KeyError):
        alloc.free(0)                            # double free
    assert alloc.pages_in_use == 0               # guards left state intact
    with pytest.raises(ValueError):
        alloc.decref(0)                          # page already free


def test_ensure_is_shrink_safe():
    alloc = PageAllocator(16, 1, 4)
    alloc.admit(0, 12)                           # 3 pages
    before = list(alloc._tables[0])
    assert alloc.ensure(0, 4) == []              # fewer tokens: no-op
    assert alloc.ensure(0, 0) == []              # degenerate: no-op
    assert alloc.ensure(0, -5) == []
    assert alloc._tables[0] == before            # pages never released
    assert alloc.ensure(0, 13) != []             # growth still works
    assert alloc.pages_in_use == 4


def test_refcounted_sharing_and_release():
    """admit_shared borrows page references; a page only frees when its
    last owner (request or cache) lets go."""
    alloc = PageAllocator(16, 1, 4)
    pages = alloc.admit(0, 16)                   # 4 pages
    alloc.admit_shared(1, pages[:2], 12)         # borrow 2, allocate 1
    assert alloc.pages_of(1)[:2] == pages[:2]
    assert alloc.ref_of(pages[0]) == 2
    assert alloc.pages_in_use == 5               # shared pages counted once
    assert alloc.free(0) == 2                    # only its exclusive pages
    assert alloc.ref_of(pages[0]) == 1           # req 1 still owns the share
    assert alloc.free(1) == 3
    assert alloc.pages_in_use == 0


def test_grow_consults_reclaimer_on_exhaustion():
    class Reclaimer:
        def __init__(self, alloc):
            self.alloc = alloc
            self.hoard: list[int] = []
            self.calls = 0

        def reclaimable(self):
            return len(self.hoard)

        def reclaim(self, n):
            self.calls += 1
            freed = 0
            while self.hoard and freed < n:
                self.alloc.decref(self.hoard.pop())
                freed += 1
            return freed

    alloc = PageAllocator(8, 1, 4)
    rec = Reclaimer(alloc)
    alloc.reclaimer = rec
    pages = alloc.admit(0, 32)                   # whole pool
    rec.hoard = [p for p in pages[4:]]
    for p in rec.hoard:
        alloc.incref(p)
    alloc.free(0)                                # 4 free, 4 hoarded
    assert alloc.free_page_count == 4
    assert alloc.available_pages() == 8          # hoard counts as capacity
    assert alloc.can_admit(32)
    got = alloc.admit(1, 32)                     # needs all 8: forces reclaim
    assert len(got) == 8 and rec.calls >= 1
    assert rec.reclaimable() == 0
    alloc.free(1)
    assert alloc.pages_in_use == 0
