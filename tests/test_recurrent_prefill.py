"""State-carrying chunked/batched prefill for recurrent hybrids (xlstm,
zamba2) and enc-dec stacks: token-identity vs the per-slot recompute path
across chunk sizes, fused horizons and preemption/resume, plus the
recurrent-row hygiene regressions (reset on slot refill, no decode
advance for mid-prefill rows)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as MDL
from repro.models import ssm as SSM
from repro.serving import DecodeEngine, EngineConfig
from repro.serving import Request as Req

PAGE = 4
_SHARED = {}


def tiny(name):
    layers = 19 if name.startswith("zamba") else None
    return replace(reduced(get_config(name), layers=layers), dtype="float32")


def _setup(name):
    if name not in _SHARED:
        cfg = tiny(name)
        _SHARED[name] = (cfg, MDL.init_params(cfg, jax.random.PRNGKey(0),
                                              jnp.float32))
    return _SHARED[name]


def _run(name, mode, *, chunk=5, horizon=1, n_pages=96, nreq=4, budget=5,
         state_resume=True, submit=None):
    cfg, params = _setup(name)
    ecfg = EngineConfig(n_slots=2, page_size=PAGE, n_pages=n_pages,
                        max_context=64, eos_token=-1, prefill_mode=mode,
                        prefill_chunk=chunk, decode_horizon=horizon,
                        state_resume=state_resume)
    eng = DecodeEngine(cfg, ecfg, params)
    if submit is None:
        rng = np.random.default_rng(0)
        for r in range(nreq):
            eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                       size=int(rng.integers(3, 18))), budget))
    else:
        submit(eng)
    outs = eng.run(3000)
    return {k: list(v) for k, v in outs.items()}, eng


# ---------------------------------------------------------------------------
# masked recurrent forwards: the bucketing primitive
# ---------------------------------------------------------------------------

def test_masked_forwards_match_unpadded_state():
    """Pad positions must be identity steps: the state returned for a
    padded+masked batch equals the state of the unpadded run, per row."""
    zc, _ = _setup("zamba2-1.2b")
    xc, _ = _setup("xlstm-350m")
    B, T, pad = 2, 6, 5
    vl = jnp.asarray([4, 6])
    mask = jnp.arange(T + pad)[None] < vl[:, None]
    key = jax.random.PRNGKey(0)

    p = SSM.init_mamba(key, zc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, zc.d_model)) * 0.5
    xp = jnp.concatenate([x, jnp.zeros((B, pad, zc.d_model))], 1)
    _, st = SSM.mamba_forward(p, zc, xp, state=SSM.mamba_init_state(zc, B),
                              chunk=128, mask=mask)
    for b, n in enumerate([4, 6]):
        _, ref = SSM.mamba_forward(p, zc, x[b:b + 1, :n],
                                   state=SSM.mamba_init_state(zc, 1),
                                   chunk=128)
        for a, r in zip(jax.tree.leaves(st), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a[b]), np.asarray(r[0]),
                                       atol=1e-5)

    pm = SSM.init_mlstm(key, xc, jnp.float32)
    ps = SSM.init_slstm(key, xc, jnp.float32)
    x2 = jax.random.normal(jax.random.PRNGKey(2), (B, T, xc.d_model)) * 0.5
    x2p = jnp.concatenate([x2, jnp.zeros((B, pad, xc.d_model))], 1)
    _, stm = SSM.mlstm_forward(pm, xc, x2p, state=SSM.mlstm_init_state(xc, B),
                               chunk=128, mask=mask)
    _, sts = SSM.slstm_forward(ps, xc, x2p, mask=mask)
    for b, n in enumerate([4, 6]):
        _, rm = SSM.mlstm_forward(pm, xc, x2[b:b + 1, :n],
                                  state=SSM.mlstm_init_state(xc, 1),
                                  chunk=128)
        _, rs = SSM.slstm_forward(ps, xc, x2[b:b + 1, :n])
        for a, r in zip(jax.tree.leaves(stm), jax.tree.leaves(rm)):
            np.testing.assert_allclose(np.asarray(a[b]), np.asarray(r[0]),
                                       atol=1e-5)
        for a, r in zip(jax.tree.leaves(sts), jax.tree.leaves(rs)):
            np.testing.assert_allclose(np.asarray(a[b]), np.asarray(r[0]),
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# engine-level token identity: batched / chunked vs per-slot recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["xlstm-350m", "zamba2-1.2b",
                                  "whisper-small"])
def test_batched_and_chunked_match_slot(arch):
    """Every prefill mode emits token-identical greedy outputs on recurrent
    and enc-dec families, across chunk sizes and fused horizons (paused and
    mid-prefill rows must not advance their carry)."""
    base, eng_s = _run(arch, "slot")
    assert eng_s.prefiller.name == "slot"
    assert eng_s.batcher.stats.completed == 4

    got, eng_b = _run(arch, "batched")
    assert eng_b.prefiller.name == "batched"
    assert got == base

    for chunk in (3, 5, 8):
        got, eng_c = _run(arch, "chunked", chunk=chunk)
        assert eng_c.prefiller.name == "chunked"
        assert got == base, chunk
        assert eng_c.alloc.pages_in_use == 0

    # fused horizons: decode interleaves with streaming chunks
    for mode in ("batched", "chunked"):
        got, eng_h = _run(arch, mode, horizon=4)
        assert got == base, mode
        assert eng_h.batcher.stats.completed == 4


def test_chunked_prefill_interleaves_with_recurrent_decode():
    """While a long prompt chunk-prefills, an already-running request keeps
    decoding — and its trajectory is untouched by the mid-prefill rows
    (the decode run-mask guards their carry)."""
    def submit(eng):
        eng.submit(Req(0, [3, 5, 7], 10))            # short: decodes early
        eng.submit(Req(1, list(range(1, 20)), 4))    # long: several chunk ticks

    got_c, eng_c = _run("xlstm-350m", "chunked", chunk=4, submit=submit)
    got_s, _ = _run("xlstm-350m", "slot", submit=submit)
    assert got_c == got_s
    assert any(b == 1 for b in eng_c.batcher.stats.batch_trace[:6])


# ---------------------------------------------------------------------------
# preemption: snapshot the carry, resume = restore-not-recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["xlstm-350m", "zamba2-1.2b"])
def test_preemption_resume_restores_carry(arch):
    """Pool-exhaustion preemption under batched/chunked prefill resumes
    from the host snapshot of the recurrent carry (and written KV pages for
    hybrids) token-identically — and actually restores instead of
    recomputing. state_resume=False keeps the recompute path, also
    token-identical."""
    kw = dict(nreq=2, budget=12)
    ample, _ = _run(arch, "batched", n_pages=96, **kw)
    for mode in ("batched", "chunked"):
        tight, eng = _run(arch, mode, n_pages=9, **kw)
        assert eng.batcher.stats.preempted > 0, mode
        assert eng.rstate_snapshots > 0, mode
        assert eng.rstate_restores > 0, mode
        assert eng.batcher.stats.completed == 2, mode
        assert tight == ample, mode
        assert eng.alloc.pages_in_use == 0
        assert not eng.rsnaps          # snapshots consumed or dropped
    # recompute fallback: same trajectory without any restore
    tight, eng = _run(arch, "batched", n_pages=9, state_resume=False, **kw)
    assert eng.batcher.stats.preempted > 0
    assert eng.rstate_restores == 0
    assert tight == ample
    # the seed recompute reference (slot) agrees too
    tight, eng = _run(arch, "slot", n_pages=9, **kw)
    assert eng.rstate_restores == 0
    assert tight == ample


def test_finish_line_preemption_with_no_emitted_token_recomputes():
    """Pool exhaustion exactly when the last prefill chunk completes
    (mark_prefill_done's growth page fails) preempts a request that never
    sampled a token. No snapshot may be stored for it — a pure restore
    could never produce the first token (no logits without a model call) —
    so resume recomputes; outputs still match an ample pool."""
    cfg, params = _setup("xlstm-350m")

    def run(n_pages):
        ecfg = EngineConfig(n_slots=2, page_size=PAGE, n_pages=n_pages,
                            max_context=64, eos_token=-1,
                            prefill_mode="chunked", prefill_chunk=4)
        eng = DecodeEngine(cfg, ecfg, params)
        for r in range(4):
            eng.submit(Req(r, np.arange(1 + r, 13 + r, dtype=np.int32), 5))
        outs = eng.run(3000)
        return {k: list(v) for k, v in outs.items()}, eng

    ample, _ = run(96)
    for pages in (6, 7):
        tight, eng = run(pages)
        assert eng.batcher.stats.preempted > 0, pages
        assert eng.batcher.stats.completed == 4, pages
        assert tight == ample, pages
        assert eng.alloc.pages_in_use == 0


def test_restore_covers_whole_context_without_model_call():
    """The common decode-preemption case: the snapshot depth equals the
    reconstructable context, so resume is a pure restore (no prefill
    compute) — detectable as zero prefill growth in jitted suffix calls."""
    _, eng = _run("xlstm-350m", "batched", n_pages=9, nreq=2, budget=12)
    assert eng.rstate_restores == eng.rstate_snapshots > 0


# ---------------------------------------------------------------------------
# recurrent-row hygiene (the DeviceSlotState dirty-patch regression)
# ---------------------------------------------------------------------------

def test_recurrent_rows_reset_on_slot_refill():
    """A freed slot's recurrent rows hold the dead request's carry; the
    next admission into that slot must start from zeros. Run two requests
    through ONE slot sequentially and compare the second request's output
    with a fresh engine — stale rows would corrupt it."""
    cfg, params = _setup("xlstm-350m")
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab_size, size=9)
    p1 = rng.integers(0, cfg.vocab_size, size=11)

    def eng_with(prompts):
        ecfg = EngineConfig(n_slots=1, page_size=PAGE, n_pages=64,
                            max_context=64, eos_token=-1,
                            prefill_mode="batched", decode_horizon=4)
        eng = DecodeEngine(cfg, ecfg, params)
        for r, p in enumerate(prompts):
            eng.submit(Req(r, p, 6))
        eng.run(2000)
        return eng

    both = eng_with([p0, p1])
    solo = eng_with([p1])
    assert both.batcher.stats.completed == 2
    assert list(both.outputs[1]) == list(solo.outputs[0])
