"""Shared test fixtures. NOTE: no XLA_FLAGS here by design — unit/smoke
tests see 1 device; multi-device shard_map tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves (see test_distributed.py).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
