"""Checkpoint crash-safety + elastic planning + data determinism."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TrainPipeline, request_trace, LONGBENCH_STATS
from repro.runtime import checkpoint as CK
from repro.runtime.elastic import (MeshPlan, StragglerPolicy, plan_remesh,
                                   plan_request_migration)


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    t = tree()
    CK.save(tmp_path, 3, t)
    CK.save(tmp_path, 7, t)
    assert CK.latest_step(tmp_path) == 7
    step, restored = CK.restore_latest(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_crash_safety(tmp_path):
    """A step dir without a manifest (crash mid-save) must be ignored."""
    t = tree()
    CK.save(tmp_path, 1, t)
    # simulate a crash: shard written but no manifest
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    np.savez(bad / "shard_00000.npz", x=np.zeros(3))
    assert CK.latest_step(tmp_path) == 1
    step, _ = CK.restore_latest(tmp_path, t)
    assert step == 1


def test_checkpoint_gc_keeps_newest(tmp_path):
    t = tree()
    for s in range(6):
        CK.save(tmp_path, s, t, keep=2)
    kept = sorted(d.name for d in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1] == "step_00000005"


def test_data_pipeline_deterministic_and_sharded():
    p0 = TrainPipeline(1000, 8, 4, n_hosts=2, host_id=0)
    p1 = TrainPipeline(1000, 8, 4, n_hosts=2, host_id=1)
    b0a, b0b = p0.batch(5), p0.batch(5)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # resumable
    assert not np.array_equal(p0.batch(5)["tokens"], p1.batch(5)["tokens"])
    assert not np.array_equal(p0.batch(5)["tokens"], p0.batch(6)["tokens"])
    assert p0.batch(0)["tokens"].shape == (2, 8)


def test_request_trace_matches_table2_stats():
    for task, st in LONGBENCH_STATS.items():
        tr = request_trace(task, 2000, seed=1)
        lens = np.asarray([l for l, _ in tr])
        assert st["min"] <= lens.min() and lens.max() <= st["max"]
        assert abs(lens.mean() - st["mean"]) < 0.15 * st["mean"]


def test_plan_remesh_drops_rows_keeps_model_axis():
    cur = MeshPlan(pods=2, data=4, model=4)
    # kill one chip in pod0/row1 and all of pod1/row0
    failed = [1 * 4 + 2] + [(1 * 4 + 0) * 4 + m for m in range(4)]
    new = plan_remesh(cur, failed)
    assert new.model == 4                       # TP shards kept intact
    assert new.data == 3                        # worst surviving pod rows
    assert new.pods == 2


def test_plan_remesh_drops_dead_pod():
    cur = MeshPlan(pods=2, data=4, model=2)
    failed = [(1 * 4 + d) * 2 for d in range(3)]   # 3 of pod1's 4 rows die
    new = plan_remesh(cur, failed)
    assert new.pods == 1 and new.data == 4


def test_request_migration_and_stragglers():
    assert plan_request_migration({1: 0, 2: 3, 3: 3}, {3}) == [2, 3]
    pol = StragglerPolicy(n_rows=4)
    for _ in range(10):
        pol.observe(np.array([1.0, 1.0, 1.0, 2.4]))
    assert pol.stragglers() == [3]
    sh = pol.shares()
    assert sh[3] < 1.0 and (sh[:3] == 1.0).all()
