"""Scheduler properties: conservation, lazy>=static batch, preemption."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.allocator import PageAllocator
from repro.core.scheduler import ContinuousBatcher, Request

PAGE = 4


def drive(sched, slots, max_steps=50_000):
    finished = None
    for _ in range(max_steps):
        if sched.done():
            return True
        if finished is None:
            _, active = sched.step()
        else:
            _, active = sched.step(finished)
        finished = np.zeros(slots, bool)
        for s in active:
            r = sched.slots[s]
            if r is not None and r.generated >= r.max_new_tokens:
                finished[s] = True
    return False


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_all_requests_complete_and_pages_release(data):
    slots = data.draw(st.integers(1, 4))
    n_pages = data.draw(st.sampled_from([32, 64]))
    alloc = PageAllocator(n_pages, 1, PAGE)
    sched = ContinuousBatcher(alloc, slots, max_context=n_pages * PAGE)
    n_req = data.draw(st.integers(1, 10))
    for i in range(n_req):
        sched.submit(Request(i, data.draw(st.integers(1, 12)),
                             data.draw(st.integers(1, 8))))
    assert drive(sched, slots)
    assert sched.stats.completed == n_req
    assert alloc.pages_in_use == 0


def test_lazy_beats_static_avg_batch():
    """The paper's §5.4 claim on the real machinery."""
    def run(static):
        maxp = 16
        alloc = PageAllocator(64, 1, PAGE,
                              static_max_pages=maxp if static else None)
        sched = ContinuousBatcher(alloc, 16, max_context=maxp * PAGE)
        rng = np.random.default_rng(0)
        for i in range(24):
            sched.submit(Request(i, int(rng.integers(4, 20)), 8))
        assert drive(sched, 16)
        return sched.stats.avg_batch

    static, lazy = run(True), run(False)
    assert lazy > 1.5 * static, (static, lazy)


def test_preemption_keeps_system_live():
    """Pool sized so lazy growth must preempt; everything still completes."""
    alloc = PageAllocator(16, 1, PAGE)
    sched = ContinuousBatcher(alloc, 8, max_context=64)
    for i in range(8):
        sched.submit(Request(i, 6, 30))          # grows past the pool
    assert drive(sched, 8)
    assert sched.stats.completed == 8
    assert sched.stats.preempted > 0
    assert alloc.pages_in_use == 0
