"""Fault-tolerant serving (PR 8): request lifecycle hardening,
deterministic fault injection, graceful degradation, and crash-consistent
engine snapshots.

Every test drives the REAL engine (tiny llama / zamba, CPU, greedy) and
asserts the two robustness contracts:

* **terminal**: every submitted request either completes or lands in
  ``eng.aborted`` with a reason — nothing hangs or vanishes;
* **leak-free**: at drain the page allocator is empty and no per-request
  engine state (carry snapshots, draft-pool coverage, deadline tracking,
  pending aborts) dangles.

Plus the determinism contracts: an armed-but-silent injector changes
nothing (bit-identical outputs AND device-sync counts), a chaos run
replays exactly from its seed, and row-death / kill+restore runs are
token-identical to clean runs.
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as MDL
from repro.runtime.faults import (NULL_FAULTS, FaultConfig, FaultInjector,
                                  make_faults)
from repro.serving import DecodeEngine, EngineConfig
from repro.serving import Request as Req

PAGE = 4


def tiny(name="llama3.2-1b", **kw):
    return replace(reduced(get_config(name)), dtype="float32", **kw)


_PARAMS: dict = {}


def _params(name="llama3.2-1b"):
    if name not in _PARAMS:
        cfg = tiny(name)
        _PARAMS[name] = (cfg, MDL.init_params(cfg, jax.random.PRNGKey(0),
                                              jnp.float32))
    return _PARAMS[name]


def _engine(faults=None, arch="llama3.2-1b", draft=None, **kw):
    cfg, params = _params(arch)
    base = dict(n_slots=3, page_size=PAGE, n_pages=96, max_context=64,
                eos_token=-1)
    base.update(kw)
    dcfg, dparams = draft if draft is not None else (None, None)
    return DecodeEngine(cfg, EngineConfig(faults=faults, draft_config=dcfg,
                                          **base), params,
                        draft_params=dparams)


def _draft():
    dcfg = replace(reduced(get_config("llama3.2-1b"), layers=1),
                   dtype="float32")
    return dcfg, MDL.init_params(dcfg, jax.random.PRNGKey(7), jnp.float32)


def _submit(eng, n, max_new=5, seed=0):
    cfg, _ = _params()
    rng = np.random.default_rng(seed)
    for r in range(n):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                   size=int(rng.integers(3, 20))), max_new))


def _assert_leak_free(eng):
    assert eng.alloc.pages_in_use == (
        eng.cache.tree.device_pages() if eng.cache is not None else 0)
    assert not eng.rsnaps
    assert not eng.deadline_t
    assert not eng._abort_req


# ---------------------------------------------------------------------------
# fault injector unit behavior
# ---------------------------------------------------------------------------

def test_injector_deterministic_and_order_free():
    """fire() is a pure function of (seed, kind, tick, key) — replaying the
    same schedule in any call order yields identical decisions/events."""
    def drive(order):
        f = FaultInjector(FaultConfig(seed=42, client_abort_p=0.3,
                                      row_death_p=0.2))
        hits = {}
        for _ in range(20):
            f.on_tick()
            for kind, key in order:
                hits[(kind, f.tick, key)] = f.fire(kind, key=key)
        return hits, f.events
    a = [("client_abort", 1), ("client_abort", 2), ("row_death", 0)]
    h1, e1 = drive(a)
    h2, e2 = drive(list(reversed(a)))
    assert h1 == h2
    key = lambda d: (d["kind"], d["tick"], d["key"])  # noqa: E731
    assert sorted(e1, key=key) == sorted(e2, key=key)


def test_injector_max_faults_and_null():
    f = FaultInjector(FaultConfig(seed=0, slow_tick_p=1.0, max_faults=3))
    for _ in range(10):
        f.on_tick()
        f.fire("slow_tick")
    assert f.total_fired == 3
    assert make_faults(None) is NULL_FAULTS
    assert not NULL_FAULTS.enabled and not NULL_FAULTS.fire("slow_tick")


# ---------------------------------------------------------------------------
# identity: an armed-but-silent injector must change nothing
# ---------------------------------------------------------------------------

def test_zero_probability_faults_are_identity():
    ref = _engine()
    _submit(ref, 6)
    base = {k: list(v) for k, v in ref.run(500).items()}
    eng = _engine(FaultConfig(seed=1))        # armed, all probabilities 0
    _submit(eng, 6)
    outs = {k: list(v) for k, v in eng.run(500).items()}
    assert outs == base
    assert eng.timing.device_syncs == ref.timing.device_syncs
    assert eng.faults.total_fired == 0
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# lifecycle: abort / deadline / shed across prefill modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["slot", "batched", "chunked"])
def test_abort_and_deadline_all_prefill_modes(mode):
    eng = _engine(prefill_mode=mode, prefill_chunk=5)
    _submit(eng, 6, max_new=20)
    eng.submit(Req(9, np.arange(1, 10), 20, deadline_s=1e-6))   # expires at once
    for _ in range(2):
        eng.tick()
    assert eng.abort(0)                         # running or queued: live
    eng.run(500)
    assert eng.aborted.get(0) == "client"
    assert eng.aborted.get(9) == "deadline"
    assert eng.batcher.stats.completed + len(eng.aborted) == 7
    assert eng.outputs[9] == [] or len(eng.outputs[9]) < 20
    _assert_leak_free(eng)
    assert not eng.abort(0)                     # already terminal


def test_abort_with_horizon_and_deadline_survivors():
    """Multi-token decode horizons cross the abort safe point; survivors'
    deadlines are generous and must NOT fire."""
    clean = _engine(decode_horizon=4)
    _submit(clean, 5, max_new=8)
    ref = {k: list(v) for k, v in clean.run(500).items()}
    eng = _engine(decode_horizon=4, default_deadline_s=60.0)
    _submit(eng, 5, max_new=8)
    eng.tick()
    eng.abort(2)
    outs = {k: list(v) for k, v in eng.run(500).items()}
    assert eng.aborted == {2: "client"}
    assert all(outs[r] == ref[r] for r in range(5) if r != 2)
    assert len(outs[2]) < len(ref[2])           # actually cut short
    _assert_leak_free(eng)                      # incl. deadline_t drained


def test_load_shed_bounded_queue():
    eng = _engine(max_queue=2)
    cfg, _ = _params()
    rng = np.random.default_rng(0)
    oks = [eng.submit(Req(r, rng.integers(0, cfg.vocab_size, size=5), 3))
           for r in range(8)]
    assert sum(oks) == 2                        # admission happens at tick
    assert eng.abort_counts["shed"] == 6
    eng.run(500)
    assert eng.batcher.stats.completed == 2
    assert all(eng.aborted[r] == "shed" for r in range(8)
               if r not in (0, 1))
    _assert_leak_free(eng)


def test_abort_during_spec_decode_cleans_draft_pool():
    eng = _engine(draft=_draft(), spec_horizon=3)
    _submit(eng, 4, max_new=10)
    eng.tick()
    eng.abort(1)
    eng.run(500)
    assert eng.aborted == {1: "client"}
    assert 1 not in eng._dlen                   # draft coverage dropped
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# chaos: seeded storms are terminal, leak-free, and replayable
# ---------------------------------------------------------------------------

def _storm_cfg(seed=7):
    return FaultConfig(seed=seed, client_abort_p=0.02, row_death_p=0.01,
                       alloc_exhaust_p=0.05, nan_logits_p=0.01,
                       slow_tick_p=0.05, slow_tick_s=0.0)


def test_chaos_storm_terminal_leak_free_and_replayable():
    def once():
        eng = _engine(_storm_cfg(), n_rows=2, n_shards=2)
        _submit(eng, 6, max_new=8)
        outs = {k: list(v) for k, v in eng.run(2000).items()}
        assert eng.batcher.stats.completed + len(eng.aborted) == 6
        _assert_leak_free(eng)
        return outs, list(eng.faults.events), dict(eng.aborted)
    o1, e1, a1 = once()
    o2, e2, a2 = once()
    assert (o1, e1, a1) == (o2, e2, a2)         # seed fully replays the run


def test_nan_quarantine_and_degradation_ladder():
    eng = _engine(FaultConfig(seed=3, nan_logits_p=0.25), degrade_after=3)
    _submit(eng, 6, max_new=6)
    eng.run(2000)
    assert eng.abort_counts["nan"] >= 1
    assert all(r == "nan" for r in eng.aborted.values())
    assert eng.batcher.stats.completed + len(eng.aborted) == 6
    if eng.abort_counts["nan"] >= 3:
        assert eng.degraded_mode & 1            # horizon pinned to 1
    _assert_leak_free(eng)


def test_real_nan_guard_opt_in():
    """Out-of-range sampled ids only quarantine when the guard is armed
    (auto with injection, or explicitly): seed behavior is sample-as-is."""
    assert _engine().nan_guard is False
    assert _engine(FaultConfig(seed=1)).nan_guard is True
    assert _engine(nan_guard=True).nan_guard is True


def test_row_death_migrates_and_preserves_outputs():
    clean = _engine(n_rows=2, n_shards=2, n_slots=4)
    _submit(clean, 8, max_new=8)
    ref = {k: list(v) for k, v in clean.run(2000).items()}
    eng = _engine(FaultConfig(seed=3, row_death_p=0.1, max_faults=1),
                  n_rows=2, n_shards=2, n_slots=4)
    _submit(eng, 8, max_new=8)
    outs = {k: list(v) for k, v in eng.run(2000).items()}
    assert eng.faults.counts.get("row_death", 0) >= 1
    assert eng.batcher.stats.migrated >= 1      # victims re-queued, not lost
    assert outs == ref                          # greedy trajectory unchanged
    _assert_leak_free(eng)


def test_spec_degrades_to_plain_decode_under_pressure():
    draft = _draft()
    clean = _engine(draft=draft, spec_horizon=3, n_slots=4)
    _submit(clean, 6, max_new=8)
    ref = {k: list(v) for k, v in clean.run(2000).items()}
    eng = _engine(FaultConfig(seed=5, alloc_exhaust_p=0.15),
                  draft=draft, spec_horizon=3, n_slots=4, degrade_after=2)
    _submit(eng, 6, max_new=8)
    outs = {k: list(v) for k, v in eng.run(2000).items()}
    assert eng.degraded_mode & 2                # spec switched off
    assert outs == ref                          # greedy outputs unchanged
    _assert_leak_free(eng)


def test_swap_failure_drops_host_tier():
    eng = _engine(FaultConfig(seed=2, swap_fail_p=0.9), n_pages=32,
                  prefix_cache=True, host_pages=32, offload_high=0.4,
                  offload_low=0.2, degrade_after=2)
    cfg, _ = _params()
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, size=12)
    for r in range(8):
        eng.submit(Req(r, np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, size=5)]), 6))
    eng.run(2000)
    assert eng.batcher.stats.completed + len(eng.aborted) == 8
    if eng.degraded_mode & 4:
        assert eng.cache.host is None           # tier actually dropped
    _assert_leak_free(eng)


# ---------------------------------------------------------------------------
# crash-consistent snapshots
# ---------------------------------------------------------------------------

def test_snapshot_restore_token_identical(tmp_path):
    clean = _engine()
    _submit(clean, 6, max_new=8)
    ref = {k: list(v) for k, v in clean.run(500).items()}
    eng = _engine(snapshot_dir=str(tmp_path), snapshot_every=3)
    _submit(eng, 6, max_new=8)
    for _ in range(7):                          # crash mid-run
        eng.tick()
    assert eng.snapshot_saves >= 1
    eng2 = _engine(snapshot_dir=str(tmp_path))
    step = eng2.restore_snapshot()
    assert step is not None
    outs = {k: list(v) for k, v in eng2.run(500).items()}
    assert outs == ref
    assert eng2.snapshot_restores == 1
    _assert_leak_free(eng2)


@pytest.mark.slow
def test_snapshot_restore_recurrent_carries(tmp_path):
    """Warm restore of a recurrent hybrid re-seats the saved SSM carries
    (no re-prefill model call) and still matches the uninterrupted run."""
    cfg, params = _params("zamba2-1.2b")
    def eng_for(**kw):
        return DecodeEngine(cfg, EngineConfig(
            n_slots=3, page_size=PAGE, n_pages=96, max_context=64,
            eos_token=-1, **kw), params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 16))) for _ in range(4)]
    clean = eng_for()
    for r, p in enumerate(prompts):
        clean.submit(Req(r, p, 8))
    ref = {k: list(v) for k, v in clean.run(500).items()}
    eng = eng_for(snapshot_dir=str(tmp_path), snapshot_every=4)
    for r, p in enumerate(prompts):
        eng.submit(Req(r, p, 8))
    for _ in range(5):
        eng.tick()
    eng2 = eng_for(snapshot_dir=str(tmp_path))
    assert eng2.restore_snapshot(step=4) == 4
    outs = {k: list(v) for k, v in eng2.run(500).items()}
    assert eng2.rstate_restores >= 1            # warm carries re-seated
    assert outs == ref
    _assert_leak_free(eng2)


def test_metrics_server_clean_shutdown():
    """Satellite: close() must observably succeed (True) — a leaked daemon
    thread returns False + a warning instead of being swallowed — and the
    scrape timeout is configurable per server and per call."""
    from repro.telemetry.prom import MetricsServer
    from repro.telemetry.registry import MetricsRegistry
    reg = MetricsRegistry()
    reg.counter("up", "help").inc()
    srv = MetricsServer(reg, port=0, scrape_timeout=2.0)
    assert srv.scrape_timeout == 2.0
    assert "up" in srv.scrape(timeout=5.0)
    assert srv.close() is True                  # thread really exited
    assert srv.close(join_timeout=0.1) is True  # idempotent once dead


def test_snapshot_restore_empty_dir(tmp_path):
    eng = _engine(snapshot_dir=str(tmp_path))
    assert eng.restore_snapshot() is None       # nothing to restore: no-op
    _submit(eng, 2)
    eng.run(500)
    assert eng.batcher.stats.completed == 2


# ---------------------------------------------------------------------------
# torn / corrupted snapshots: reject cleanly, never half-apply
# ---------------------------------------------------------------------------

def _snap_run(tmp_path, every=2, ticks=7):
    """A mid-run crash leaving >= 2 snapshot steps behind, plus the clean
    reference outputs the restore must reproduce."""
    clean = _engine()
    _submit(clean, 6, max_new=8)
    ref = {k: list(v) for k, v in clean.run(500).items()}
    eng = _engine(snapshot_dir=str(tmp_path), snapshot_every=every)
    _submit(eng, 6, max_new=8)
    for _ in range(ticks):
        eng.tick()
    steps = sorted(int(d.name.split("_")[1])
                   for d in tmp_path.glob("step_*"))
    assert len(steps) >= 2
    return ref, steps


def test_snapshot_restore_rejects_torn_manifest(tmp_path):
    """Truncating the newest manifest mid-file un-commits that step (the
    manifest IS the commit point): restore skips it without touching the
    payload, falls back to the previous intact step, and still finishes
    every request token-identically."""
    ref, steps = _snap_run(tmp_path)
    mf = tmp_path / f"step_{steps[-1]:08d}" / "manifest.json"
    text = mf.read_text()
    mf.write_text(text[:len(text) // 2])        # torn mid-write
    eng2 = _engine(snapshot_dir=str(tmp_path))
    assert eng2.restore_snapshot() == steps[-2]  # fell back, no half-apply
    outs = {k: list(v) for k, v in eng2.run(500).items()}
    assert outs == ref
    _assert_leak_free(eng2)


def test_snapshot_restore_rejects_corrupt_payload(tmp_path):
    """A bit flip in a committed step's KV payload fails the manifest's
    per-array crc32: the step is rejected (counted in snapshot_rejects)
    BEFORE anything is applied and restore degrades to the older step."""
    ref, steps = _snap_run(tmp_path)
    shard = tmp_path / f"step_{steps[-1]:08d}" / "shard_00000.npz"
    blob = bytearray(shard.read_bytes())
    blob[len(blob) // 2] ^= 0x40                # flip one payload bit
    shard.write_bytes(bytes(blob))
    eng2 = _engine(snapshot_dir=str(tmp_path))
    assert eng2.restore_snapshot() == steps[-2]
    assert eng2.snapshot_rejects == 1
    outs = {k: list(v) for k, v in eng2.run(500).items()}
    assert outs == ref
    _assert_leak_free(eng2)


def test_snapshot_restore_all_corrupt_falls_back_cold(tmp_path):
    """Every step damaged -> restore returns None (nothing half-applied,
    every reject counted); a cold re-submit then reproduces the reference
    run exactly — the deterministic re-prefill fallback."""
    ref, steps = _snap_run(tmp_path)
    for st in steps:
        shard = tmp_path / f"step_{st:08d}" / "shard_00000.npz"
        blob = bytearray(shard.read_bytes())
        blob[len(blob) // 2] ^= 0x40
        shard.write_bytes(bytes(blob))
    eng2 = _engine(snapshot_dir=str(tmp_path))
    assert eng2.restore_snapshot() is None
    assert eng2.snapshot_rejects == len(steps)
    assert not eng2.prompts                     # truly nothing applied
    _submit(eng2, 6, max_new=8)                 # cold re-prefill fallback
    outs = {k: list(v) for k, v in eng2.run(500).items()}
    assert outs == ref
    _assert_leak_free(eng2)


# ---------------------------------------------------------------------------
# swap-failure retry/backoff (before the degradation ladder)
# ---------------------------------------------------------------------------

def test_swap_retry_backoff_before_degradation():
    """The first swap_retry_limit consecutive swap-in failures are absorbed
    as retries (TierStats.swap_retries) behind a capped exponential backoff;
    only failures past the budget advance swap_in_fails toward the
    degrade_after ladder — and the tier's counters stay visible even after
    the ladder drops it."""
    eng = _engine(FaultConfig(seed=2, swap_fail_p=0.9), n_pages=32,
                  prefix_cache=True, host_pages=32, offload_high=0.4,
                  offload_low=0.2, degrade_after=2, swap_retry_limit=2,
                  swap_backoff_cap=4)
    cfg, _ = _params()
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, size=12)
    for r in range(8):
        eng.submit(Req(r, np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, size=5)]), 6))
    eng.run(2000)
    assert eng.batcher.stats.completed + len(eng.aborted) == 8
    sd = eng.cache.stats_dict()
    fired = eng.faults.counts.get("swap_fail", 0)
    if fired:
        # the first failure of any streak is always absorbed as a retry
        assert sd["swap_retries"] >= 1
        # every failure landed somewhere: retry budget or the ladder
        assert sd["swap_retries"] + sd["swap_in_fails"] >= fired
    if eng.degraded_mode & 4:
        assert eng.cache.host is None
        assert "swap_retries" in sd             # stats survive the drop
    _assert_leak_free(eng)
