"""Fused multi-step decode: horizon invariance, mid-horizon EOS/budget,
preemption between horizons, prefix sharing, and the ~K-fold host-sync
reduction (the perf contract of the DCS-style pipelined tick)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.allocator import PageAllocator
from repro.core.scheduler import ContinuousBatcher, Request
from repro.models import model as MDL
from repro.serving import DecodeEngine, EngineConfig, make_scan_sampler
from repro.serving import Request as Req

PAGE = 4
_SHARED = {}


def _setup():
    if "cfg" not in _SHARED:
        cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
        _SHARED["cfg"] = cfg
        _SHARED["params"] = MDL.init_params(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    return _SHARED["cfg"], _SHARED["params"]


BUDGETS = [3, 12, 5, 12, 2, 9]      # none a multiple of 4 or 8 -> budgets
                                    # exhaust MID-horizon at K=4/8


def _run(K, mode="batched", *, n_pages=96, cache=False, eos=-1,
         budgets=BUDGETS, nreq=6, sampler="greedy", seed=0, shared=0):
    cfg, params = _setup()
    ecfg = EngineConfig(n_slots=3, page_size=PAGE, n_pages=n_pages,
                        max_context=64, eos_token=eos, prefill_mode=mode,
                        prefill_chunk=5, decode_horizon=K,
                        prefix_cache=cache, host_pages=16 if cache else 0,
                        sampler=sampler, sample_seed=seed,
                        temperature=0.8)
    eng = DecodeEngine(cfg, ecfg, params)
    rng = np.random.default_rng(3)
    sys_prompt = np.arange(2000, 2000 + shared, dtype=np.int32)
    for r in range(nreq):
        p = rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 20)))
        if shared:
            p = np.concatenate([sys_prompt, p[:4]]).astype(np.int32)
        eng.submit(Req(r, p, budgets[r % len(budgets)]))
    outs = eng.run(3000)
    return {k: list(v) for k, v in outs.items()}, eng


def test_horizon_token_identity_and_sync_reduction():
    """Greedy outputs are identical for decode_horizon 1 / 4 / 8 in every
    prefill mode (budgets exhaust mid-horizon by construction), and the
    host<->device sync count drops ~K-fold."""
    base, e1 = _run(1)
    assert e1.batcher.stats.completed == 6
    for K, mode in ((4, "slot"), (4, "chunked"), (8, "batched"),
                    (8, "chunked")):
        got, eng = _run(K, mode)
        assert got == base, (K, mode)
        assert eng.batcher.stats.completed == 6
        assert eng.alloc.pages_in_use == 0
    _, e8 = _run(8)
    # same decode tokens, ~8x fewer rendezvous (ragged tail gives slack)
    t1, t8 = e1.timing, e8.timing
    assert t8.decode_tokens == t1.decode_tokens
    assert t8.device_syncs * 4 <= t1.device_syncs
    assert t8.device_syncs >= 1


def test_eos_mid_horizon_freezes_slot():
    """A slot sampling EOS mid-horizon freezes (writes drop, no further
    emissions) and the tail of the horizon leaves other slots' trajectories
    untouched — outputs identical to per-token EOS handling."""
    probe, _ = _run(1)
    eos = probe[1][2]                 # a token the model actually emits
    base, e1 = _run(1, eos=eos)
    assert any(len(v) < len(probe[k]) for k, v in base.items()), \
        "EOS never fired; probe token not re-emitted"
    for K in (4, 8):
        got, eng = _run(K, eos=eos)
        assert got == base, K
        assert eng.batcher.stats.completed == 6
        assert eng.alloc.pages_in_use == 0


def test_preemption_between_horizons():
    """Pool exhaustion under speculative horizon reservation: the slot's
    allowance degrades mid-horizon (pause, not preempt) and scheduler-level
    preemption at the tick boundary stays token-identical."""
    base, e1 = _run(1, n_pages=10, nreq=2, budgets=[12, 12])
    assert e1.batcher.stats.preempted > 0
    for K in (4, 8):
        got, eng = _run(K, n_pages=10, nreq=2, budgets=[12, 12])
        assert eng.batcher.stats.completed == 2
        assert got == base, K
        assert eng.alloc.pages_in_use == 0


def test_prefix_sharing_across_horizons():
    """Radix prefix sharing (borrowed pages, suffix prefill, the overlap
    window's peek prefetch) composes with the fused path: outputs identical
    and hits actually happen."""
    base, _ = _run(1, cache=True, shared=38)
    got, eng = _run(8, cache=True, shared=38)
    assert got == base
    assert eng.cache.stats.hits > 0
    assert eng.batcher.stats.completed == 6


def test_stochastic_fused_deterministic_in_seed():
    """Temperature sampling inside the fused scan is deterministic in
    (seed, horizon): same seed reproduces the stream, different seed
    diverges. (Horizon changes the key-split order, so streams are only
    pinned per-K — greedy is the horizon-invariant mode.)"""
    a, _ = _run(8, sampler="temperature", seed=7, nreq=3)
    b, _ = _run(8, sampler="temperature", seed=7, nreq=3)
    c, _ = _run(8, sampler="temperature", seed=8, nreq=3)
    assert a == b
    assert a != c


def test_scan_sampler_matches_eager():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(5, 33)),
                         jnp.float32)
    key = jax.random.PRNGKey(0)
    g = make_scan_sampler("greedy")(key, logits)
    assert (np.asarray(g) == np.argmax(np.asarray(logits), -1)).all()
    peaked = np.zeros((1, 100), np.float32)
    peaked[0, [3, 41, 77]] = 10.0
    fn = make_scan_sampler("top_k", top_k=3)
    for i in range(20):
        tok = int(fn(jax.random.PRNGKey(i), jnp.asarray(peaked))[0])
        assert tok in (3, 41, 77)


def test_reserve_horizon_degrades_instead_of_preempting():
    """Page reservation for a horizon is best-effort: when the pool cannot
    cover K tokens ahead, the slot's allowance shrinks to what its pages
    cover (>= 1) and nothing is preempted by the reservation itself."""
    alloc = PageAllocator(8, 1, PAGE)
    sched = ContinuousBatcher(alloc, 2, max_context=256, bt_width=8)
    sched.submit(Request(0, prompt_len=12, max_new_tokens=40))
    sched.submit(Request(1, prompt_len=12, max_new_tokens=40))
    _, active = sched.step()
    assert active == [0, 1]
    before = sched.stats.preempted
    allow = sched.reserve_horizon(active, 16)
    assert sched.stats.preempted == before
    assert all(1 <= allow[s] <= 16 for s in active)
    # 8 pages, 2 requests x 13 tokens -> 4 pages each, zero slack: the
    # allowance must reflect covered tokens, not the requested horizon
    assert any(allow[s] < 16 for s in active)
    # ample pool: full horizon, clamped by remaining budget
    alloc2 = PageAllocator(64, 1, PAGE)
    sched2 = ContinuousBatcher(alloc2, 1, max_context=256, bt_width=20)
    sched2.submit(Request(0, prompt_len=4, max_new_tokens=5))
    _, active2 = sched2.step()
    allow2 = sched2.reserve_horizon(active2, 16)
    assert allow2[0] == 5              # max_new - generated + 1

    # dirty-set: reservation growth marks rows for the device mirror
    assert 0 in sched.dirty or 0 in sched2.dirty


def test_legacy_sampler_callable_rides_the_fused_path():
    """Engines built with the seed per-row ``sample=`` callable no longer
    pin run() to per-token decode: the callback adapter threads the host
    callable through the fused scan, outputs match the jitted greedy
    sampler, and host syncs still drop ~K-fold."""
    cfg, params = _setup()

    def make(sample, K):
        ecfg = EngineConfig(n_slots=3, page_size=PAGE, n_pages=96,
                            max_context=64, eos_token=-1, decode_horizon=K)
        eng = DecodeEngine(cfg, ecfg, params, sample=sample)
        rng = np.random.default_rng(3)
        for r in range(6):
            eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                       size=int(rng.integers(3, 20))),
                       BUDGETS[r]))
        eng.run(3000)
        return eng

    base, _ = _run(8)                  # jitted greedy sampler reference
    legacy = make(lambda row: int(np.argmax(row)), 8)
    assert {k: list(v) for k, v in legacy.outputs.items()} == base
    assert legacy.batcher.stats.completed == 6
    legacy1 = make(lambda row: int(np.argmax(row)), 1)
    t8, t1 = legacy.timing, legacy1.timing
    assert t8.decode_tokens == t1.decode_tokens
    assert t8.device_syncs * 4 <= t1.device_syncs

    # stateful callable: the adapter invokes it for RUNNING rows only (in
    # slot order), so its state stream matches the per-token step() loop's
    # active-rows-only pattern exactly — all-rows invocation would consume
    # extra state on idle rows and diverge
    def make_stateful():
        n = [0]

        def s(row):
            n[0] += 1
            return int(np.argsort(row)[-1 - (n[0] % 3)])
        return s

    fused = make(make_stateful(), 1)   # run() at K=1: same event order
    ecfg = EngineConfig(n_slots=3, page_size=PAGE, n_pages=96,
                        max_context=64, eos_token=-1, decode_horizon=1)
    eng = DecodeEngine(cfg, ecfg, params, sample=make_stateful())
    rng = np.random.default_rng(3)
    for r in range(6):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                   size=int(rng.integers(3, 20))),
                   BUDGETS[r]))
    fin = None
    for _ in range(3000):
        if eng.batcher.done():
            break
        fin = eng.step(fin)
    assert {k: list(v) for k, v in fused.outputs.items()} == \
        {k: list(v) for k, v in eng.outputs.items()}
    assert fused.batcher.stats.completed == 6


def test_mixed_step_and_run_apis_stay_identical():
    """The public per-token step() interleaves with the fused run():
    step() advances host state only, so it must dirty its rows for the
    device mirror and hand its finished mask to the next run()."""
    cfg, params = _setup()

    def make():
        # page_size 64: several ticks with no page growth, so nothing
        # re-dirties rows accidentally
        ecfg = EngineConfig(n_slots=2, page_size=64, n_pages=8,
                            max_context=128, eos_token=-1, decode_horizon=4)
        eng = DecodeEngine(cfg, ecfg, params)
        eng.submit(Req(0, [3, 5, 7, 9], 12))
        eng.submit(Req(1, [2, 4, 6], 12))
        return eng

    pure = make()
    pure.run(1000)
    mixed = make()
    mixed.run(3)                       # fused ticks
    fin = mixed.step()                 # per-token ticks in between
    mixed.step(fin)                    # result mask intentionally dropped
    mixed.run(1000)                    # fused again
    assert {k: list(v) for k, v in mixed.outputs.items()} == \
        {k: list(v) for k, v in pure.outputs.items()}
    assert mixed.batcher.stats.completed == 2
    assert mixed.alloc.pages_in_use == 0
