"""End-to-end behaviour: serving engine + training loop on tiny models."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.engine import DecodeEngine, EngineConfig
from repro.data.pipeline import TrainPipeline
from repro.models import model as MDL
from repro.training import optimizer as OPT
from repro.training.train import make_train_step
from repro.serving import Request as Req


def tiny(name="llama3.2-1b", **kw):
    return replace(reduced(get_config(name)), dtype="float32", **kw)


def test_engine_continuous_batching_matches_reference():
    cfg = tiny()
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    ecfg = EngineConfig(n_slots=3, page_size=4, n_pages=64, max_context=40,
                        eos_token=-1)
    eng = DecodeEngine(cfg, ecfg, params)
    rng = np.random.default_rng(0)
    for r in range(5):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 9))),
                   max_new_tokens=5))
    outs = eng.run(200)
    assert eng.batcher.stats.completed == 5
    assert eng.alloc.pages_in_use == 0            # all pages released (DPA)

    def greedy_ref(prompt, n):
        toks = list(prompt)
        for _ in range(n):
            lg, _ = MDL.forward(cfg, params, jnp.asarray(np.asarray(toks)[None]))
            toks.append(int(np.argmax(np.asarray(lg)[0, -1])))
        return toks[len(prompt):]

    for r in range(3):
        assert outs[r] == greedy_ref(eng.prompts[r], len(outs[r])), r


def test_engine_slot_reuse_increases_throughput():
    """EOS replacement (paper Fig 2b): more requests than slots complete."""
    cfg = tiny()
    ecfg = EngineConfig(n_slots=2, page_size=4, n_pages=32, max_context=24,
                        eos_token=-1)
    eng = DecodeEngine(cfg, ecfg)
    for r in range(6):
        eng.submit(Req(r, [3, 5, 7], max_new_tokens=3))
    eng.run(300)
    assert eng.batcher.stats.completed == 6
    assert eng.batcher.stats.admitted == 6


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "whisper-small"])
def test_engine_handles_recurrent_and_encdec(arch):
    """The serving engine must also run hybrid (paged KV + recurrent state)
    and encoder-decoder archs end to end."""
    cfg = tiny(arch)
    ecfg = EngineConfig(n_slots=2, page_size=4, n_pages=32, max_context=24,
                        eos_token=-1)
    eng = DecodeEngine(cfg, ecfg)
    for r in range(3):
        eng.submit(Req(r, [2, 4, 6, 8], max_new_tokens=3))
    outs = eng.run(200)
    assert eng.batcher.stats.completed == 3
    assert all(len(v) >= 3 for v in outs.values())
    assert eng.alloc.pages_in_use == 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-350m"])
def test_train_loss_decreases(arch):
    cfg = tiny(arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    opt_cfg = OPT.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    step = jax.jit(make_train_step(cfg, MDL.DEFAULT_RT, opt_cfg))
    opt = OPT.init(params)
    pipe = TrainPipeline(cfg.vocab_size, seq_len=16, global_batch=4)
    losses = []
    for i in range(15):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i % 3).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_microbatch_accumulation_matches_full_batch():
    cfg = tiny()
    params = MDL.init_params(cfg, jax.random.PRNGKey(2), jnp.float32)
    opt_cfg = OPT.AdamWConfig(lr=1e-3, clip_norm=1e9, weight_decay=0.0)
    pipe = TrainPipeline(cfg.vocab_size, seq_len=8, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    outs = []
    for mb in (1, 2):
        step = jax.jit(make_train_step(cfg, MDL.DEFAULT_RT, opt_cfg,
                                       microbatches=mb))
        p2, _, m = step(params, OPT.init(params), batch)
        outs.append(p2)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])))
    assert d < 5e-5, d
