"""Minimal example-based stand-in for ``hypothesis`` when it isn't installed.

Covers exactly the surface this suite uses — ``@settings(max_examples=...,
deadline=...)``, ``@given(st.data())`` / ``@given(k=strategy, ...)`` and the
``data``, ``integers``, ``sampled_from``, ``lists``, ``booleans``
strategies. Each property runs ``max_examples`` times against a
deterministic per-example seeded ``random.Random`` (seed derived from the
test name), so failures reproduce. No shrinking, no database — install
hypothesis for the real thing; test modules import this as a fallback only.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib


class _Strategy:
    def __init__(self, sample):
        self._sample = sample


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int | None = None) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 10
    return _Strategy(lambda rng: [elements._sample(rng)
                                  for _ in range(rng.randint(min_size, hi))])


class _Data:
    """Interactive draws, mirroring ``st.data()``'s DataObject."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy._sample(self._rng)


def data() -> _Strategy:
    return _Strategy(None)          # sentinel; given() builds the _Data


strategies = types.SimpleNamespace(
    data=data, integers=integers, sampled_from=sampled_from, lists=lists,
    booleans=booleans)


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    interactive = bool(arg_strategies)      # the @given(st.data()) form

    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            # @settings sits ABOVE @given, so it tags this wrapper;
            # read at call time.
            n = getattr(runner, "_max_examples", 20)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random(base + 1_000_003 * i)
                if interactive:
                    fn(_Data(rng), *args, **kwargs)
                else:
                    drawn = {k: s._sample(rng)
                             for k, s in kw_strategies.items()}
                    fn(*args, **drawn, **kwargs)
        # pytest must not mistake the property's arguments for fixtures:
        # hide the wrapped function and present a zero-arg signature
        del runner.__wrapped__
        runner.__signature__ = inspect.Signature()
        return runner
    return deco
