"""Serving-package behaviour: prefill-mode equivalence, policies, samplers,
and the vectorized host-bookkeeping snapshots."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.allocator import PageAllocator
from repro.core.scheduler import ContinuousBatcher, Request
from repro.models import model as MDL
from repro.serving import (DecodeEngine, EngineConfig, FCFSPolicy,
                           MemoryAwarePolicy, SJFPolicy, make_sampler)
from repro.serving import Request as Req

PAGE = 4


def tiny(name="llama3.2-1b", **kw):
    return replace(reduced(get_config(name)), dtype="float32", **kw)


# ---------------------------------------------------------------------------
# prefill-mode equivalence (acceptance: batched/chunked == per-slot greedy)
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, mode, *, chunk=5):
    ecfg = EngineConfig(n_slots=3, page_size=PAGE, n_pages=96, max_context=64,
                        eos_token=-1, prefill_mode=mode, prefill_chunk=chunk)
    eng = DecodeEngine(cfg, ecfg, params)
    rng = np.random.default_rng(0)
    for r in range(6):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                   size=int(rng.integers(3, 20))), 5))
    outs = eng.run(500)
    assert eng.batcher.stats.completed == 6
    assert eng.alloc.pages_in_use == 0
    return {k: list(v) for k, v in outs.items()}, eng


def test_batched_and_chunked_prefill_match_slot_prefill():
    cfg = tiny()
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    slot, eng_slot = _run_engine(cfg, params, "slot")
    batched, eng_b = _run_engine(cfg, params, "batched")
    chunked, eng_c = _run_engine(cfg, params, "chunked", chunk=5)
    assert eng_slot.prefiller.name == "slot"
    assert eng_b.prefiller.name == "batched"
    assert eng_c.prefiller.name == "chunked"
    assert batched == slot
    assert chunked == slot


def test_chunked_prefill_interleaves_with_decode():
    """While one long prompt is chunk-prefilling, already-running requests
    keep decoding (the DCS overlap) — and outputs still match slot mode."""
    cfg = tiny()
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    def run(mode):
        ecfg = EngineConfig(n_slots=2, page_size=PAGE, n_pages=96,
                            max_context=64, eos_token=-1, prefill_mode=mode,
                            prefill_chunk=4)
        eng = DecodeEngine(cfg, ecfg, params)
        eng.submit(Req(0, [3, 5, 7], 10))            # short: decodes early
        eng.submit(Req(1, list(range(1, 20)), 4))    # long: 5 chunk ticks
        return eng, eng.run(300)

    eng_c, outs_c = run("chunked")
    _, outs_s = run("slot")
    assert {k: list(v) for k, v in outs_c.items()} == \
        {k: list(v) for k, v in outs_s.items()}
    # the long prompt held a slot for several ticks without being active:
    # some tick decoded batch=1 while slot 1 prefilled
    assert any(b == 1 for b in eng_c.batcher.stats.batch_trace[:6])


def test_preemption_resume_is_token_identical():
    """Pool-exhaustion preemption (re-prefill + resume) must not change
    greedy outputs or total emission vs an ample pool, in every prefill
    mode — the resumed context is prompt + written tokens, with the last
    sampled (unwritten) token re-entering as the next decode input."""
    cfg = tiny()
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    def run(n_pages, mode):
        ecfg = EngineConfig(n_slots=2, page_size=PAGE, n_pages=n_pages,
                            max_context=64, eos_token=-1, prefill_mode=mode,
                            prefill_chunk=4)
        eng = DecodeEngine(cfg, ecfg, params)
        rng = np.random.default_rng(3)
        for r in range(2):
            eng.submit(Req(r, rng.integers(0, cfg.vocab_size, size=9), 12))
        outs = eng.run(2000)
        return {k: list(v) for k, v in outs.items()}, eng

    ample, _ = run(96, "batched")
    for mode, pages in (("slot", 9), ("batched", 9), ("chunked", 10)):
        tight, eng = run(pages, mode)
        assert eng.batcher.stats.preempted > 0, mode
        assert eng.batcher.stats.completed == 2, mode
        assert tight == ample, mode


def test_recurrent_family_gets_requested_prefill_mode():
    """Recurrent hybrids no longer fall back to per-slot prefill: the
    state-carrying chunked/batched paths serve them directly (the deeper
    token-identity sweeps live in tests/test_recurrent_prefill.py)."""
    cfg = tiny("xlstm-350m")
    ecfg = EngineConfig(n_slots=2, page_size=PAGE, n_pages=32, max_context=24,
                        eos_token=-1, prefill_mode="chunked")
    eng = DecodeEngine(cfg, ecfg)
    assert eng.prefiller.name == "chunked"
    for r in range(2):
        eng.submit(Req(r, [2, 4, 6], 3))
    outs = eng.run(200)
    assert eng.batcher.stats.completed == 2
    assert all(len(v) >= 3 for v in outs.values())


# ---------------------------------------------------------------------------
# scheduling policies
# ---------------------------------------------------------------------------

def _drain_admission_order(policy, lens, *, slots=1, budget=2):
    alloc = PageAllocator(64, 1, PAGE)
    sched = ContinuousBatcher(alloc, slots, max_context=256, policy=policy)
    for i, n in enumerate(lens):
        sched.submit(Request(i, n, budget))
    order, finished = [], None
    for _ in range(200):
        if sched.done():
            break
        admitted, active = sched.step(finished)
        order += [req.req_id for _, req in admitted]
        finished = np.zeros(slots, bool)
        for s in active:
            r = sched.slots[s]
            if r is not None and r.generated >= r.max_new_tokens:
                finished[s] = True
    return order


def test_sjf_admits_shortest_first():
    lens = [16, 2, 9, 4]
    assert _drain_admission_order(FCFSPolicy(), lens) == [0, 1, 2, 3]
    assert _drain_admission_order(SJFPolicy(by="prompt"), lens) == [1, 3, 2, 0]


def test_sjf_total_counts_token_budget():
    alloc = PageAllocator(64, 1, PAGE)
    sched = ContinuousBatcher(alloc, 1, max_context=256, policy=SJFPolicy())
    sched.submit(Request(0, prompt_len=4, max_new_tokens=50))
    sched.submit(Request(1, prompt_len=8, max_new_tokens=2))
    admitted, _ = sched.step()
    assert admitted[0][1].req_id == 1       # 8+2 < 4+50


def test_memory_aware_refuses_lifetime_overflow():
    alloc = PageAllocator(8, 1, PAGE)
    sched = ContinuousBatcher(alloc, 2, max_context=256,
                              policy=MemoryAwarePolicy())
    # occupy one slot so the policy is not in idle-degrade mode
    sched.submit(Request(0, prompt_len=8, max_new_tokens=4))
    sched.step()
    # prompt fits (1 page free after slot 0 grew) but prompt+max_new needs 13
    # pages: FCFS admits and would preempt later; memory-aware refuses
    sched.submit(Request(1, prompt_len=4, max_new_tokens=48))
    assert FCFSPolicy().select(sched, None) == 0
    assert MemoryAwarePolicy().select(sched, None) is None


def test_memory_aware_degrades_to_fcfs_when_idle():
    alloc = PageAllocator(8, 1, PAGE)
    sched = ContinuousBatcher(alloc, 2, max_context=256)
    sched.submit(Request(0, prompt_len=4, max_new_tokens=1000))
    # nothing running and the request can never fit its lifetime: admit
    # anyway (runs under preemption) instead of livelocking the queue
    assert MemoryAwarePolicy().select(sched, None) == 0


def test_memory_aware_prefers_cheapest_candidate():
    alloc = PageAllocator(64, 1, PAGE)
    sched = ContinuousBatcher(alloc, 2, max_context=256)
    sched.submit(Request(0, prompt_len=40, max_new_tokens=8))
    sched.submit(Request(1, prompt_len=4, max_new_tokens=8))
    # both fit; the decode_latency cost model ranks the shorter context first
    assert MemoryAwarePolicy().select(sched, None) == 1


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

def test_greedy_sampler_matches_np_argmax():
    logits = np.asarray(np.random.default_rng(0).normal(size=(5, 33)),
                        np.float32)
    assert (make_sampler("greedy")(logits) == np.argmax(logits, -1)).all()
    assert int(make_sampler("greedy")(logits[0])) == int(np.argmax(logits[0]))


def test_stochastic_samplers_deterministic_in_seed():
    logits = np.asarray(np.random.default_rng(1).normal(size=(4, 64)),
                        np.float32)
    for kind, kw in (("temperature", {"temperature": 0.7}),
                     ("top_k", {"top_k": 5})):
        a = make_sampler(kind, seed=7, **kw)
        b = make_sampler(kind, seed=7, **kw)
        seq_a = [a(logits).tolist() for _ in range(4)]
        seq_b = [b(logits).tolist() for _ in range(4)]
        assert seq_a == seq_b
        c = make_sampler(kind, seed=8, **kw)
        assert [c(logits).tolist() for _ in range(4)] != seq_a


def test_top_k_sampler_stays_in_top_k():
    logits = np.zeros((1, 100), np.float32)
    logits[0, [3, 41, 77]] = 10.0           # everything else ~e^-10 away
    s = make_sampler("top_k", top_k=3, seed=0)
    for _ in range(20):
        assert int(s(logits)[0]) in (3, 41, 77)


# ---------------------------------------------------------------------------
# vectorized host bookkeeping
# ---------------------------------------------------------------------------

def test_snapshots_match_allocator_state():
    """The incrementally-maintained block-table/ctx snapshots must equal the
    per-slot reconstruction from the allocator at every tick, including
    through frees, refills and preemptions."""
    W = 257 // PAGE + 1
    alloc = PageAllocator(32, 1, PAGE)
    sched = ContinuousBatcher(alloc, 3, max_context=256, bt_width=W)
    rng = np.random.default_rng(0)
    for i in range(8):
        sched.submit(Request(i, int(rng.integers(1, 30)),
                             int(rng.integers(1, 20))))
    finished = None
    for _ in range(300):
        if sched.done():
            break
        _, active = sched.step(finished)
        snap_bt = sched.block_tables(W)
        snap_ctx = sched.context_lens()
        for s, req in enumerate(sched.slots):
            if req is None:
                assert (snap_bt[s] == -1).all()
                assert snap_ctx[s] == 0
            else:
                np.testing.assert_array_equal(
                    snap_bt[s], alloc.block_table(req.req_id, W), str(s))
                assert snap_ctx[s] == req.total_len
        finished = np.zeros(3, bool)
        for s in active:
            r = sched.slots[s]
            if r is not None and r.generated >= r.max_new_tokens:
                finished[s] = True
    assert sched.stats.completed == 8
    assert alloc.pages_in_use == 0


def test_engine_timing_reports_host_and_device_split():
    cfg = tiny()
    ecfg = EngineConfig(n_slots=2, page_size=PAGE, n_pages=32, max_context=24,
                        eos_token=-1)
    eng = DecodeEngine(cfg, ecfg)
    eng.submit(Req(0, [1, 2, 3], 3))
    eng.run(100)
    tm = eng.timing.as_dict()
    assert tm["steps"] > 0
    assert tm["decode_s"] > 0 and tm["host_s"] > 0
