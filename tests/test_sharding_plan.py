"""Sharding-plan invariants for every assigned arch, without real devices
(AbstractMesh): every sharded dim must divide its mesh axis, for both the
train (FSDP) and serve (Megatron-TP + EP) layouts, single- and multi-pod."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, SHAPES, ParallelConfig, get_config
from repro.distributed.sharding import abstract_mesh as make_abstract_mesh
from repro.distributed.sharding import make_plan
from repro.models import model as MDL


def abstract_mesh(multi_pod):
    if multi_pod:
        return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = get_config(arch)
    mesh = abstract_mesh(multi_pod)
    sizes = dict(mesh.shape)
    parallel = ParallelConfig(pods=2 if multi_pod else 1)
    plan = make_plan(mesh, parallel, SHAPES["train_4k"])
    params = jax.eval_shape(lambda: MDL.init_params(
        cfg, jax.random.PRNGKey(0),
        moe_virtual=parallel.tp if cfg.is_moe else 0))
    for mode in ("train", "serve"):
        specs = plan.param_specs(params, mode=mode)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for dim, names in zip(leaf.shape, spec):
                if names is None:
                    continue
                names = names if isinstance(names, tuple) else (names,)
                n = int(np.prod([sizes[a] for a in names]))
                assert dim % n == 0, (arch, mode, leaf.shape, spec)


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_plan_layout_selection(shape_name):
    mesh = abstract_mesh(False)
    plan = make_plan(mesh, ParallelConfig(), SHAPES[shape_name])
    if shape_name == "train_4k":
        assert plan.train_layout == "fsdp"       # 256 % 256 == 0
    if shape_name == "prefill_32k":
        assert plan.train_layout == "sp"
    if shape_name == "long_500k":
        assert plan.batch_spec is None           # batch=1 can't shard
        spec = plan.itpp_spec(256)
        assert spec.merge_axes == spec.page_axes  # merge over the whole pod
    if shape_name == "decode_32k":
        spec = plan.itpp_spec(256)
        assert spec.merge_axes == ("model",)     # row-affine requests


def test_pool_pages_divide_shards():
    from repro.core.paged_kv import pool_spec_for
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sn in ("decode_32k", "long_500k"):
            spec = pool_spec_for(cfg, SHAPES[sn], ParallelConfig())
            assert spec.n_pages % 256 == 0, (arch, sn)
