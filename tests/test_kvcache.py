"""KV-cache hierarchy tests: radix-tree invariants (property-style),
CoW/eviction/offload mechanics, and end-to-end token-identity of the
serving engine with prefix sharing on vs off."""
from dataclasses import replace

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.allocator import PageAllocator
from repro.kvcache import PrefixCache, RadixTree
from repro.serving import Request as Req

PAGE = 4


def make_cache(n_pages=64, page_size=PAGE, host_pages=0):
    alloc = PageAllocator(n_pages, 1, page_size)
    return alloc, PrefixCache(alloc, host_pages=host_pages)


def tree_invariants(cache):
    """Structural invariants that must hold after any op sequence."""
    tree, alloc = cache.tree, cache.alloc
    seen: set[int] = set()
    for node in tree.nodes():
        ps = tree.page_size
        assert len(node.tokens) > 0
        assert len(node.tokens) % ps == 0, "nodes split at page boundaries"
        if node.on_host:
            assert node.pages is None
            assert node.host["k"].shape[1] == len(node.tokens) // ps
        else:
            assert len(node.pages) == len(node.tokens) // ps
            for p in node.pages:
                assert p not in seen, "page owned by two tree nodes"
                assert alloc.ref_of(p) >= 1, "tree page without a reference"
                seen.add(p)
        for tok, child in node.children.items():
            assert child.parent is node
            assert int(child.tokens[0]) == tok
        assert node.ref >= sum(c.ref for c in node.children.values()), \
            "path pins must be monotone toward the root"


def _seq(data, shared, n):
    """Token sequence sharing a prefix of ``shared`` with a common base."""
    base = np.arange(1000, 1000 + shared, dtype=np.int32)
    priv = np.asarray([data.draw(st.integers(0, 500))
                       for _ in range(max(0, n - shared))], np.int32)
    return np.concatenate([base[:min(shared, n)], priv])[:n]


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_radix_insert_match_evict_invariants(data):
    """Random interleaving of admit(lookup+admit_shared) / insert / free /
    reclaim keeps the tree structurally sound, the page refcounts
    conserved, and every match a true prefix of the inserted corpus."""
    alloc, cache = make_cache(n_pages=48)
    rng = data
    live: dict[int, np.ndarray] = {}
    next_req = 0
    for _ in range(rng.draw(st.integers(5, 30))):
        action = rng.draw(st.sampled_from(
            ["admit", "finish", "reclaim", "lookup"]))
        if action == "admit" and alloc.available_pages() >= 8:
            shared = rng.draw(st.integers(0, 20))
            n = rng.draw(st.integers(2, 24))
            seq = _seq(rng, shared, n)
            hit = cache.lookup(next_req, seq)
            # a hit must be a true prefix of the request's sequence
            assert hit.matched < len(seq)
            try:
                alloc.admit_shared(next_req, hit.pages, len(seq))
            except MemoryError:
                cache.release(next_req)
                continue
            cache.commit(next_req, alloc.pages_of(next_req))
            live[next_req] = seq
            next_req += 1
        elif action == "finish" and live:
            r = rng.draw(st.sampled_from(sorted(live)))
            cache.insert(r, live[r])
            cache.release(r)
            alloc.free(r)
            del live[r]
        elif action == "reclaim":
            cache.reclaim(rng.draw(st.integers(1, 8)))
        elif action == "lookup" and next_req:
            seq = _seq(rng, rng.draw(st.integers(0, 20)),
                       rng.draw(st.integers(2, 24)))
            dev, host = cache.peek(seq)
            assert (dev + host) * PAGE <= len(seq)
        tree_invariants(cache)
    for r in sorted(live):
        cache.release(r)
        alloc.free(r)
        tree_invariants(cache)
    # after releasing every request, all remaining pages belong to the tree
    assert alloc.pages_in_use == cache.tree.device_pages()
    # ...and a full reclaim returns the pool to empty
    cache.reclaim(alloc.n_pages)
    assert alloc.pages_in_use == 0


def test_match_splits_at_page_boundary_and_cows_midpage():
    alloc, cache = make_cache()
    seq = np.arange(100, 120, dtype=np.int32)          # 20 tokens, 5 pages
    pages = alloc.admit(0, len(seq))
    cache.insert(0, seq)
    alloc.free(0)
    # diverge 18 tokens in: 4 full pages shared + 2-token CoW into page 4
    q = np.concatenate([seq[:18], [7, 8, 9]]).astype(np.int32)
    hit = cache.lookup(1, q)
    assert hit.pages == pages[:4]
    assert hit.matched == 18 and hit.cow_tokens == 2
    assert hit.cow_src == pages[4]
    table = alloc.admit_shared(1, hit.pages, len(q))
    cache.commit(1, table)
    assert table[:4] == pages[:4] and table[4] not in pages
    assert cache.stats.cow_copies == 1
    # fully-cached prompt is capped one token short (first-token logits)
    full = cache.lookup(2, seq)
    assert full.matched == 19 and full.matched < len(seq)
    cache.release(1)
    cache.release(2)
    alloc.free(1)


def test_lru_eviction_spares_pinned_paths():
    alloc, cache = make_cache(n_pages=16)
    a = np.arange(0, 8, dtype=np.int32)
    b = np.arange(100, 108, dtype=np.int32)
    for r, seq in enumerate((a, b)):
        alloc.admit(r, len(seq))
        cache.insert(r, seq)
        alloc.free(r)
    assert cache.reclaimable() == 4
    hit = cache.lookup(9, np.concatenate([a, [1, 2]]).astype(np.int32))
    assert len(hit.pages) == 2                  # pinned while "running"
    assert cache.reclaimable() == 2             # only b's pages evictable
    freed = cache.reclaim(10)
    assert freed == 2                           # b evicted, a survives
    assert cache.tree.device_pages() == 2
    cache.release(9)
    assert cache.reclaimable() == 2


def test_reclaimable_excludes_request_referenced_pages():
    """A tree page a running request still shares would survive eviction
    (the request's reference keeps it resident), so it must not be
    advertised as reclaimable admission capacity — the old node-granular
    count let admission overcommit into mid-decode preemptions."""
    alloc, cache = make_cache(n_pages=16)
    a = np.arange(0, 8, dtype=np.int32)         # 2 pages into the tree
    alloc.admit(0, len(a))
    cache.insert(0, a)
    alloc.free(0)
    assert cache.reclaimable() == 2             # tree-only refs: evictable
    hit = cache.lookup(1, np.concatenate([a, [1, 2]]).astype(np.int32))
    assert len(hit.pages) == 2
    alloc.admit_shared(1, hit.pages, len(a) + 2)
    cache.release(1)                            # unpinned (node ref == 0)...
    # ...but the request still owns a reference on both shared pages, so
    # evicting the node could not actually free them
    assert cache.reclaimable() == 0
    assert alloc.available_pages() == alloc.free_page_count
    alloc.free(1)                               # request gone: refs drop to
    assert cache.reclaimable() == 2             # the tree's own — capacity
    """swap-out -> drain -> match (swap-in) -> apply restores page bytes."""
    import jax.numpy as jnp
    from repro.core.paged_kv import PoolSpec, init_pool

    alloc, cache = make_cache(n_pages=16, host_pages=8)
    spec = PoolSpec(n_layers=2, n_pages=16, page_size=PAGE, n_kv_heads=1,
                    d_head=2, max_pages_per_req=6, dtype="float32")
    pool = init_pool(spec)
    rng = np.random.default_rng(0)
    seq = np.arange(50, 58, dtype=np.int32)            # 2 pages
    pages = alloc.admit(0, len(seq))
    payload = rng.normal(size=(2, len(pages), PAGE, 1, 2)).astype(np.float32)
    pool = {"k": pool["k"].at[:, np.asarray(pages)].set(payload),
            "v": pool["v"].at[:, np.asarray(pages)].set(2 * payload)}
    cache.pool_ref = lambda: pool
    cache.insert(0, seq)
    alloc.free(0)
    # force the pages out to the host tier
    freed = cache.reclaim(2)
    assert freed == 2 and cache.tree.host_pages() == 2
    assert cache.host.used == 2
    cache.maintain()                                   # drain to numpy
    # zero the pool: device copy is gone, only the host copy survives
    pool = {"k": jnp.zeros_like(pool["k"]), "v": jnp.zeros_like(pool["v"])}
    hit = cache.lookup(1, np.concatenate([seq, [1, 2]]).astype(np.int32))
    assert hit.matched == 8 and len(hit.pages) == 2
    assert cache.host.used == 0 and cache.has_pending
    pool = cache.apply_pending(pool)
    np.testing.assert_allclose(
        np.asarray(pool["k"][:, np.asarray(hit.pages)]), payload, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(pool["v"][:, np.asarray(hit.pages)]), 2 * payload,
        rtol=1e-6)
    cache.release(1)


# ---------------------------------------------------------------------------
# end-to-end: engine with sharing on vs off
# ---------------------------------------------------------------------------

def _engine_outputs(cfg, params, *, cache, host=0, n_pages=96, mode="batched",
                    n_req=5, budget=5, **ecfg_kw):
    from repro.serving import DecodeEngine, EngineConfig
    ecfg = EngineConfig(n_slots=3, page_size=PAGE, n_pages=n_pages,
                        max_context=64, eos_token=-1, prefill_mode=mode,
                        prefill_chunk=5, prefix_cache=cache, host_pages=host,
                        **ecfg_kw)
    eng = DecodeEngine(cfg, ecfg, params)
    rng = np.random.default_rng(1)
    system = np.arange(2000, 2038, dtype=np.int32)     # 38-token sys prompt
    for r in range(n_req):
        sfx = rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 8)))
        eng.submit(Req(r, np.concatenate([system, sfx]).astype(np.int32), budget))
    outs = eng.run(1500)
    assert eng.batcher.stats.completed == n_req
    return {k: list(v) for k, v in outs.items()}, eng


@pytest.mark.slow
def test_prefix_sharing_outputs_token_identical():
    """Greedy outputs with the radix cache (incl. CoW suffix prefill and
    the host tier under a tight pool) must equal the no-sharing baseline in
    every prefill mode — and sharing must actually happen."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL

    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    base, _ = _engine_outputs(cfg, params, cache=False)
    for mode in ("batched", "slot", "chunked"):
        got, eng = _engine_outputs(cfg, params, cache=True, mode=mode)
        assert got == base, mode
        st = eng.cache.stats
        assert st.hits > 0 and st.hit_tokens > 0, mode
        assert st.cow_copies > 0, mode          # 38 % PAGE != 0 -> CoW
    # tight pool + host tier: watermark offload and swap-in on reuse
    # (same-tick dedup off: the burst must land cold all at once to build
    # the pool pressure this scenario is about)
    got, eng = _engine_outputs(cfg, params, cache=True, host=64, n_pages=40,
                               prefill_dedup=False)
    assert got == base
    ts = eng.cache.host.stats
    assert ts.swapped_out_pages > 0 and ts.swapped_in_pages > 0


@pytest.mark.slow
def test_same_tick_dedup_cold_burst():
    """A cold burst of same-prefix requests submitted in ONE tick prefills
    the shared prefix once: admission defers followers while the leader's
    prefill is in flight, and they re-admit as radix hits next tick —
    outputs stay token-identical to the no-cache baseline."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL

    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    base, _ = _engine_outputs(cfg, params, cache=False, n_req=3)
    got, eng = _engine_outputs(cfg, params, cache=True, n_req=3)
    assert got == base
    st = eng.batcher.stats
    # all three arrive cold in tick 1 (3 slots free) — without dedup each
    # would pay a full prefill; with it, followers wait for the leader
    assert st.dedup_deferred >= 2
    cs = eng.cache.stats
    assert cs.hits >= 2 and cs.hit_tokens >= 2 * 36


@pytest.mark.slow
def test_shared_pages_and_admitted_kv_beyond_pool():
    """With 90% shared prompts the engine holds fewer device pages than the
    no-sharing run and sustains an admitted batch whose summed per-request
    KV exceeds the device pool."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL
    from repro.serving import DecodeEngine, EngineConfig

    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    system = np.arange(3000, 3040, dtype=np.int32)     # 10 pages

    def run(cache, n_pages):
        ecfg = EngineConfig(n_slots=6, page_size=PAGE, n_pages=n_pages,
                            max_context=64, eos_token=-1,
                            prefix_cache=cache, host_pages=64)
        eng = DecodeEngine(cfg, ecfg, params)
        rng = np.random.default_rng(2)
        eng.submit(Req(0, system, 2))                       # warm the prefix
        eng.run(100)
        for r in range(1, 7):
            sfx = rng.integers(0, cfg.vocab_size, size=3)
            eng.submit(Req(r, np.concatenate([system, sfx]).astype(np.int32), 6))
        peak_pages = peak_kv = 0
        finished = None
        for _ in range(400):
            if eng.batcher.done():
                break
            finished = eng.step(finished)
            peak_pages = max(peak_pages, eng.alloc.pages_in_use)
            kv = sum(len(eng.alloc.pages_of(r.req_id))
                     for r in eng.batcher.slots if r is not None)
            peak_kv = max(peak_kv, kv)
        assert eng.batcher.stats.completed == 7
        return peak_pages, peak_kv, eng

    base_pages, _, _ = run(False, 96)
    shared_pages, peak_kv, eng = run(True, 40)
    assert eng.cache.stats.hits >= 6
    assert shared_pages < base_pages               # measurably fewer pages
    # per-request KV footprint (counting shared pages per owner) exceeds the
    # 40-page device pool: the batch is only admissible because pages are
    # shared / one swap away
    assert peak_kv > 40
