"""Unit tests for the trip-count-aware HLO analyzer (launch/hlo_analysis.py)
— the §Roofline foundation."""
import textwrap

from repro.launch import hlo_analysis as H


def analyze(txt):
    return H.analyze(textwrap.dedent(txt))


def test_while_trip_count_weighting():
    res = analyze("""
        %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %a = f32[8,8]{1,0} get-tuple-element(%p), index=1
          %d = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %i = s32[] get-tuple-element(%p), index=0
          ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
        }

        %cond (p: (s32[], f32[8,8])) -> pred[] {
          %p2 = (s32[], f32[8,8]) parameter(0)
          %i2 = s32[] get-tuple-element(%p2), index=0
          %c = s32[] constant(7)
          ROOT %lt = pred[] compare(%i2, %c), direction=LT
        }

        ENTRY %main (x: f32[8,8]) -> f32[8,8] {
          %x = f32[8,8]{1,0} parameter(0)
          %i0 = s32[] constant(0)
          %t0 = (s32[], f32[8,8]) tuple(%i0, %x)
          %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
          ROOT %g = f32[8,8]{1,0} get-tuple-element(%w), index=1
        }
    """)
    # dot flops = 2*8*8*8 = 1024, x7 trips
    assert res["flops"] == 7 * 1024


def test_collective_byte_accounting():
    res = analyze("""
        ENTRY %main (x: bf16[4,8]) -> bf16[16,8] {
          %x = bf16[4,8]{1,0} parameter(0)
          %ag = bf16[16,8]{1,0} all-gather(%x), channel_id=1, replica_groups=[1,4]<=[4], dimensions={0}
          %ar = bf16[16,8]{1,0} all-reduce(%ag), channel_id=2, replica_groups=[1,4]<=[4], to_apply=%add
          ROOT %cp = bf16[16,8]{1,0} collective-permute(%ar), channel_id=3, source_target_pairs={{0,1}}
        }
    """)
    ag = 16 * 8 * 2
    assert res["collectives"]["all-gather"] == ag
    assert res["collectives"]["all-reduce"] == 2 * ag    # ring rs+ag
    assert res["collectives"]["collective-permute"] == ag


def test_dus_counts_update_not_buffer():
    res = analyze("""
        ENTRY %main (big: f32[1000,1000], small: f32[1,1000]) -> f32[1000,1000] {
          %big = f32[1000,1000]{1,0} parameter(0)
          %small = f32[1,1000]{1,0} parameter(1)
          %i = s32[] constant(3)
          ROOT %d = f32[1000,1000]{1,0} dynamic-update-slice(%big, %small, %i, %i)
        }
    """)
    # 2x update bytes (read+write slice), NOT the 4MB buffer
    assert res["hbm_bytes"] == 2 * 1000 * 4


def test_large_convert_zeroed_small_kept():
    res = analyze("""
        ENTRY %main (w: bf16[4096,4096], t: bf16[4,4]) -> f32[4,4] {
          %w = bf16[4096,4096]{1,0} parameter(0)
          %big = f32[4096,4096]{1,0} convert(%w)
          %t = bf16[4,4]{1,0} parameter(1)
          ROOT %small = f32[4,4]{1,0} convert(%t)
        }
    """)
    assert res["hbm_bytes"] == 4 * 4 * 4       # only the small convert


def test_conditional_branches_averaged():
    res = analyze("""
        %br0 (p: f32[8,8]) -> f32[8,8] {
          %p = f32[8,8]{1,0} parameter(0)
          ROOT %d = f32[8,8]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }

        %br1 (p: f32[8,8]) -> f32[8,8] {
          %p3 = f32[8,8]{1,0} parameter(0)
          ROOT %n = f32[8,8]{1,0} negate(%p3)
        }

        ENTRY %main (x: f32[8,8], c: pred[]) -> f32[8,8] {
          %x = f32[8,8]{1,0} parameter(0)
          %c = pred[] parameter(1)
          ROOT %sel = f32[8,8]{1,0} conditional(%c, %x, %x), true_computation=%br0, false_computation=%br1
        }
    """)
    assert res["flops"] == 0.5 * 1024          # one of two branches runs


def test_roofline_terms_and_bottleneck():
    t = H.roofline_terms({"flops": 197e12, "hbm_bytes": 819e9 * 2,
                          "collective_bytes": 50e9 * 0.5})
    assert abs(t["t_compute"] - 1.0) < 1e-9
    assert abs(t["t_memory"] - 2.0) < 1e-9
    assert abs(t["t_collective"] - 0.5) < 1e-9
    assert t["bottleneck"] == "memory"
