"""SLO serving layer: Request/policy API, virtual time, priority
preemption token-identity, EDF ordering, goodput, and the workload
generator's determinism contract."""
import os
import sys
from dataclasses import replace
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.allocator import PageAllocator
from repro.core.scheduler import ContinuousBatcher
from repro.core.scheduler import Request as SchedReq
from repro.models import model as MDL
from repro.runtime.clock import VirtualClock
from repro.serving import (DecodeEngine, EDFPolicy, EngineConfig, Request,
                           SLOPolicy, available_policies)
from repro.serving.policies import SJFPolicy, make_policy
from repro.telemetry.tracing import RequestTracker

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import workload  # noqa: E402

PAGE = 4


def tiny(name="llama3.2-1b", **kw):
    return replace(reduced(get_config(name)), dtype="float32", **kw)


_PARAMS = {}


def params_for(cfg):
    if "p" not in _PARAMS:
        _PARAMS["p"] = MDL.init_params(cfg, jax.random.PRNGKey(0),
                                       jnp.float32)
    return _PARAMS["p"]


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

def test_virtual_clock():
    vc = VirtualClock()
    assert vc() == 0.0
    vc.advance(0.25)
    assert vc() == 0.25
    vc.advance_to(1.0)
    assert vc() == 1.0
    vc.advance_to(0.5)          # never goes backwards
    assert vc() == 1.0
    with pytest.raises(AssertionError):
        vc.advance(-0.1)


# ---------------------------------------------------------------------------
# workload generator determinism
# ---------------------------------------------------------------------------

def test_workload_trace_deterministic():
    cfg = workload.default_slo_config()
    t1, t2 = workload.generate(cfg), workload.generate(cfg)
    assert t1 == t2
    cfg2 = workload.default_slo_config()
    cfg2.seed = cfg.seed + 1
    assert workload.generate(cfg2)["events"] != t1["events"]
    ts = [e["t"] for e in t1["events"]]
    assert ts == sorted(ts)


def test_workload_prompt_tokens_share_group_prefix():
    trace = workload.generate(workload.default_slo_config())
    subs = [e for e in trace["events"] if e["kind"] == "submit"]
    by_group = {}
    for e in subs:
        if e["prefix_group"] >= 0 and e["prefix_len"] > 0:
            by_group.setdefault((e["tenant"], e["prefix_group"]),
                                []).append(e)
    pair = next(v for v in by_group.values() if len(v) >= 2)
    a = workload.prompt_tokens(trace, pair[0], vocab=128)
    b = workload.prompt_tokens(trace, pair[1], vocab=128)
    k = min(pair[0]["prefix_len"], pair[1]["prefix_len"])
    assert k > 0 and list(a[:k]) == list(b[:k])    # shared prefix
    # materialization itself is deterministic
    assert list(a) == list(workload.prompt_tokens(trace, pair[0], vocab=128))
    assert a.min() >= 1                            # never the eos id 0


def test_workload_committed_trace_matches_generator():
    """The checked-in trace is exactly what the committed config
    regenerates — nobody hand-edited it."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "traces", "slo_default.json")
    committed = workload.load_trace(path)
    assert committed["events"] == \
        workload.generate(workload.default_slo_config())["events"]


# ---------------------------------------------------------------------------
# policy registry + EDF / SLO ordering (scheduler-level, no model)
# ---------------------------------------------------------------------------

def _batcher(policy, entries):
    """entries: (req_id, prompt_len, max_new, submit_t, spec)."""
    b = ContinuousBatcher(PageAllocator(64, 1, PAGE), 1, max_context=256,
                          policy=policy)
    for rid, plen, mnew, st, spec in entries:
        b.submit(SchedReq(rid, plen, mnew, submit_t=st, spec=spec,
                          priority=getattr(spec, "priority", 0)))
    return b


def test_policy_registry():
    assert {"fcfs", "sjf", "edf", "slo", "memory_aware"} <= \
        set(available_policies())
    p = make_policy(SJFPolicy.Config(by="prompt"))
    assert isinstance(p, SJFPolicy) and p.by == "prompt"
    with pytest.raises(KeyError, match="edf"):
        make_policy("nope")
    with pytest.raises(TypeError):
        SJFPolicy(SJFPolicy.Config(), by="prompt")


def test_edf_ordering_property():
    """EDF admits the earliest effective deadline (hard deadline or TTFT
    target, whichever is sooner); deadline-free requests sort last; ties
    break FCFS — checked against an independent key on random queues."""
    rng = np.random.default_rng(0)
    for _ in range(25):
        entries = []
        for rid in range(8):
            st = float(rng.uniform(0, 1))
            dl = float(rng.uniform(0.1, 2)) if rng.random() < 0.5 else None
            tt = float(rng.uniform(0.05, 1)) if rng.random() < 0.5 else None
            entries.append((rid, int(rng.integers(1, 8)), 4, st,
                            Request(rid, [1], 4, deadline_s=dl,
                                    ttft_slo_s=tt)))
        b = _batcher(EDFPolicy(), entries)
        expected = min(
            range(len(entries)),
            key=lambda i: (min(entries[i][3] + (entries[i][4].deadline_s
                                                or np.inf),
                               entries[i][3] + (entries[i][4].ttft_slo_s
                                                or np.inf)),
                           entries[i][3], i))
        assert b.policy.select(b) == expected


def test_slo_priority_beats_deadline():
    """Tier first: a high-priority request with a LATER deadline is still
    admitted ahead of an urgent low-priority one; within a tier, EDF."""
    lo = Request(0, [1], 4, priority=0, ttft_slo_s=0.01)
    hi = Request(1, [1], 4, priority=2, ttft_slo_s=5.0)
    b = _batcher(SLOPolicy(), [(0, 4, 4, 0.0, lo), (1, 4, 4, 0.0, hi)])
    assert b.policy.select(b) == 1
    a = Request(2, [1], 4, priority=1, ttft_slo_s=0.5)
    c = Request(3, [1], 4, priority=1, ttft_slo_s=0.1)
    b2 = _batcher(SLOPolicy(), [(2, 4, 4, 0.0, a), (3, 4, 4, 0.0, c)])
    assert b2.policy.select(b2) == 1


# ---------------------------------------------------------------------------
# goodput against a hand-checked timeline
# ---------------------------------------------------------------------------

def test_goodput_hand_checked():
    vc = VirtualClock()
    tr = RequestTracker(clock=vc)

    def close(rid, *, finish=True):
        tr.on_finish(SimpleNamespace(req_id=rid, cached_len=0), 0) if finish \
            else tr.on_abort(SimpleNamespace(req_id=rid), 0, "client")

    # A: meets both targets (ttft 0.05 <= 0.1, tpot 0.025 <= 0.05)
    tr.on_submit(0, 4, 5, spec=Request(0, [1], 5, ttft_slo_s=0.1,
                                       tpot_slo_s=0.05))
    vc.advance(0.05)
    tr.on_tokens(0, 1, vc())
    vc.advance(0.1)
    tr.on_tokens(0, 4, vc())
    close(0)
    assert tr.records[-1].slo_ok
    # B: misses TTFT (0.2 > 0.05)
    tr.on_submit(1, 4, 2, spec=Request(1, [1], 2, ttft_slo_s=0.05))
    vc.advance(0.2)
    tr.on_tokens(1, 2, vc())
    close(1)
    assert not tr.records[-1].slo_ok
    # C: no targets -> vacuously attained on finish
    tr.on_submit(2, 4, 1, spec=Request(2, [1], 1))
    tr.on_tokens(2, 1, vc())
    close(2)
    # D: aborted -> never attains, but counts against goodput
    tr.on_submit(3, 4, 8, spec=Request(3, [1], 8, ttft_slo_s=9.0))
    close(3, finish=False)
    assert tr.goodput() == pytest.approx(2 / 4)
    s = tr.summary()
    assert s["slo_attained"] == 2 and s["goodput"] == pytest.approx(0.5)
    assert s["finished"] == 3 and s["aborted"] == 1


# ---------------------------------------------------------------------------
# engine-level: priority preemption, deadlines, shim — all on virtual time
# ---------------------------------------------------------------------------

def _tick_until_done(eng, vc, dt=0.01, limit=500):
    for _ in range(limit):
        if eng.batcher.done() and eng._inflight is None:
            return
        eng.tick()
        vc.advance(dt)
    raise AssertionError("engine did not drain")


def _mk_engine(cfg, vc, *, n_slots, policy):
    ecfg = EngineConfig(n_slots=n_slots, page_size=PAGE, n_pages=96,
                        max_context=64, eos_token=-1, prefill_mode="batched",
                        sched_policy=policy, clock=vc)
    return DecodeEngine(cfg, ecfg, params_for(cfg))


def test_priority_preemption_token_identical():
    """A high-priority arrival starves behind two full low-priority slots;
    the SLO policy preempts one through the snapshot/restore path, and the
    victim's resumed output is token-identical to an uncontended run."""
    cfg = tiny()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=7) for _ in range(3)]

    def submit_all(eng, vc):
        eng.submit(Request(0, prompts[0], 20, priority=0, ttft_slo_s=2.0,
                           tpot_slo_s=1.0))
        eng.submit(Request(1, prompts[1], 20, priority=0, ttft_slo_s=2.0,
                           tpot_slo_s=1.0))
        for _ in range(3):
            eng.tick()
            vc.advance(0.01)
        eng.submit(Request(2, prompts[2], 5, priority=2, ttft_slo_s=0.08))
        _tick_until_done(eng, vc)
        return {k: list(v) for k, v in eng.outputs.items()}

    vc = VirtualClock()
    eng = _mk_engine(cfg, vc, n_slots=2, policy="slo")
    pressured = submit_all(eng, vc)
    assert eng.batcher.stats.priority_preempted >= 1
    assert eng.batcher.stats.completed == 3
    # same three requests, ample slots, no preemption possible
    vc2 = VirtualClock()
    ample = submit_all(_mk_engine(cfg, vc2, n_slots=4, policy="fcfs"), vc2)
    assert pressured == ample


def test_deadline_abort_on_virtual_time_is_deterministic():
    """Deadlines read the injected clock: the abort tick is a pure function
    of tick_s, so two replays tear down with identical token counts."""
    cfg = tiny()

    def run():
        vc = VirtualClock()
        eng = _mk_engine(cfg, vc, n_slots=2, policy="fcfs")
        eng.submit(Request(0, [3, 5, 7], 50, deadline_s=0.055))
        _tick_until_done(eng, vc)
        return eng.aborted.get(0), len(eng.outputs.get(0, ())), \
            dict(eng.abort_counts)

    a, b = run(), run()
    assert a == b
    assert a[0] == "deadline" and 0 < a[1] < 50


def test_request_shim_equivalence():
    """The deprecated positional submit still works, warns, and produces
    the same tokens as the Request path."""
    cfg = tiny()
    vc = VirtualClock()
    eng = _mk_engine(cfg, vc, n_slots=2, policy="fcfs")
    with pytest.deprecated_call():
        eng.submit(0, [3, 5, 7], 6)
    _tick_until_done(eng, vc)
    vc2 = VirtualClock()
    eng2 = _mk_engine(cfg, vc2, n_slots=2, policy="fcfs")
    eng2.submit(Request(0, [3, 5, 7], 6))
    _tick_until_done(eng2, vc2)
    assert {k: list(v) for k, v in eng.outputs.items()} == \
        {k: list(v) for k, v in eng2.outputs.items()}
