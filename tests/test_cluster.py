"""Disaggregated serving cluster (prefill/decode split + crash-safe KV
handoff, serving/cluster.py) and the handoff wire format
(kvcache/handoff.py).

The load-bearing contract everywhere: the disaggregated pool is
token-identical (greedy) to a single colocated engine — through clean
handoffs, torn/corrupted transfers, destination timeouts, engine deaths
(cold re-drive AND warm snapshot restore), and role collapse. Plus the
drain contract per surviving engine: no leaked pages or per-request
state."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kvcache import handoff as HO
from repro.models import model as MDL
from repro.runtime.faults import FaultConfig
from repro.serving import (ClusterConfig, DecodeEngine, EngineCluster,
                           EngineConfig)
from repro.serving import Request as Req

PAGE = 4
_PARAMS: dict = {}


def _params(name="llama3.2-1b"):
    if name not in _PARAMS:
        cfg = replace(reduced(get_config(name)), dtype="float32")
        _PARAMS[name] = (cfg, MDL.init_params(cfg, jax.random.PRNGKey(0),
                                              jnp.float32))
    return _PARAMS[name]


def _ecfg(**kw):
    base = dict(n_slots=3, page_size=PAGE, n_pages=96, max_context=64,
                eos_token=-1)
    base.update(kw)
    return EngineConfig(**base)


def _prompts(n, seed=0, arch="llama3.2-1b"):
    cfg, _ = _params(arch)
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 20)))
            for _ in range(n)]


def _ref(prompts, max_new=5, arch="llama3.2-1b", **ekw):
    cfg, params = _params(arch)
    eng = DecodeEngine(cfg, _ecfg(**ekw), params)
    for r, p in enumerate(prompts):
        eng.submit(Req(r, p, max_new))
    return {k: list(v) for k, v in eng.run(2000).items()}


def _cluster(ccfg=None, arch="llama3.2-1b", **ekw):
    cfg, params = _params(arch)
    return EngineCluster(cfg, _ecfg(**ekw), ccfg or ClusterConfig(), params)


def _run(cl, prompts, max_new=5):
    for r, p in enumerate(prompts):
        cl.submit(Req(r, p, max_new))
    return {k: list(v) for k, v in cl.run(2000).items()}


def _assert_cluster_drained(cl, n):
    assert cl.done()
    term = sum(1 for rec in cl.reqs.values()
               if rec["state"] in ("done", "aborted"))
    assert term == n == len(cl.reqs)
    for h in cl.handles:
        if not h.alive:
            continue
        eng = h.eng
        assert eng.batcher.done() and eng._inflight is None
        assert eng.alloc.pages_in_use == (
            eng.cache.tree.device_pages() if eng.cache is not None else 0)
        assert not eng.rsnaps
        assert not eng.deadline_t
        assert not eng._abort_req


# ---------------------------------------------------------------------------
# handoff wire format
# ---------------------------------------------------------------------------

def test_handoff_roundtrip_and_nested_arrays():
    ent = {"prompt_len": 9, "max_new": 4, "state": "warm", "depth": 9}
    arrs = {"prompt": np.arange(8, dtype=np.int32),
            "out": np.asarray([7], np.int32),
            "rows": {"ssm": {"0": np.ones((1, 2), np.float32)}}}
    h = HO.pack(3, ent, arrs)
    got = HO.decode(HO.encode(h))
    assert got.req_id == 3 and got.entry == ent
    nested = HO.nested_arrays(got)
    assert np.array_equal(nested["prompt"], arrs["prompt"])
    assert np.array_equal(nested["rows"]["ssm"]["0"],
                          arrs["rows"]["ssm"]["0"])


@pytest.mark.parametrize("damage", [HO.tear, HO.flip])
def test_handoff_detects_damage(damage):
    """Every torn/flipped variant of a blob must raise HandoffError before
    anything is constructed — a half-applied transfer is the one outcome
    the manifest gating exists to prevent."""
    h = HO.pack(0, {"prompt_len": 4, "max_new": 2, "state": "cold"},
                {"prompt": np.arange(4, dtype=np.int32),
                 "out": np.asarray([1], np.int32)})
    blob = HO.encode(h)
    for salt in range(12):
        with pytest.raises(HO.HandoffError):
            HO.decode(damage(blob, salt))
    HO.decode(blob)                              # pristine still decodes


# ---------------------------------------------------------------------------
# disaggregated == colocated (token identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["slot", "batched", "chunked"])
def test_disagg_token_identical_to_colocated(mode):
    prompts = _prompts(6)
    ref = _ref(prompts, prefill_mode=mode)
    cl = _cluster(prefill_mode=mode)
    outs = _run(cl, prompts)
    assert outs == ref
    assert cl.counters["handoffs"] == 6
    assert cl.counters["handoff_ok"] == 6
    _assert_cluster_drained(cl, 6)


def test_disagg_recurrent_carry_handoff():
    """Hybrid-SSM handoff moves the recurrent carry with the KV pages; the
    decode engine re-seats it warm (no re-prefill) and stays identical."""
    prompts = _prompts(4, arch="zamba2-1.2b")
    ref = _ref(prompts, max_new=6, arch="zamba2-1.2b")
    cl = _cluster(arch="zamba2-1.2b")
    outs = _run(cl, prompts, max_new=6)
    assert outs == ref
    assert cl.counters["handoff_ok"] == 4
    dec = cl.handles[1].eng
    assert dec.rstate_restores >= 1              # carries arrived warm
    _assert_cluster_drained(cl, 4)


def test_colocated_cluster_matches_single_engine():
    prompts = _prompts(6)
    ref = _ref(prompts)
    cl = _cluster(ClusterConfig(colocated=True, n_prefill=1, n_decode=0))
    outs = _run(cl, prompts)
    assert outs == ref
    assert cl.counters["handoffs"] == 0          # no transfers when colocated
    _assert_cluster_drained(cl, 6)


# ---------------------------------------------------------------------------
# corrupted / torn transfers: retry with backoff, then cold re-drive
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["handoff_torn", "handoff_corrupt"])
def test_handoff_damage_retries_then_identical(kind):
    prompts = _prompts(6)
    ref = _ref(prompts)
    cl = _cluster(ClusterConfig(
        faults=FaultConfig(seed=5, **{f"{kind}_p": 0.5})))
    outs = _run(cl, prompts)
    assert outs == ref
    assert cl.counters["handoff_retries"] >= 1
    assert cl.faults.counts.get(kind, 0) >= 1
    _assert_cluster_drained(cl, 6)


def test_handoff_all_corrupt_degrades_to_cold_redrive():
    """Every transmission corrupted: retries exhaust and each handoff
    degrades to a cold re-prefill on the destination — slower, never
    wrong."""
    prompts = _prompts(6)
    ref = _ref(prompts)
    cl = _cluster(ClusterConfig(
        handoff_retries=3,
        faults=FaultConfig(seed=3, handoff_corrupt_p=1.0)))
    outs = _run(cl, prompts)
    assert outs == ref
    assert cl.counters["handoff_ok"] == 0
    assert cl.counters["handoff_redrives"] == 6
    assert cl.counters["handoff_retries"] == 6 * 4   # 1 try + 3 retries each
    _assert_cluster_drained(cl, 6)


def test_handoff_timeout_redispatches_to_healthy_engine():
    """Kill the routed destination while transfers are pending: the
    per-handoff deadline fires and the handoff is re-dispatched to the
    surviving decode engine, token-identically."""
    prompts = _prompts(4)
    ref = _ref(prompts)
    cl = _cluster(ClusterConfig(n_prefill=1, n_decode=2, transfer_ticks=3,
                                handoff_timeout=2))
    for r, p in enumerate(prompts):
        cl.submit(Req(r, p, 5))
    # run until transfers are pending, then kill their destination directly
    while not cl._pending:
        cl.tick()
    victim = {ho.dst_ix for ho in cl._pending}
    assert len(victim) >= 1
    cl._kill(cl.handles[victim.pop()])
    outs = {k: list(v) for k, v in cl.run(2000).items()}
    assert outs == ref
    assert cl.counters["handoff_timeouts"] >= 1
    assert cl.counters["handoff_redispatches"] >= 1
    _assert_cluster_drained(cl, 4)


# ---------------------------------------------------------------------------
# engine death: cold re-drive, warm snapshot restore, role collapse
# ---------------------------------------------------------------------------

def test_engine_death_cold_redrive_token_identical():
    prompts = _prompts(6)
    ref = _ref(prompts, max_new=8)
    cl = _cluster(ClusterConfig(
        faults=FaultConfig(seed=11, engine_death_p=0.05, start_tick=3,
                           max_faults=1)))
    outs = _run(cl, prompts, max_new=8)
    assert outs == ref
    assert cl.counters["engine_deaths"] == 1
    assert cl.counters["engine_restores"] == 0   # no snapshots: cold path
    # one role died -> sticky collapse to a colocated single-engine pool
    assert cl.degraded_mode & 1
    assert cl.counters["role_collapses"] >= 1
    assert sum(h.alive for h in cl.handles) == 1
    assert all(h.role == "both" for h in cl.handles if h.alive)
    _assert_cluster_drained(cl, 6)


def test_engine_death_warm_restore_token_identical(tmp_path):
    """With per-engine serving snapshots the dead engine is rebuilt warm
    from its last step and resumes mid-stream — no collapse, both roles
    stay covered, outputs identical."""
    prompts = _prompts(6)
    ref = _ref(prompts, max_new=8)
    cl = _cluster(ClusterConfig(
        snapshot_dir=str(tmp_path), snapshot_every=2,
        faults=FaultConfig(seed=2, engine_death_p=0.04, start_tick=6,
                           max_faults=1)))
    outs = _run(cl, prompts, max_new=8)
    assert outs == ref
    assert cl.counters["engine_deaths"] == 1
    assert cl.counters["engine_restores"] == 1
    assert cl.degraded_mode == 0                 # restore kept both roles
    assert sum(h.alive for h in cl.handles) == 2
    _assert_cluster_drained(cl, 6)


def test_all_engines_dead_goes_terminal():
    """Nothing left to serve on: every live request aborts with
    engine_death instead of hanging the router."""
    prompts = _prompts(4)
    cl = _cluster(ClusterConfig(
        faults=FaultConfig(seed=7, engine_death_p=1.0)))
    outs = _run(cl, prompts)
    assert cl.counters["engine_deaths"] == 2
    assert all(cl.aborted[r] == "engine_death" for r in range(4))
    assert all(outs[r] == [] for r in range(4))
    assert cl.done()


# ---------------------------------------------------------------------------
# router backpressure
# ---------------------------------------------------------------------------

def test_backpressure_sheds_at_router():
    prompts = _prompts(12)
    cl = _cluster(ClusterConfig(max_backlog=4))
    accepted = [cl.submit(Req(r, p, 4)) for r, p in enumerate(prompts)]
    outs = {k: list(v) for k, v in cl.run(2000).items()}
    n_ok = sum(accepted)
    assert 0 < n_ok < 12                        # some flowed, some shed
    assert cl.counters["shed"] == 12 - n_ok
    for r, ok in enumerate(accepted):
        if ok:
            assert outs[r]                      # accepted => served
        else:
            assert cl.aborted[r] == "shed" and outs[r] == []
    _assert_cluster_drained(cl, 12)


def test_cluster_telemetry_counters_exposed():
    from repro.telemetry import TelemetryConfig, parse_exposition
    prompts = _prompts(4)
    cl = _cluster(ClusterConfig(telemetry=TelemetryConfig()),
                  telemetry=TelemetryConfig())
    _run(cl, prompts)
    samples = parse_exposition(cl.tel.registry.render())
    assert samples["repro_cluster_handoffs_total"] == 4.0
    g = cl.tel.registry.get
    assert g("cluster_handoff_ok_total") == 4.0
    assert g("cluster_engines_healthy") == 2.0
    assert g("cluster_pending_handoffs") == 0.0
    # per-engine registries: each pool member namespaced by its index
    for ix, h in enumerate(cl.handles):
        etext = h.eng.tel.registry.render()
        assert f"repro_e{ix}_engine_steps_total" in etext
