"""SSM invariants: chunked-parallel == exact sequential, under hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.models import ssm as S


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_chunked_gla_equals_sequential(data):
    B = data.draw(st.integers(1, 2))
    H = data.draw(st.sampled_from([1, 3]))
    dk = data.draw(st.sampled_from([2, 4, 8]))
    dv = data.draw(st.sampled_from([2, 5]))
    chunk = data.draw(st.sampled_from([1, 2, 4, 8]))
    n_chunks = data.draw(st.integers(1, 4))
    S_ = chunk * n_chunks
    normalize = data.draw(st.booleans())
    seed = data.draw(st.integers(0, 1000))
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S_, H, dk))
    k = jax.random.normal(ks[1], (B, S_, H, dk))
    v = jax.random.normal(ks[2], (B, S_, H, dv))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S_, H)))
    lg = jax.random.normal(ks[4], (B, S_, H)) * 0.5
    y, state = S.chunked_gla(q, k, v, la, lg, chunk=chunk, normalize=normalize)
    # exact sequential reference
    if normalize:
        st0 = (jnp.zeros((B, H, dk, dv)), jnp.zeros((B, H, dk)),
               jnp.full((B, H), -1e30))
    else:
        st0 = (jnp.zeros((B, H, dk, dv)), jnp.zeros((B, H, dk)),
               jnp.zeros((B, H)))
    ys = []
    cur = st0
    for t in range(S_):
        yt, cur = S.gla_step(q[:, t], k[:, t], v[:, t], la[:, t], lg[:, t],
                             cur, normalize=normalize)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state[0]), np.asarray(cur[0]),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(split=st.integers(1, 15), seed=st.integers(0, 100))
def test_state_handoff_is_split_invariant(split, seed):
    """prefill-then-decode equals one shot: chunked_gla with carried state."""
    B, S_, H, dk, dv = 1, 16, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S_, H, dk))
    k = jax.random.normal(ks[1], (B, S_, H, dk))
    v = jax.random.normal(ks[2], (B, S_, H, dv))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S_, H)))
    lg = jax.random.normal(ks[4], (B, S_, H)) * 0.3
    y_full, _ = S.chunked_gla(q, k, v, la, lg, chunk=1, normalize=True)
    y1, st1 = S.chunked_gla(q[:, :split], k[:, :split], v[:, :split],
                            la[:, :split], lg[:, :split], chunk=1,
                            normalize=True)
    y2, _ = S.chunked_gla(q[:, split:], k[:, split:], v[:, split:],
                          la[:, split:], lg[:, split:], chunk=1,
                          normalize=True, state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)


def test_mamba_and_mlstm_blocks_parallel_vs_step():
    from dataclasses import replace
    from repro.configs import get_config, reduced
    zc = replace(reduced(get_config("zamba2-1.2b")), dtype="float32")
    xc = replace(reduced(get_config("xlstm-350m")), dtype="float32")
    B, T = 2, 8
    key = jax.random.PRNGKey(0)
    p = S.init_mamba(key, zc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, zc.d_model)) * 0.5
    y_par, _ = S.mamba_forward(p, zc, x, state=S.mamba_init_state(zc, B),
                               chunk=4)
    stt = S.mamba_init_state(zc, B)
    ys = []
    for t in range(T):
        yt, stt = S.mamba_step(p, zc, x[:, t], stt)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-5)

    pm = S.init_mlstm(key, xc, jnp.float32)
    x2 = jax.random.normal(jax.random.PRNGKey(2), (B, T, xc.d_model)) * 0.5
    ym, _ = S.mlstm_forward(pm, xc, x2, state=S.mlstm_init_state(xc, B),
                            chunk=4)
    stt = S.mlstm_init_state(xc, B)
    ys = []
    for t in range(T):
        yt, stt = S.mlstm_step(pm, xc, x2[:, t], stt)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-5)
