"""Multi-device shard_map correctness (subprocess: needs
--xla_force_host_platform_device_count BEFORE jax init, which conftest
deliberately does not set — see the assignment's dry-run note)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_devprog(body: str, n_dev: int = 8):
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_platform_name", "cpu")
        from repro.core.jax_compat import make_mesh, shard_map
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": SRC},
                       timeout=560)
    assert r.returncode == 0 and "SUBPROC_OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


@pytest.mark.slow
def test_itpp_sharded_matches_oracle():
    run_devprog("""
        from repro.core import paged_kv as PK, itpp as IT
        from repro.core.allocator import PageAllocator
        B, H, KVH, D, page, maxp, n_pages = 4, 8, 2, 16, 4, 8, 64
        alloc = PageAllocator(n_pages, 8, page, policy="striped")
        ctx_prev = np.array([13, 7, 22, 1], np.int32)
        bts = []
        for b in range(B):
            alloc.admit(b, int(ctx_prev[b]) + 1)
            bts.append(alloc.block_table(b, maxp))
        bt = jnp.asarray(np.stack(bts))
        key = jax.random.PRNGKey(0)
        pool_k = jax.random.normal(key, (n_pages, page, KVH, D))
        pool_v = jax.random.normal(jax.random.PRNGKey(1), (n_pages, page, KVH, D))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, H, D))
        k_new = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, D))
        v_new = jax.random.normal(jax.random.PRNGKey(4), (B, KVH, D))
        ctx = jnp.asarray(ctx_prev + 1)
        npage = jnp.asarray([bts[b][int(ctx_prev[b]) // page] for b in range(B)])
        noff = jnp.asarray(ctx_prev % page)
        pk_ref, pv_ref = PK.write_token(pool_k, pool_v, k_new, v_new, npage, noff)
        ref = PK.paged_decode_attention_ref(q, pk_ref, pv_ref, bt, ctx)
        mesh = make_mesh((8,), ("model",))
        spec = IT.ItppSpec(("model",), ("model",), None, 8, 8, page)
        f = IT.make_itpp_attention(mesh, spec, max_pages_per_req=maxp)
        out, pk, pv = jax.jit(f)(q, k_new, v_new, pool_k, pool_v, bt, ctx,
                                 npage, noff, 0)
        assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-5
        assert np.abs(np.asarray(pk) - np.asarray(pk_ref)).max() == 0
    """)


@pytest.mark.slow
def test_moe_ep_matches_local():
    run_devprog("""
        from dataclasses import replace
        from repro.configs import get_config, reduced
        from repro.models import moe as M
        from jax.sharding import PartitionSpec as P
        cfg = replace(reduced(get_config("mixtral-8x22b")), dtype="float32",
                      capacity_factor=8.0)   # dropless so paths agree
        V = 8   # 4 experts x 2 ff-slices on 8 shards
        p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, n_virtual=V)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        y_local, aux_l = M.moe_local(p, cfg, x)
        mesh = make_mesh((8,), ("model",))
        def body(pw, x_loc):
            B, S, D = x_loc.shape
            y, aux = M.moe_ep(pw, cfg, x_loc.reshape(-1, D), "model", 8)
            return y.reshape(B, S, D), jax.lax.pmean(aux, "model")
        pspec = {"router": P(None, None), "w1": P("model", None, None),
                 "w2": P("model", None, None), "w3": P("model", None, None)}
        f = shard_map(body, mesh=mesh,
                      in_specs=(pspec, P(None, "model", None)),
                      out_specs=(P(None, "model", None), P()),
                      check_vma=False)
        y_ep, aux_e = jax.jit(f)({k: p[k] for k in pspec}, x)
        err = np.abs(np.asarray(y_ep) - np.asarray(y_local)).max()
        assert err < 1e-4, err
    """)


@pytest.mark.slow
def test_long_context_single_request_spans_all_shards():
    """long_500k layout: batch=1, pages striped over the whole mesh, merge
    over every axis — the paper's one-request-across-the-pod scenario."""
    run_devprog("""
        from repro.core import paged_kv as PK, itpp as IT
        from repro.core.allocator import PageAllocator
        B, H, KVH, D, page, maxp, n_pages = 1, 4, 1, 16, 4, 16, 64
        alloc = PageAllocator(n_pages, 8, page, policy="striped")
        ctx_prev = 57
        alloc.admit(0, ctx_prev + 1)
        bt = jnp.asarray(alloc.block_table(0, maxp)[None])
        key = jax.random.PRNGKey(0)
        pool_k = jax.random.normal(key, (n_pages, page, KVH, D))
        pool_v = jax.random.normal(jax.random.PRNGKey(1), (n_pages, page, KVH, D))
        q = jax.random.normal(jax.random.PRNGKey(2), (B, H, D))
        k_new = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, D))
        v_new = jax.random.normal(jax.random.PRNGKey(4), (B, KVH, D))
        ctx = jnp.asarray([ctx_prev + 1])
        npage = jnp.asarray([alloc.block_table(0, maxp)[ctx_prev // page]])
        noff = jnp.asarray([ctx_prev % page])
        pk_ref, pv_ref = PK.write_token(pool_k, pool_v, k_new, v_new, npage, noff)
        ref = PK.paged_decode_attention_ref(q, pk_ref, pv_ref, bt, ctx)
        mesh = make_mesh((2, 4), ("data", "model"))
        spec = IT.ItppSpec(("data", "model"), ("data", "model"), None, 8, 8, page)
        f = IT.make_itpp_attention(mesh, spec, max_pages_per_req=maxp)
        out, _, _ = jax.jit(f)(q, k_new, v_new, pool_k, pool_v, bt, ctx,
                               npage, noff, 0)
        assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-5
    """)


@pytest.mark.slow
def test_sharded_prefill_writer_matches_global():
    """Blocked page allocation + shard-local prefill scatter (§Perf P1) must
    produce the identical pool as the global reference writer."""
    run_devprog("""
        from repro.core import paged_kv as PK, itpp as IT
        from repro.core.allocator import PageAllocator
        # production layout: pool pages sharded over (data, model) = 8,
        # requests row-affine to data rows, blocked striping over the row's
        # model shards so the seq-sharded writes stay local
        B, S, page, KVH, D = 2, 32, 4, 2, 8
        maxp = S // page
        stripe = 4                 # model axis size
        chunk = maxp // stripe
        alloc = PageAllocator(32, 8, page, policy="row_affine", n_rows=2,
                              blocked_chunk=chunk)
        bts = []
        for b in range(B):
            alloc.admit(b, S, row=b)
            bts.append(alloc.block_table(b, maxp))
        bt = jnp.asarray(np.stack(bts))
        key = jax.random.PRNGKey(0)
        k = jax.random.normal(key, (B, S, KVH, D))
        v = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, D))
        pool_k = jnp.zeros((32, page, KVH, D))
        pool_v = jnp.zeros((32, page, KVH, D))
        ref_k, ref_v = PK.write_prefill(pool_k, pool_v, k, v, bt)
        mesh = make_mesh((2, 4), ("data", "model"))
        spec = IT.ItppSpec(("data", "model"), ("model",), "data", 8, 4, page)
        writer = IT.make_prefill_writer(mesh, spec, seq_axis="model")
        out_k, out_v = jax.jit(writer)(pool_k, pool_v, k, v, bt)
        assert np.abs(np.asarray(out_k) - np.asarray(ref_k)).max() == 0
        assert np.abs(np.asarray(out_v) - np.asarray(ref_v)).max() == 0
    """)


@pytest.mark.slow
@pytest.mark.skipif(not hasattr(__import__("jax"), "shard_map"),
                    reason="nested partial-manual shard_map needs the "
                           "jax>=0.5 shard_map; 0.4.x SPMD partitioning "
                           "rejects PartitionId inside the manual region")
def test_pp_decode_matches_forward():
    """GPipe decode over the pod axis (nested ITPP+TP inside partial-manual
    shard_map) must equal the plain full-sequence forward."""
    run_devprog("""
        from dataclasses import replace
        from repro.configs import get_config, reduced, ParallelConfig, ShapeConfig
        from repro.core.allocator import PageAllocator
        from repro.core.paged_kv import PoolSpec
        from repro.distributed.sharding import make_plan
        from repro.distributed.pipeline import make_pp_decode_step
        from repro.models import model as MDL
        cfg = replace(reduced(get_config("llama3.2-1b"), layers=4),
                      dtype="float32")
        params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B, S, page, mbs = 4, 12, 4, 2
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        logits_ref, _ = MDL.forward(cfg, params, toks)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("d", "decode", S, B)
        parallel = ParallelConfig(dp=2, tp=2, pods=2, page_size=page)
        plan = make_plan(mesh, parallel, shape, pod_mode="pp")
        maxp = S // page + 1
        pool = PoolSpec(cfg.n_layers, 16, page, cfg.n_kv_heads, cfg.d_head,
                        maxp, dtype="float32")
        state = MDL.init_decode_state(cfg, pool, B, dtype="float32")
        alloc = PageAllocator(16, 4, page, policy="row_affine", n_rows=2)
        bts = []
        for b in range(B):
            alloc.admit(b, S, row=b % 2)   # request b -> data shard b % mb
            bts.append(alloc.block_table(b, maxp))
        bt = np.stack(bts)
        S_pre = 8
        _, state = MDL.prefill(cfg, params, state, toks[:, :S_pre],
                               jnp.asarray(bt))
        step = make_pp_decode_step(cfg, plan, parallel, pool, n_stages=2,
                                   microbatches=mbs)
        jstep = jax.jit(step)
        for t in range(S_pre, S):
            batch = {"tokens": toks[:, t], "bt": jnp.asarray(bt),
                     "ctx": jnp.full((B,), t + 1, jnp.int32),
                     "npage": jnp.asarray([bts[b][t // page]
                                           for b in range(B)]),
                     "noff": jnp.full((B,), t % page, jnp.int32)}
            lg, state = jstep(params, state, batch)
            err = np.abs(np.asarray(lg)
                         - np.asarray(logits_ref[:, t])).max()
            assert err < 5e-3, (t, err)
    """)


@pytest.mark.slow
def test_train_step_sharded_matches_single_device():
    """FSDP-sharded train step == single-device train step (same batch)."""
    run_devprog("""
        from dataclasses import replace
        from repro.configs import get_config, reduced, ParallelConfig, SHAPES, ShapeConfig
        from repro.distributed.sharding import make_plan
        from repro.models import model as MDL
        from repro.training import optimizer as OPT
        from repro.training.train import make_train_step
        cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
        params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        B, S = 8, 16
        batch = {
          "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size),
          "targets": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
          "mask": jnp.ones((B, S), jnp.float32)}
        opt_cfg = OPT.AdamWConfig(lr=1e-3)
        ref_step = jax.jit(make_train_step(cfg, MDL.DEFAULT_RT, opt_cfg))
        p_ref, _, m_ref = ref_step(params, OPT.init(params), batch)
        mesh = make_mesh((2, 4), ("data", "model"))
        shp = ShapeConfig("t", "train", S, B)
        plan = make_plan(mesh, ParallelConfig(dp=2, tp=4), shp)
        rt = plan.make_runtime(cfg, ParallelConfig(remat=False), mode="train")
        step = make_train_step(cfg, rt, opt_cfg)
        pspec = plan.param_specs(params, mode="train")
        in_sh = (plan.named(pspec),
                 plan.named({"m": pspec, "v": pspec,
                             "step": jax.sharding.PartitionSpec()}),
                 None)
        jstep = jax.jit(step, in_shardings=in_sh)
        p_sh, _, m_sh = jstep(params, OPT.init(params), batch)
        assert abs(float(m_sh["loss"]) - float(m_ref["loss"])) < 1e-4
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
        assert d < 1e-4, d
    """)
