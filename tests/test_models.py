"""Per-arch smoke + forward/prefill/decode consistency for all 10 assigned
architectures (reduced same-family configs, per the assignment)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced
from repro.core.allocator import PageAllocator
from repro.core.paged_kv import PoolSpec
from repro.models import model as MDL


def build(name):
    cfg = replace(reduced(get_config(name)), dtype="float32")
    if cfg.is_moe:
        cfg = replace(cfg, capacity_factor=8.0)   # dropless for consistency
    return cfg


def make_inputs(cfg, B, S, S_pre, key=3):
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        ee = jax.random.normal(jax.random.PRNGKey(key),
                               (B, S, cfg.d_model)) * 0.02
        kw["extra_embeds"] = ee.at[:, S_pre:].set(0)
        kw["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    """Assignment requirement: reduced config, one forward/train step on CPU,
    output shapes + no NaNs."""
    cfg = build(arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    kw = make_inputs(cfg, B, S, S)
    logits, aux = MDL.forward(cfg, params, toks, **kw)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    batch = {"tokens": toks, "targets": toks, "mask": jnp.ones((B, S)), **kw}
    loss, _ = MDL.train_loss(cfg, params, batch)
    grads = jax.grad(lambda p: MDL.train_loss(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_consistency(arch):
    """decode with the paged cache must match the full-sequence forward."""
    cfg = build(arch)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S, S_pre, page = 2, 12, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    kw = make_inputs(cfg, B, S, S_pre)
    logits, _ = MDL.forward(cfg, params, toks, **kw)

    n_attn = cfg.n_layers if cfg.family == "encdec" else \
        sum(1 for k in cfg.block_kinds() if k in ("attn", "local"))
    maxp = S // page + 1
    spec = PoolSpec(max(n_attn, 1), 32, page, cfg.n_kv_heads, cfg.d_head,
                    maxp, dtype="float32")
    state = MDL.init_decode_state(cfg, spec, B, dtype="float32")
    alloc = PageAllocator(32, 1, page)
    bts = []
    for b in range(B):
        alloc.admit(b, S)
        bts.append(alloc.block_table(b, maxp))
    bt = jnp.asarray(np.stack(bts))
    kw_pre = dict(kw)
    if cfg.family == "vlm":
        kw_pre["positions"] = kw["positions"][:, :, :S_pre]
        kw_pre["extra_embeds"] = kw["extra_embeds"][:, :S_pre]
    last, state = MDL.prefill(cfg, params, state, toks[:, :S_pre], bt, **kw_pre)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, S_pre - 1]),
                               rtol=3e-4, atol=3e-4)
    for t in range(S_pre, S):
        ctx = jnp.full((B,), t + 1, jnp.int32)
        npage = jnp.asarray([bts[b][t // page] for b in range(B)])
        noff = jnp.full((B,), t % page, jnp.int32)
        pos = None
        if cfg.family == "vlm":
            pos = jnp.broadcast_to(jnp.full((B, 1), t)[None],
                                   (3, B, 1)).astype(jnp.int32)
        lg, state = MDL.decode_step(cfg, params, state, toks[:, t], bt, ctx,
                                    npage, noff, positions=pos)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   rtol=4e-3, atol=4e-3)


def test_sliding_window_ring_pool_matches_full_pool():
    """mixtral-style SWA: the window-capped ring pool must reproduce the
    unbounded pool's logits exactly (DPA bounded reuse)."""
    from repro.models.model import Runtime
    cfg = replace(build("mixtral-8x22b"), sliding_window=6)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S, page = 1, 16, 2
    S_pre = 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    def run(ring: bool):
        W = (cfg.sliding_window + page) // page + 1 if ring else S // page + 1
        n_attn = cfg.n_layers
        spec = PoolSpec(n_attn, 32, page, cfg.n_kv_heads, cfg.d_head, W,
                        dtype="float32", ring=ring)
        rt = Runtime(ring_width=W if ring else 0)
        state = MDL.init_decode_state(cfg, spec, B, dtype="float32")
        alloc = PageAllocator(32, 1, page,
                              ring_pages=W if ring else None)
        alloc.admit(0, S)
        bt_np = alloc.block_table(0, W)
        bt = jnp.asarray(bt_np[None])
        last, state = MDL.prefill(cfg, params, state, toks[:, :S_pre], bt,
                                  rt=rt)
        outs = [np.asarray(last)]
        for t in range(S_pre, S):
            ctx = jnp.full((B,), t + 1, jnp.int32)
            vp = (t // page) % W if ring else t // page
            npage = jnp.asarray([bt_np[vp]])
            noff = jnp.full((B,), t % page, jnp.int32)
            lg, state = MDL.decode_step(cfg, params, state, toks[:, t], bt,
                                        ctx, npage, noff, rt=rt)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    np.testing.assert_allclose(run(True), run(False), rtol=2e-4, atol=2e-4)
