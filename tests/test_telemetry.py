"""Telemetry correctness: deterministic-counter exactness against the
subsystems' own ground truth across prefill modes / preemption / spec
decoding, per-request records reproducing the engine's TTFT, Prometheus
exposition + Perfetto trace round-trips, and the no-op-sink identity (a
telemetry-disabled engine is token- and sync-count-identical)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as MDL
from repro.serving import DecodeEngine, EngineConfig
from repro.telemetry import (NULL, TelemetryConfig, make_telemetry,
                             parse_exposition, percentile, validate_trace)
from repro.telemetry.chrome_trace import ENGINE_PID, TRACKS
from repro.serving import Request as Req

PAGE = 4
BUDGETS = [3, 12, 5, 12, 2, 9]
_SHARED = {}


def _setup():
    if "cfg" not in _SHARED:
        cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
        _SHARED["cfg"] = cfg
        _SHARED["params"] = MDL.init_params(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    return _SHARED["cfg"], _SHARED["params"]


def _run(K=4, mode="batched", *, telemetry="on", n_pages=96, cache=False,
         host_pages=0, budgets=BUDGETS, nreq=6, spec=False, spec_horizon=3,
         trace=True):
    cfg, params = _setup()
    tel = (TelemetryConfig(metrics=True, trace=trace)
           if telemetry == "on" else None)
    ecfg = EngineConfig(n_slots=3, page_size=PAGE, n_pages=n_pages,
                        max_context=64, eos_token=-1, prefill_mode=mode,
                        prefill_chunk=5,
                        decode_horizon=1 if spec else K,
                        prefix_cache=cache, host_pages=host_pages,
                        draft_config=cfg if spec else None,
                        spec_horizon=spec_horizon, telemetry=tel)
    eng = DecodeEngine(cfg, ecfg, params,
                       draft_params=params if spec else None)
    rng = np.random.default_rng(3)
    for r in range(nreq):
        p = rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 20)))
        eng.submit(Req(r, p, budgets[r % len(budgets)]))
    outs = eng.run(3000)
    return {k: list(v) for k, v in outs.items()}, eng


# ---------------------------------------------------------------------------
# deterministic counter exactness vs subsystem ground truth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,mode", [(1, "batched"), (4, "slot"),
                                    (4, "batched"), (4, "chunked")])
def test_counters_match_ground_truth(K, mode):
    """Every registry sample equals the authoritative counter it binds:
    decode tokens, device syncs, scheduler admit/complete, per-request
    token totals — across the per-token and fused paths in every prefill
    mode."""
    outs, eng = _run(K, mode)
    g = eng.tel.registry.get
    t, st = eng.timing, eng.batcher.stats
    assert g("engine_decode_tokens_total") == t.decode_tokens
    assert g("engine_device_syncs_total") == t.device_syncs
    assert g("engine_steps_total") == t.steps
    assert g("sched_admitted_total") == st.admitted
    assert g("sched_completed_total") == st.completed == len(outs)
    assert g("sched_preempted_total") == st.preempted
    # tracker-side totals: one record per request, tokens add up exactly
    recs = {r.req_id: r for r in eng.tel.tracker.records}
    assert len(recs) == len(outs) and all(r.finished for r in recs.values())
    for rid, toks in outs.items():
        assert recs[rid].tokens == len(toks), rid
    total = sum(len(v) for v in outs.values())
    assert g("requests_finished_total") == len(outs)
    assert g("request_tokens_total") == total
    assert g("requests_live") == 0
    # pool drained, peak high-water recorded
    assert g("kv_pages_in_use", {"tier": "device"}) == 0
    assert g("kv_pages_in_use_peak", {"tier": "device"}) > 0


def test_preemption_resume_counters():
    """A pool-starved run preempts; the tracker's per-request preemption /
    resume counts reconcile exactly with SchedulerStats (every preempted
    request that finished was re-admitted)."""
    outs, eng = _run(4, n_pages=10, nreq=2, budgets=[12, 12])
    st = eng.batcher.stats
    assert st.preempted > 0 and st.completed == 2
    recs = eng.tel.tracker.records
    assert sum(r.preemptions for r in recs) == st.preempted
    assert sum(r.resumes for r in recs) == st.preempted
    assert st.admitted == len(recs) + sum(r.resumes for r in recs)
    assert eng.tel.registry.get("sched_preempted_total") == st.preempted
    assert eng.tel.summary()["preemptions"] == st.preempted


def test_spec_accept_counters():
    """Speculative run with an oracle draft (draft == target): accepted ==
    proposed > 0, and the registry / per-record accounting both equal the
    engine's own spec counters."""
    outs, eng = _run(spec=True)
    assert eng.spec_rounds > 0
    assert eng.spec_accepted == eng.spec_proposed > 0
    g = eng.tel.registry.get
    assert g("spec_rounds_total") == eng.spec_rounds
    assert g("spec_proposed_total") == eng.spec_proposed
    assert g("spec_accepted_total") == eng.spec_accepted
    recs = eng.tel.tracker.records
    assert sum(r.spec_accepted for r in recs) == eng.spec_accepted
    assert sum(r.spec_proposed for r in recs) == eng.spec_proposed
    accl = [r.accept_len_mean for r in recs if r.accept_len_mean is not None]
    assert accl and all(a > 1.0 for a in accl)   # oracle accepts everything


def test_cache_and_host_tier_counters():
    """Prefix-cache + host-tier bindings mirror CacheStats / TierStats
    exactly (swap in/out, lookups, device pages across tiers)."""
    outs, eng = _run(4, cache=True, host_pages=16)
    g = eng.tel.registry.get
    cs, hs = eng.cache.stats, eng.cache.host.stats
    assert g("kv_cache_lookups") == cs.lookups > 0
    assert g("kv_cache_hits") == cs.hits
    assert g("kv_cache_hit_tokens") == cs.hit_tokens
    assert g("kv_cache_evicted_pages") == cs.evicted_pages
    assert g("kv_swapped_out_pages") == hs.swapped_out_pages
    assert g("kv_swapped_in_pages") == hs.swapped_in_pages
    assert g("kv_pages_total", {"tier": "host"}) == eng.cache.host.capacity
    assert g("kv_pages_in_use", {"tier": "host"}) == eng.cache.host.used


def test_modeled_pim_counters():
    """Modeled HBM bytes accumulate as exact multiples of the model's
    kv_bytes_per_token; channel util stays in [0, 1]; the pow2 bucket
    high-water is a real bucket width."""
    outs, eng = _run(4)
    g = eng.tel.registry.get
    bpt = eng.tel.pim.kv_bytes_per_token()
    cfg = eng.cfg
    assert bpt == 2 * cfg.n_kv_heads * cfg.d_head * 2 * cfg.n_layers
    v = g("pim_modeled_hbm_bytes_total")
    assert v > 0
    assert abs(v / bpt - round(v / bpt)) < 1e-6   # integer token-ctx sum
    assert 0.0 <= g("pim_channel_util") <= 1.0
    hw = int(g("decode_table_bucket_highwater"))
    assert hw >= 1 and (hw & (hw - 1)) == 0 or hw == eng.batcher._bt_width
    assert 0.0 <= g("dpa_page_waste_ratio") <= 1.0


# ---------------------------------------------------------------------------
# per-request records == the bench's latency source of truth
# ---------------------------------------------------------------------------

def test_records_reproduce_engine_ttft():
    """Record-derived TTFT equals the engine's legacy first_tok_t-submit_t
    to float identity, and queue/ttft/tpot orderings are sane."""
    outs, eng = _run(4)
    for r in eng.tel.tracker.records:
        legacy = eng.first_tok_t[r.req_id] - eng.submit_t[r.req_id]
        assert abs(r.ttft_s - legacy) < 1e-9, r.req_id
        assert r.queue_s is not None and 0 <= r.queue_s <= r.ttft_s
        if r.tokens >= 2:
            assert r.tpot_s is not None and r.tpot_s >= 0
            assert r.finish_t >= r.first_token_t >= r.submit_t
    sm = eng.tel.summary()
    assert sm["finished"] == len(outs)
    assert sm["ttft_p50_ms"] <= sm["ttft_p99_ms"]


def test_percentile_nearest_rank():
    assert percentile([], 50) == 0.0
    assert percentile([7.0], 99) == 7.0
    vs = [50.0, 10.0, 40.0, 20.0, 30.0]
    assert percentile(vs, 0) == 10.0
    assert percentile(vs, 50) == 30.0
    assert percentile(vs, 100) == 50.0


# ---------------------------------------------------------------------------
# exposition + trace round-trips
# ---------------------------------------------------------------------------

def test_prometheus_render_parses():
    outs, eng = _run(4, cache=True, host_pages=16)
    text = eng.tel.registry.render()
    samples = parse_exposition(text)
    assert len(samples) > 40
    # per-tier PIM pool samples present with labels
    assert 'repro_kv_pages_total{tier="device"}' in samples
    assert 'repro_kv_pages_total{tier="host"}' in samples
    assert samples["repro_engine_decode_tokens_total"] == \
        eng.timing.decode_tokens
    # histogram series integrity (bucket monotonicity spot check)
    buckets = sorted((k, v) for k, v in samples.items()
                     if k.startswith("repro_request_ttft_seconds_bucket"))
    assert buckets
    assert samples["repro_request_ttft_seconds_count"] == len(outs)


def test_parse_exposition_rejects_malformed():
    with pytest.raises(ValueError):
        parse_exposition("this is not a metric line !!!\n")
    with pytest.raises(ValueError):
        parse_exposition("# TYPE foo banana\nfoo 1\n")
    with pytest.raises(ValueError):
        parse_exposition("# TYPE h histogram\nh_sum 1\nh_count 1\n")


def test_trace_has_pipeline_tracks():
    """The fused tick writes host / dispatch / sync slices on distinct
    engine tracks plus the inferred device span overlapping them (the DCS
    picture), and per-request spans under the request pid."""
    outs, eng = _run(8)
    doc = eng.tel.trace.to_doc()
    info = validate_trace(doc)
    assert info["events"] > 0 and info["slices"] > 0
    for track in ("host", "dispatch", "sync", "device"):
        assert (ENGINE_PID, TRACKS[track]) in info["tracks"], track
    # device spans (ph b/e) overlap the horizon: at least one per sync-ish
    dev = [e for e in doc["traceEvents"]
           if e.get("ph") == "b" and e.get("tid") == TRACKS["device"]]
    assert dev
    # request-lifecycle slices exist for finished requests (requests pid)
    req = {e["name"] for e in doc["traceEvents"]
           if e.get("pid") != ENGINE_PID and e.get("ph") == "X"}
    assert {"queue", "prefill", "decode"} <= req


# ---------------------------------------------------------------------------
# no-op sink: disabled telemetry is behavior-identical
# ---------------------------------------------------------------------------

def test_disabled_telemetry_identity():
    """telemetry=None produces token-identical outputs with the SAME
    device-sync and decode-token counts as an instrumented run — the
    telemetry layer adds no rendezvous — and installs nothing: no events
    hook, no registry entries, shared NULL facade."""
    base, e_off = _run(4, telemetry="off")
    got, e_on = _run(4, telemetry="on")
    assert got == base
    assert e_on.timing.device_syncs == e_off.timing.device_syncs
    assert e_on.timing.decode_tokens == e_off.timing.decode_tokens
    assert e_off.tel is NULL and not e_off.tel.enabled
    assert e_off.batcher.events is None
    assert e_off.tel.registry.render() == "\n"        # renders empty
    assert e_off.tel.save_trace() is None
    e_off.tel.close()                                  # no-ops don't raise


def test_make_telemetry_dispatch():
    from repro.telemetry import Telemetry
    assert make_telemetry(None) is NULL
    assert make_telemetry(False) is NULL
    assert make_telemetry(TelemetryConfig(metrics=False)) is NULL
    live = make_telemetry(TelemetryConfig(metrics=True))
    assert isinstance(live, Telemetry) and live.enabled
    assert make_telemetry(live) is live
    assert make_telemetry(NULL) is NULL
    with pytest.raises(TypeError):
        make_telemetry(42)
