"""PIM cost-model properties + paper-claim tolerances."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import pim_model as PM

KW = dict(avg_ctx=16362, max_ctx=32768, ctx_cv=0.1)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([2, 4, 8, 16]), lvl=st.integers(0, 3))
def test_more_nodes_never_slower(n, lvl):
    a = PM.throughput(PM.lol_pim(n, level=lvl), PM.QWEN_7B, **KW)
    b = PM.throughput(PM.lol_pim(2 * n, level=lvl), PM.QWEN_7B, **KW)
    assert b["tokens_per_s"] >= a["tokens_per_s"] * 0.95


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([4, 8, 16]))
def test_each_technique_level_helps(n):
    t = [PM.throughput(PM.lol_pim(n, level=l), PM.QWEN_7B, **KW)
         ["tokens_per_s"] for l in (0, 1, 2, 3)]
    assert t[3] >= t[2] >= t[0] * 0.9
    assert t[3] > 1.5 * t[0]          # the paper's combined >=2x at scale


def test_lazy_alloc_batch_ratio():
    base = PM.max_batch(PM.lol_pim(4, level=0), PM.QWEN_7B, 8192, 32768)
    lazy = PM.max_batch(PM.lol_pim(4, level=2), PM.QWEN_7B, 8192, 32768)
    assert lazy >= 3 * base            # ~max_ctx/avg_ctx = 4x (paper: 380%)


def test_pingpong_never_hurts():
    for n in (2, 8):
        a = PM.decode_latency(PM.lol_pim(n, level=2), PM.QWEN_7B, 32, 16384)
        b = PM.decode_latency(PM.lol_pim(n, level=3), PM.QWEN_7B, 32, 16384)
        assert b["t_step"] <= a["t_step"] + 1e-9


def test_table8_within_tolerance():
    rows = {"7B": (4, PM.QWEN_7B, (1833, 2455, 3668)),
            "14B": (5, PM.QWEN_14B, (1309, 1737, 2553)),
            "72B": (16, PM.QWEN_72B, (737, 1211, 1740))}
    kw = dict(avg_ctx=16362, max_ctx=32768, ctx_cv=1651 / 16362)
    for name, (n, m, tg) in rows.items():
        for lvl, t in zip((0, 2, 3), tg):
            r = PM.throughput(PM.lol_pim(n, level=lvl), m, **kw)
            err = abs(r["tokens_per_s"] - t) / t
            assert err < 0.25, (name, lvl, r["tokens_per_s"], t)


def test_72b_headline_ratio():
    """Paper §8.2: 72B LoL-PIM vs baseline PIM = 2.65x at 1 TB."""
    kw = dict(avg_ctx=16362, max_ctx=32768, ctx_cv=0.1)
    lol = PM.throughput(PM.lol_pim(16, level=3), PM.QWEN_72B, **kw)
    base = PM.throughput(PM.lol_pim(16, level=0), PM.QWEN_72B, **kw)
    ratio = lol["tokens_per_s"] / base["tokens_per_s"]
    assert 2.0 < ratio < 3.5, ratio
