"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.backend import KernelConfig, default_interpret
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssm_scan import ssm_chunk_scan
from repro.serving import Request as Req

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KVH,G,D,page,maxp", [
    (2, 1, 1, 8, 4, 3),
    (3, 2, 4, 16, 8, 4),
    (1, 4, 2, 32, 16, 2),
])
def test_paged_attention_sweep(dtype, B, KVH, G, D, page, maxp):
    key = jax.random.PRNGKey(B + D)
    P_ = B * maxp + 2
    q = jax.random.normal(key, (B, KVH, G, D), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D))
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D))
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)[:B * maxp]
                     .reshape(B, maxp).astype(np.int32))
    ctx = jnp.asarray(np.random.default_rng(1).integers(
        1, maxp * page + 1, B).astype(np.int32))
    out = paged_attention(q.astype(dtype), kp.astype(dtype), vp.astype(dtype),
                          bt, ctx, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_splits", [1, 3])
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("B,KVH,G,T,D,page,maxp", [
    (2, 1, 1, 3, 8, 4, 4),
    (3, 2, 2, 5, 16, 4, 5),
])
def test_paged_attention_verify_sweep(dtype, n_splits, window, B, KVH, G, T,
                                      D, page, maxp):
    """Multi-query verify kernel vs the gather-then-dense oracle: T query
    rows per slot at positions ctx-1..ctx+T-2 (speculative verify), causal
    frontier advancing per row, partial last pages, optional window."""
    from repro.kernels.paged_attention import paged_attention_verify
    P_ = B * maxp + 2
    q = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, G, T, D),
                          jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D))
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D))
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)[:B * maxp]
                     .reshape(B, maxp).astype(np.int32))
    # ctx counts tokens INCLUDING the first query row; leave T-1 slots of
    # page headroom so the verify rows all fit in the table
    ctx = jnp.asarray(np.random.default_rng(1).integers(
        1, maxp * page - T + 2, B).astype(np.int32))
    w = None if window is None else jnp.full((B,), window, jnp.int32)
    out = paged_attention_verify(
        q.astype(dtype), kp.astype(dtype), vp.astype(dtype), bt, ctx,
        window=w, n_splits=n_splits, interpret=True)
    want = ref.paged_attention_verify_ref(q, kp, vp, bt, ctx, window=w)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_attention_verify_degenerates_to_decode():
    """T=1 verify must be bit-comparable to the plain decode kernel (same
    math, qpos=1 mask degenerates to tok < ctx)."""
    from repro.kernels.paged_attention import paged_attention_verify
    B, KVH, G, D, page, maxp = 2, 2, 3, 16, 4, 4
    P_ = B * maxp + 1
    q = jax.random.normal(jax.random.PRNGKey(0), (B, KVH, G, 1, D))
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D))
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D))
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)[:B * maxp]
                     .reshape(B, maxp).astype(np.int32))
    ctx = jnp.asarray([5, maxp * page], np.int32)
    out = paged_attention_verify(q, kp, vp, bt, ctx, interpret=True)
    want = paged_attention(q[:, :, :, 0], kp, vp, bt, ctx, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, :, :, 0]), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KVH,G,D,T,S", [
    (2, 2, 3, 16, 32, 4),
    (1, 1, 8, 32, 64, 8),
    (4, 2, 1, 8, 16, 2),
])
def test_flash_decode_sweep(dtype, B, KVH, G, D, T, S):
    key = jax.random.PRNGKey(T)
    q = jax.random.normal(key, (B, KVH, G, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KVH, D))
    ctx = jnp.asarray(np.random.default_rng(0).integers(1, T + 1, B),
                      jnp.int32)
    o, l, m = flash_decode(q.astype(dtype), k.astype(dtype), v.astype(dtype),
                           ctx, n_splits=S, interpret=True)
    oref, lref, mref = ref.flash_decode_ref(q, k, v, ctx, S)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               atol=TOL[dtype] * 5, rtol=TOL[dtype] * 5)
    # merged partials == dense attention (the ITPP/EPU merge identity)
    merged = ref.merge_flash_partials(o, l, m)
    from repro.models.layers import decode_attention_ref
    dense = decode_attention_ref(q.reshape(B, KVH * G, D), k, v, ctx)
    np.testing.assert_allclose(np.asarray(merged.reshape(B, KVH * G, D)),
                               np.asarray(dense), atol=TOL[dtype] * 5,
                               rtol=TOL[dtype] * 5)


@pytest.mark.parametrize("B,S,H,N,P,chunk", [
    (2, 32, 3, 8, 16, 8),
    (1, 64, 1, 4, 4, 16),
    (3, 16, 2, 16, 8, 4),
])
def test_ssm_scan_sweep(B, S, H, N, P, chunk):
    key = jax.random.PRNGKey(S)
    q = jax.random.normal(key, (B, S, H, N))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, N))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, S, H)))
    lg = jax.random.normal(jax.random.PRNGKey(4), (B, S, H)) * 0.1
    y, st_ = ssm_chunk_scan(q, k, v, la, lg, chunk=chunk, interpret=True)
    yref, (Cref, _, _) = ref.ssm_chunk_scan_ref(q, k, v, la, lg, None, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(Cref), atol=1e-4)


def test_ssm_scan_state_carry_and_tail_mask():
    """Chunk-boundary continuation: feeding the returned state back in
    resumes the scan exactly (split-invariance), and ``valid_len`` masks a
    length-bucketed pad tail into identity steps so the returned state
    stops at each row's true last token."""
    B, S, H, N, P = 2, 16, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    q = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    lg = jax.random.normal(ks[4], (B, S, H)) * 0.2
    h0 = jax.random.normal(ks[5], (B, H, N, P)) * 0.3
    h0_ref = (h0, jnp.zeros((B, H, N)), jnp.zeros((B, H)))

    # carry in/out: two half-scans == one full scan from the same state
    y1, s1 = ssm_chunk_scan(q[:, :8], k[:, :8], v[:, :8], la[:, :8],
                            lg[:, :8], chunk=4, state=h0, interpret=True)
    y2, s2 = ssm_chunk_scan(q[:, 8:], k[:, 8:], v[:, 8:], la[:, 8:],
                            lg[:, 8:], chunk=4, state=s1, interpret=True)
    yref, (Cref, _, _) = ref.ssm_chunk_scan_ref(q, k, v, la, lg, h0_ref, 4)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(yref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(Cref), atol=1e-4)

    # masked tail: row 0 valid to 10, row 1 full — pow2 bucketing stays
    # valid because pads never touch the carry
    _, sm = ssm_chunk_scan(q, k, v, la, lg, chunk=4, state=h0,
                           valid_len=jnp.asarray([10, S]), interpret=True)
    _, (C10, _, _) = ref.ssm_chunk_scan_ref(
        q[:1, :10], k[:1, :10], v[:1, :10], la[:1, :10], lg[:1, :10],
        tuple(x[:1] for x in h0_ref), 2)
    np.testing.assert_allclose(np.asarray(sm[0]), np.asarray(C10[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sm[1]), np.asarray(Cref[1]),
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,H,KVH,D,causal,window", [
    (2, 16, 4, 2, 16, True, 0),
    (1, 32, 8, 2, 8, True, 8),
    (2, 16, 4, 4, 16, False, 0),
    (1, 24, 6, 3, 8, True, 5),
])
def test_flash_attention_fwd_sweep(dtype, B, Sq, H, KVH, D, causal, window):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.layers import flash_attention
    key = jax.random.PRNGKey(Sq)
    q = jax.random.normal(key, (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, KVH, D))
    out = flash_attention_fwd(q.astype(dtype), k.astype(dtype),
                              v.astype(dtype), causal=causal, window=window,
                              q_blk=8, kv_blk=8, interpret=True)
    ref = flash_attention(q, k, v, causal=causal, window=window, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=TOL[dtype] * 3, rtol=TOL[dtype] * 3)


# ---------------------------------------------------------------------------
# decode hot path: kernel vs gathered-dense reference, full feature matrix
# ---------------------------------------------------------------------------

def _decode_case(*, G, ring, window, partial_ctx, seed=0):
    """A paged decode step: pool, tables (with -1 pads and one dead batch
    row), per-request ctx (spanning partial pages when asked), and the
    incoming token's K/V + write target."""
    from repro.core.allocator import PageAllocator
    page, maxp, KVH, D, B = 4, 5, 2, 8, 3
    H = KVH * G
    P = B * maxp + 2
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    pool_k = jax.random.normal(key, (P, page, KVH, D), jnp.float32)
    pool_v = jax.random.normal(jax.random.PRNGKey(seed + 1),
                               (P, page, KVH, D), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, H, D))
    k_new = jax.random.normal(jax.random.PRNGKey(seed + 3), (B, KVH, D))
    v_new = jax.random.normal(jax.random.PRNGKey(seed + 4), (B, KVH, D))
    ring_width = maxp if ring else 0
    if ring:
        # wrapped ring: more context than the ring holds
        ctx = np.asarray([maxp * page + 3, maxp * page + 1, 0], np.int32)
    elif partial_ctx:
        ctx = np.asarray([7, maxp * page, 0], np.int32)   # mid-page + full
    else:
        ctx = np.asarray([page, 2 * page, 0], np.int32)
    perm = rng.permutation(P)
    bt = np.full((B, maxp), -1, np.int32)
    npage = np.full((B,), P, np.int32)                    # dead rows drop
    noff = np.zeros((B,), np.int32)
    pos = 0
    for b in range(B):
        if ctx[b] == 0:
            continue
        n_alloc = min(-(-int(ctx[b]) // page), maxp)
        bt[b, :n_alloc] = perm[pos:pos + n_alloc]
        pos += n_alloc
        t = int(ctx[b]) - 1
        vp = (t // page) % ring_width if ring else t // page
        npage[b] = bt[b, vp]
        noff[b] = t % page
    return dict(q=q, k_new=k_new, v_new=v_new, pool_k=pool_k, pool_v=pool_v,
                bt=jnp.asarray(bt), ctx=jnp.asarray(ctx),
                npage=jnp.asarray(npage), noff=jnp.asarray(noff),
                window=window, ring_width=ring_width, page=page, maxp=maxp)


def _run_shard(case, kernels, *, cond_window=0, window=None):
    from repro.core.itpp import ItppSpec, itpp_decode_attention_shard
    spec = ItppSpec((), (), None, 1, 1, case["page"])
    w = case["window"] if window is None else window
    return itpp_decode_attention_shard(
        case["q"], case["k_new"], case["v_new"], case["pool_k"],
        case["pool_v"], case["bt"], case["ctx"], case["npage"], case["noff"],
        w, spec=spec, mesh_axis_sizes={},
        max_pages_per_req=case["maxp"], ring_width=case["ring_width"],
        cond_window=cond_window, kernels=kernels)


@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("ring,window,partial_ctx", [
    (False, 0, False),            # plain, page-aligned ctx
    (False, 0, True),             # ctx mid-page + exactly-full table
    (False, 6, True),             # sliding-window mask
    (True, 9, False),             # ring pool (slots recycle mod width)
    (True, 0, False),             # ring, unwindowed mask
])
@pytest.mark.parametrize("n_splits", [1, 3])
def test_itpp_kernel_matches_gathered_dense(G, ring, window, partial_ctx,
                                            n_splits):
    """The Pallas decode hot path is numerically identical to the
    gather-then-dense reference across the pool feature matrix, including
    the folded-in token write."""
    case = _decode_case(G=G, ring=ring, window=window, partial_ctx=partial_ctx)
    out_d, pk_d, pv_d = _run_shard(case, None)
    out_k, pk_k, pv_k = _run_shard(
        case, KernelConfig(use_pallas=True, interpret=True,
                           n_splits=n_splits))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_array_equal(np.asarray(pk_k), np.asarray(pk_d))
    np.testing.assert_array_equal(np.asarray(pv_k), np.asarray(pv_d))


@pytest.mark.parametrize("window", [0, 6])
def test_itpp_kernel_cond_window_branches(window):
    """cond_window: the windowed-slice kernel (only the table slots
    overlapping the window ride the grid) agrees with the dense path for
    both lax.cond branches of a mixed local:global stack."""
    case = _decode_case(G=2, ring=False, window=window, partial_ctx=True)
    out_d, *_ = _run_shard(case, None, cond_window=8)
    out_k, *_ = _run_shard(
        case, KernelConfig(use_pallas=True, interpret=True), cond_window=8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               atol=3e-5, rtol=3e-5)


def test_itpp_kernel_traced_window_scan():
    """Per-layer window flags ride a scan as data (gemma3-style): the
    kernel path must accept a TRACED window scalar."""
    case = _decode_case(G=2, ring=False, window=0, partial_ctx=True)
    kc = KernelConfig(use_pallas=True, interpret=True)

    def body(carry, w):
        out, *_ = _run_shard(case, kc, window=w)
        return carry, out

    _, outs = jax.jit(lambda ws: jax.lax.scan(body, 0, ws))(
        jnp.asarray([0, 6], jnp.int32))
    for i, w in enumerate((0, 6)):
        ref_out, *_ = _run_shard(case, None, window=jnp.int32(w))
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(ref_out),
                                   atol=3e-5, rtol=3e-5)


def test_flash_decode_tail_split():
    """T that does not divide n_splits: the tail split is padded+masked."""
    B, KVH, G, D, T, S = 2, 2, 2, 8, 21, 4
    q = jax.random.normal(jax.random.PRNGKey(0), (B, KVH, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KVH, D))
    ctx = jnp.asarray([T, 5], jnp.int32)
    o, l, m = flash_decode(q, k, v, ctx, n_splits=S, interpret=True)
    oref, lref, mref = ref.flash_decode_ref(q, k, v, ctx, S)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lref), atol=1e-5)
    merged = ref.merge_flash_partials(o, l, m)
    from repro.models.layers import decode_attention_ref
    dense = decode_attention_ref(q.reshape(B, KVH * G, D), k, v, ctx)
    np.testing.assert_allclose(
        np.asarray(merged.reshape(B, KVH * G, D)), np.asarray(dense),
        atol=1e-5, rtol=1e-5)


def test_backend_autodetect(monkeypatch):
    """interpret defaults ride the backend; REPRO_KERNEL_INTERPRET wins."""
    import repro.kernels.backend as BK
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    assert default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
    assert BK.default_interpret() is False
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert BK.default_interpret() is True
    kc = KernelConfig().resolve()
    assert kc.use_pallas == BK.on_tpu() and kc.interpret is True


# ---------------------------------------------------------------------------
# end-to-end: engine greedy decode, kernel path on vs off
# ---------------------------------------------------------------------------

def _serve(cfg, params, **ecfg_kw):
    from repro.serving import DecodeEngine, EngineConfig
    kw = dict(n_slots=2, page_size=4, n_pages=48, max_context=32,
              eos_token=-1, prefill_mode="batched")
    kw.update(ecfg_kw)
    eng = DecodeEngine(cfg, EngineConfig(**kw), params)
    rng = np.random.default_rng(3)
    for r in range(3):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                   size=int(rng.integers(4, 14))), 4))
    outs = eng.run(300)
    assert eng.batcher.stats.completed == 3
    return {k: list(v) for k, v in outs.items()}


@pytest.mark.slow
def test_engine_kernel_token_identity():
    """Greedy decode through the serving engine is token-identical with the
    Pallas decode hot path on vs the gathered-dense path."""
    from repro.configs import get_config, reduced
    from dataclasses import replace
    from repro.models import model as MDL
    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    dense = _serve(cfg, params, use_pallas=False)
    kernel = _serve(cfg, params, use_pallas=True, kernel_interpret=True)
    assert kernel == dense


@pytest.mark.slow
def test_engine_decode_bucketing_token_identity():
    """pow2 live-page bucketing of the decode table (maxp > 16) does not
    change greedy outputs."""
    from repro.configs import get_config, reduced
    from dataclasses import replace
    from repro.models import model as MDL
    cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    kw = dict(page_size=2, n_pages=96, max_context=60)   # maxp = 31 > 16
    full = _serve(cfg, params, decode_bucket=False, **kw)
    bucketed = _serve(cfg, params, decode_bucket=True, **kw)
    assert bucketed == full


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_merge_partials_property(data):
    """Property: stable merge of ANY split of the KV is split-invariant."""
    B = data.draw(st.integers(1, 3))
    KVH = data.draw(st.sampled_from([1, 2]))
    G = data.draw(st.sampled_from([1, 2, 4]))
    D = data.draw(st.sampled_from([8, 16]))
    T = 32
    key = jax.random.PRNGKey(data.draw(st.integers(0, 100)))
    q = jax.random.normal(key, (B, KVH, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KVH, D))
    ctx = jnp.asarray(data.draw(st.lists(st.integers(1, T), min_size=B,
                                         max_size=B)), jnp.int32)
    merged = {}
    for s in (1, 2, 4, 8):
        o, l, m = ref.flash_decode_ref(q, k, v, ctx, s)
        merged[s] = np.asarray(ref.merge_flash_partials(o, l, m))
    for s in (2, 4, 8):
        np.testing.assert_allclose(merged[s], merged[1], atol=2e-5, rtol=2e-5)


def test_partials_split_axis_leads_grid_and_is_split_invariant():
    """The split-K axis now LEADS the pallas grid (parallel dimension
    semantics for megacore partitioning): the partials land split-major
    [S, B, KVH, G, ...] and the MERGED attention is numerically identical
    for every n_splits — the 'no numeric change' contract of threading
    dimension_semantics through."""
    from repro.kernels.paged_attention import paged_attention_partials
    B, KVH, G, D, page, maxp = 2, 2, 3, 16, 4, 6
    P_ = B * maxp + 1
    q = jax.random.normal(jax.random.PRNGKey(0), (B, KVH, G, D))
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D))
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D))
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)[:B * maxp]
                     .reshape(B, maxp).astype(np.int32))
    ctx = jnp.asarray([maxp * page, 7], jnp.int32)
    merged = {}
    for s in (1, 2, 3, 6):
        o, l, m = paged_attention_partials(q, kp, vp, bt, ctx, n_splits=s,
                                           interpret=True)
        assert o.shape == (s, B, KVH, G, D)
        assert l.shape == m.shape == (s, B, KVH, G)
        oo, ll, _ = ref.combine_partials(o, l, m)
        merged[s] = np.asarray(oo / np.maximum(np.asarray(ll), 1e-30)[..., None])
    for s in (2, 3, 6):
        np.testing.assert_allclose(merged[s], merged[1], atol=2e-5, rtol=2e-5)
