"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:              # graceful fallback: example-based driver
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssm_scan import ssm_chunk_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KVH,G,D,page,maxp", [
    (2, 1, 1, 8, 4, 3),
    (3, 2, 4, 16, 8, 4),
    (1, 4, 2, 32, 16, 2),
])
def test_paged_attention_sweep(dtype, B, KVH, G, D, page, maxp):
    key = jax.random.PRNGKey(B + D)
    P_ = B * maxp + 2
    q = jax.random.normal(key, (B, KVH, G, D), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D))
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D))
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)[:B * maxp]
                     .reshape(B, maxp).astype(np.int32))
    ctx = jnp.asarray(np.random.default_rng(1).integers(
        1, maxp * page + 1, B).astype(np.int32))
    out = paged_attention(q.astype(dtype), kp.astype(dtype), vp.astype(dtype),
                          bt, ctx, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KVH,G,D,T,S", [
    (2, 2, 3, 16, 32, 4),
    (1, 1, 8, 32, 64, 8),
    (4, 2, 1, 8, 16, 2),
])
def test_flash_decode_sweep(dtype, B, KVH, G, D, T, S):
    key = jax.random.PRNGKey(T)
    q = jax.random.normal(key, (B, KVH, G, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KVH, D))
    ctx = jnp.asarray(np.random.default_rng(0).integers(1, T + 1, B),
                      jnp.int32)
    o, l, m = flash_decode(q.astype(dtype), k.astype(dtype), v.astype(dtype),
                           ctx, n_splits=S, interpret=True)
    oref, lref, mref = ref.flash_decode_ref(q, k, v, ctx, S)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               atol=TOL[dtype] * 5, rtol=TOL[dtype] * 5)
    # merged partials == dense attention (the ITPP/EPU merge identity)
    merged = ref.merge_flash_partials(o, l, m)
    from repro.models.layers import decode_attention_ref
    dense = decode_attention_ref(q.reshape(B, KVH * G, D), k, v, ctx)
    np.testing.assert_allclose(np.asarray(merged.reshape(B, KVH * G, D)),
                               np.asarray(dense), atol=TOL[dtype] * 5,
                               rtol=TOL[dtype] * 5)


@pytest.mark.parametrize("B,S,H,N,P,chunk", [
    (2, 32, 3, 8, 16, 8),
    (1, 64, 1, 4, 4, 16),
    (3, 16, 2, 16, 8, 4),
])
def test_ssm_scan_sweep(B, S, H, N, P, chunk):
    key = jax.random.PRNGKey(S)
    q = jax.random.normal(key, (B, S, H, N))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, N))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, S, H)))
    lg = jax.random.normal(jax.random.PRNGKey(4), (B, S, H)) * 0.1
    y, st_ = ssm_chunk_scan(q, k, v, la, lg, chunk=chunk, interpret=True)
    yref, (Cref, _, _) = ref.ssm_chunk_scan_ref(q, k, v, la, lg, None, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(Cref), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,H,KVH,D,causal,window", [
    (2, 16, 4, 2, 16, True, 0),
    (1, 32, 8, 2, 8, True, 8),
    (2, 16, 4, 4, 16, False, 0),
    (1, 24, 6, 3, 8, True, 5),
])
def test_flash_attention_fwd_sweep(dtype, B, Sq, H, KVH, D, causal, window):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models.layers import flash_attention
    key = jax.random.PRNGKey(Sq)
    q = jax.random.normal(key, (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sq, KVH, D))
    out = flash_attention_fwd(q.astype(dtype), k.astype(dtype),
                              v.astype(dtype), causal=causal, window=window,
                              q_blk=8, kv_blk=8, interpret=True)
    ref = flash_attention(q, k, v, causal=causal, window=window, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=TOL[dtype] * 3, rtol=TOL[dtype] * 3)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_merge_partials_property(data):
    """Property: stable merge of ANY split of the KV is split-invariant."""
    B = data.draw(st.integers(1, 3))
    KVH = data.draw(st.sampled_from([1, 2]))
    G = data.draw(st.sampled_from([1, 2, 4]))
    D = data.draw(st.sampled_from([8, 16]))
    T = 32
    key = jax.random.PRNGKey(data.draw(st.integers(0, 100)))
    q = jax.random.normal(key, (B, KVH, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, KVH, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, KVH, D))
    ctx = jnp.asarray(data.draw(st.lists(st.integers(1, T), min_size=B,
                                         max_size=B)), jnp.int32)
    merged = {}
    for s in (1, 2, 4, 8):
        o, l, m = ref.flash_decode_ref(q, k, v, ctx, s)
        merged[s] = np.asarray(ref.merge_flash_partials(o, l, m))
    for s in (2, 4, 8):
        np.testing.assert_allclose(merged[s], merged[1], atol=2e-5, rtol=2e-5)
