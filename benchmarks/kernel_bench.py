"""Table 6 analogue: kernel validation + microbenchmark.

The paper validates its Ramulator PIM model against the AiM-SDK within
<0.9% cycle error. Our analogue: each Pallas kernel vs its pure-jnp oracle
(max abs error, shapes swept in tests/) plus wall time of the jnp reference
path (the CPU-measurable part) and the analytic TPU-roofline time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

HBM_BW = 819e9


def _time(f, *args, n=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / n


def run(emit):
    key = jax.random.PRNGKey(0)
    out = {}
    # paged_attention: decode-32k-like tile (scaled down for CPU interpret)
    B, KVH, G, D, page, maxp = 4, 2, 4, 128, 256, 8
    P_ = B * maxp
    q = jax.random.normal(key, (B, KVH, G, D), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D), jnp.float32)
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D), jnp.float32)
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)
                     .reshape(B, maxp).astype(np.int32))
    ctx = jnp.asarray([maxp * page, 700, 1200, 300], jnp.int32)
    kern = np.asarray(ops.decode_attention(q, kp, vp, bt, ctx,
                                           use_pallas=True, interpret=True))
    orac = np.asarray(ref.paged_attention_ref(q, kp, vp, bt, ctx))
    err = np.abs(kern - orac).max()
    t_ref = _time(lambda: ops.decode_attention(q, kp, vp, bt, ctx,
                                               use_pallas=False))
    kv_bytes = float(ctx.sum()) * KVH * D * 4 * 2
    emit("kernel_paged_attention", t_ref * 1e6,
         f"maxerr={err:.2e} tpu_roofline={kv_bytes / HBM_BW * 1e6:.1f}us")
    out["paged_attention"] = err

    # flash_decode (ITPP split-K partials)
    T = 4096
    k = jax.random.normal(jax.random.PRNGKey(3), (B, T, KVH, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (B, T, KVH, D), jnp.float32)
    ctx2 = jnp.asarray([T, 1000, 2222, 64], jnp.int32)
    o, l, m = ops.itpp_partials(q, k, v, ctx2, n_splits=8, use_pallas=True,
                                interpret=True)
    oref, lref, mref = ref.flash_decode_ref(q, k, v, ctx2, 8)
    err = max(np.abs(np.asarray(o) - np.asarray(oref)).max(),
              np.abs(np.asarray(l) - np.asarray(lref)).max())
    merged = ref.merge_flash_partials(o, l, m)
    t_ref = _time(lambda: ops.itpp_partials(q, k, v, ctx2, n_splits=8,
                                            use_pallas=False))
    emit("kernel_flash_decode", t_ref * 1e6,
         f"maxerr={err:.2e} merged_finite={bool(jnp.isfinite(merged).all())}")
    out["flash_decode"] = err

    # ssm_chunk_scan
    Bs, S, H, N, P2 = 2, 512, 4, 64, 64
    qs = jax.random.normal(key, (Bs, S, H, N))
    ks = jax.random.normal(jax.random.PRNGKey(5), (Bs, S, H, N))
    vs = jax.random.normal(jax.random.PRNGKey(6), (Bs, S, H, P2))
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(7), (Bs, S, H)))
    lg = jax.random.normal(jax.random.PRNGKey(8), (Bs, S, H)) * 0.1
    y, st = ops.mamba_mixer(qs, ks, vs, la, lg, chunk=128, use_pallas=True,
                            interpret=True)
    yref, stref = ops.mamba_mixer(qs, ks, vs, la, lg, chunk=128,
                                  use_pallas=False)
    err = max(np.abs(np.asarray(y) - np.asarray(yref)).max(),
              np.abs(np.asarray(st) - np.asarray(stref)).max())
    t_ref = _time(lambda: ops.mamba_mixer(qs, ks, vs, la, lg, chunk=128,
                                          use_pallas=False))
    emit("kernel_ssm_scan", t_ref * 1e6, f"maxerr={err:.2e}")
    out["ssm_scan"] = err
    return out
