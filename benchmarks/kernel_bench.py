"""Table 6 analogue: kernel validation + decode hot-path microbenchmark.

The paper validates its Ramulator PIM model against the AiM-SDK within
<0.9% cycle error. Our analogue: each Pallas kernel vs its pure-jnp oracle
(max abs error, shapes swept in tests/) plus wall time of the jnp reference
path (the CPU-measurable part) and the analytic TPU-roofline time.

``decode_step`` section: the PR-3 hot-path comparison — one decode step's
paged attention (token write folded in, ``ops.paged_decode_step``) as

  * ``dense_full``  — gather-then-dense at the FULL block-table width
    (pre-kernelization production path: work & traffic scale with
    max_pages_per_req regardless of live context);
  * ``hot_path``    — the context-adaptive path the engine now dispatches:
    table bucketed to the live-page pow2 width (serving/engine.py) and the
    backend-resolved kernel config (Pallas on TPU, reference math off-TPU
    — identical semantics either way, asserted here).

Modeled HBM bytes/token per layer (the metric the paper's TCP/ITPP design
optimizes): gathered-dense reads the table-width KV stream AND writes+reads
the gathered copy (3x table bytes); the kernel streams live-context KV once.

Run standalone: ``python benchmarks/kernel_bench.py [--smoke] [--json
PATH]`` — ``--json`` writes the emitted rows plus the decode-step
latency/error table as machine-readable JSON (``BENCH_kernels.json`` in
CI) so kernel-path regressions are visible across PRs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.backend import KernelConfig

HBM_BW = 819e9


def _time(f, *args, n=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / n




def decode_step_bench(emit, *, smoke: bool = False):
    """Decode-step latency + modeled HBM traffic, gathered-dense vs the
    context-adaptive hot path, across live context lengths in a
    max-context-sized table (live pages << max_pages_per_req is the
    paper's long-context serving regime)."""
    if smoke:
        page, W, B, KVH, G, D = 16, 32, 2, 1, 2, 16
        ctxs = (48, 240)
    else:
        page, W, B, KVH, G, D = 256, 1025, 2, 1, 4, 32
        ctxs = (2048, 32768, 262144)
    H = KVH * G
    kc_hot = KernelConfig().resolve()
    out = {}
    for ctx_t in ctxs:
        live = min(-(-ctx_t // page) + 1, W)
        P = B * live + 2
        key = jax.random.PRNGKey(ctx_t)
        pool_k = jax.random.normal(key, (P, page, KVH, D), jnp.float32)
        pool_v = jax.random.normal(jax.random.PRNGKey(1), (P, page, KVH, D),
                                   jnp.float32)
        q = jax.random.normal(jax.random.PRNGKey(2), (B, H, D), jnp.float32)
        k_new = jax.random.normal(jax.random.PRNGKey(3), (B, KVH, D))
        v_new = jax.random.normal(jax.random.PRNGKey(4), (B, KVH, D))
        bt = np.full((B, W), -1, np.int32)
        perm = np.random.default_rng(0).permutation(P - 2)
        for b in range(B):
            bt[b, :live] = perm[b * live:(b + 1) * live]
        ctx = jnp.asarray([ctx_t, max(1, ctx_t - page // 2)], jnp.int32)[:B]
        npage = jnp.asarray([bt[b, (int(ctx[b]) - 1) // page]
                             for b in range(B)], jnp.int32)
        noff = jnp.asarray([(int(ctx[b]) - 1) % page for b in range(B)],
                           jnp.int32)
        bt = jnp.asarray(bt)
        from repro.serving.prefill import decode_table_bucket
        wb = decode_table_bucket(live, W)         # engine's live-page bucket

        def dense_full():
            return ops.paged_decode_step(q, k_new, v_new, pool_k, pool_v,
                                         bt, ctx, npage, noff,
                                         kernels=KernelConfig(False, True))

        def hot_path():
            return ops.paged_decode_step(q, k_new, v_new, pool_k, pool_v,
                                         bt[:, :wb], ctx, npage, noff,
                                         kernels=kc_hot)

        o_d = dense_full()[0]
        o_h = hot_path()[0]
        err = float(jnp.abs(o_d - o_h).max())
        t_dense = _time(dense_full)
        t_hot = _time(hot_path)
        from repro.kernels.backend import decode_hbm_bytes
        el = 4                                    # fp32 pool
        dense_mb = 3 * decode_hbm_bytes(W * page, KVH, D, el) / 1e6
        hot_mb = decode_hbm_bytes(ctx_t, KVH, D, el) / 1e6
        emit(f"kernel_decode_step_ctx{ctx_t}", t_dense * 1e6,
             f"hot_us={t_hot * 1e6:.0f} speedup={t_dense / t_hot:.1f}x "
             f"live_pages={live}/{W} bucket={wb} "
             f"dense_MB/tok={dense_mb:.1f} kernel_MB/tok={hot_mb:.2f} "
             f"maxerr={err:.2e} backend={jax.default_backend()}")
        out[ctx_t] = (t_dense, t_hot, err)
    return out


def run(emit, *, smoke: bool = False):
    key = jax.random.PRNGKey(0)
    out = {}
    # paged_attention: decode-32k-like tile (scaled down for CPU interpret)
    B, KVH, G, D, page, maxp = 4, 2, 4, 128, 256, 8
    if smoke:
        B, KVH, G, D, page, maxp = 2, 2, 2, 32, 16, 4
    P_ = B * maxp
    q = jax.random.normal(key, (B, KVH, G, D), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P_, page, KVH, D), jnp.float32)
    vp = jax.random.normal(jax.random.PRNGKey(2), (P_, page, KVH, D), jnp.float32)
    bt = jnp.asarray(np.random.default_rng(0).permutation(P_)
                     .reshape(B, maxp).astype(np.int32))
    ctx = jnp.asarray(np.minimum([maxp * page, 700, 1200, 300][:B],
                                 maxp * page), jnp.int32)
    kern = np.asarray(ops.decode_attention(q, kp, vp, bt, ctx,
                                           use_pallas=True, interpret=True))
    orac = np.asarray(ref.paged_attention_ref(q, kp, vp, bt, ctx))
    err = np.abs(kern - orac).max()
    t_ref = _time(lambda: ops.decode_attention(q, kp, vp, bt, ctx,
                                               use_pallas=False))
    kv_bytes = float(ctx.sum()) * KVH * D * 4 * 2
    emit("kernel_paged_attention", t_ref * 1e6,
         f"maxerr={err:.2e} tpu_roofline={kv_bytes / HBM_BW * 1e6:.1f}us")
    out["paged_attention"] = err

    # flash_decode (ITPP split-K partials) — non-divisible T exercises the
    # padded tail split
    T = 500 if smoke else 4001
    k = jax.random.normal(jax.random.PRNGKey(3), (B, T, KVH, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (B, T, KVH, D), jnp.float32)
    ctx2 = jnp.asarray(np.minimum([T, 100, 222, 64][:B], T), jnp.int32)
    o, l, m = ops.itpp_partials(q, k, v, ctx2, n_splits=8, use_pallas=True,
                                interpret=True)
    oref, lref, mref = ref.flash_decode_ref(q, k, v, ctx2, 8)
    err = max(np.abs(np.asarray(o) - np.asarray(oref)).max(),
              np.abs(np.asarray(l) - np.asarray(lref)).max())
    merged = ref.merge_flash_partials(o, l, m)
    t_ref = _time(lambda: ops.itpp_partials(q, k, v, ctx2, n_splits=8,
                                            use_pallas=False))
    emit("kernel_flash_decode", t_ref * 1e6,
         f"maxerr={err:.2e} merged_finite={bool(jnp.isfinite(merged).all())}")
    out["flash_decode"] = err

    # ssm_chunk_scan
    Bs, S, H, N, P2 = 2, 512, 4, 64, 64
    if smoke:
        Bs, S, H, N, P2 = 2, 128, 2, 16, 16
    qs = jax.random.normal(key, (Bs, S, H, N))
    ks = jax.random.normal(jax.random.PRNGKey(5), (Bs, S, H, N))
    vs = jax.random.normal(jax.random.PRNGKey(6), (Bs, S, H, P2))
    la = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(7), (Bs, S, H)))
    lg = jax.random.normal(jax.random.PRNGKey(8), (Bs, S, H)) * 0.1
    y, st = ops.mamba_mixer(qs, ks, vs, la, lg, chunk=128, use_pallas=True,
                            interpret=True)
    yref, stref = ops.mamba_mixer(qs, ks, vs, la, lg, chunk=128,
                                  use_pallas=False)
    err = max(np.abs(np.asarray(y) - np.asarray(yref)).max(),
              np.abs(np.asarray(st) - np.asarray(stref)).max())
    t_ref = _time(lambda: ops.mamba_mixer(qs, ks, vs, la, lg, chunk=128,
                                          use_pallas=False))
    emit("kernel_ssm_scan", t_ref * 1e6, f"maxerr={err:.2e}")
    out["ssm_scan"] = err

    out["decode_step"] = decode_step_bench(emit, smoke=smoke)
    return out


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write results as JSON (e.g. BENCH_kernels.json)")
    args = ap.parse_args(argv)

    rows = []

    def emit(name, us, derived):
        rows.append({"name": name, "us": us, "derived": derived})
        print(f"{name},{us:.2f},{derived}", flush=True)

    out = run(emit, smoke=args.smoke)
    for k in ("paged_attention", "flash_decode", "ssm_scan"):
        assert out[k] < 1e-2, (k, out[k])
    for ctx_t, (_, _, err) in out["decode_step"].items():
        assert err < 1e-3, (ctx_t, err)
    if args.json:
        doc = {"bench": "kernels", "rows": rows,
               "maxerr": {k: float(out[k]) for k in
                          ("paged_attention", "flash_decode", "ssm_scan")},
               "decode_step": {str(c): {"dense_us": 1e6 * d, "hot_us": 1e6 * h,
                                        "maxerr": float(e)}
                               for c, (d, h, e) in out["decode_step"].items()}}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {args.json}")
    print("# kernel_bench OK")


if __name__ == "__main__":
    main()
