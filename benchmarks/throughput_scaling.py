"""Paper Fig. 9 + Fig. 10: decode throughput vs system capacity.

Standalone systems (GPU-HBM / GPU-GDDR / baseline PIM / LoL-PIM ①②③) and
heterogeneous GPU+PIM, for Qwen1.5-7B and -72B over the three LongBench
tasks, capacities 128 GB -> 1024 GB. Checks the paper's headline claims:
at 1 TB LoL-PIM beats GPU-GDDR by ~3.5x and baseline PIM by ~4.7x (7B), and
8.54x / 2.65x for 72B (paper §8.2).
"""
from __future__ import annotations

from repro.core import pim_model as PM
from repro.data.pipeline import LONGBENCH_STATS

CAPS_GB = (128, 256, 512, 1024)
MODELS = {"7B": PM.QWEN_7B, "72B": PM.QWEN_72B}


def systems(cap_gb: int):
    n = cap_gb // 64
    return {
        "gpu-hbm": PM.System(PM.GPU_HBM, max(1, cap_gb // 80)),
        "gpu-gddr": PM.System(PM.GPU_GDDR, n),
        "pim-base": PM.lol_pim(n, level=0),
        "lol-pim": PM.lol_pim(n, level=3),
        "gpu+lol-pim": PM.lol_pim(n, level=3, gpu_hybrid=True),
    }


def run(emit):
    claims = []
    for mname, model in MODELS.items():
        for task, st in LONGBENCH_STATS.items():
            kw = dict(avg_ctx=st["mean"], max_ctx=32768,
                      ctx_cv=st["std"] / st["mean"])
            by_cap = {}
            for cap in CAPS_GB:
                for sname, sys in systems(cap).items():
                    r = PM.throughput(sys, model, **kw)
                    by_cap[(cap, sname)] = r["tokens_per_s"]
                    emit(f"fig9_{mname}_{task}_{cap}GB_{sname}",
                         r["t_step"] * 1e6, f"{r['tokens_per_s']:.0f}tok/s")
            if model is PM.QWEN_7B and task == "musique":
                lol, base = by_cap[(1024, "lol-pim")], by_cap[(1024, "pim-base")]
                gddr = by_cap[(1024, "gpu-gddr")]
                claims.append(("7B lol/pim-base @1TB", lol / max(base, 1e-9), 4.74))
                claims.append(("7B lol/gpu-gddr @1TB", lol / max(gddr, 1e-9), 3.53))
            if model is PM.QWEN_72B and task == "musique":
                lol, base = by_cap[(1024, "lol-pim")], by_cap[(1024, "pim-base")]
                gddr = by_cap[(1024, "gpu-gddr")]
                claims.append(("72B lol/pim-base @1TB", lol / max(base, 1e-9), 2.65))
                claims.append(("72B lol/gpu-gddr @1TB", lol / max(gddr, 1e-9), 8.54))
    for name, got, paper in claims:
        emit(f"claim_{name.replace(' ', '_').replace('/', '_over_')}",
             0.0, f"model={got:.2f}x paper={paper}x")
    return claims
