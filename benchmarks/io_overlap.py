"""Paper Fig. 7(a) / §6: I/O-aware (ping-pong) buffering latency cut per op.

Per-op module-level latency: core dot-product time vs I/O transfer time
(DT-Out for QK^T score collection, DT-GB for SV/FFN input staging), without
(serial) and with (overlapped) ping-pong buffering. The paper reports
reductions of 40% (QK^T), 44% (SV), 29% (FFN1), 28% (FFN2).
"""
from __future__ import annotations

from repro.core import pim_model as PM

# one AiMX module: 16 channels x 512 GB/s = 8.19 TB/s internal; 64 GB/s IF;
# slow Out-Reg drain (pim_model.OUTREG_BW_GBS)
INT = PM.PIM_NODE.int_bw_gbs / PM.PIM_NODE.modules * 1e9 * PM.DRAM_EFF
IF = PM.PIM_NODE.module_if_gbs * 1e9
OUT = PM.OUTREG_BW_GBS * 1e9
EL = 2
GB_RELOAD = 4          # 2KB GB holds 1/4 of a d_model=4096 input vector


def op_latencies(model: PM.LLM, B: int, ctx: int):
    """Per-module per-layer (core, io) seconds for the four ops of Fig. 7."""
    d, ff, nh, nkv, dh = (model.d_model, model.d_ff, model.n_heads,
                          model.n_kv_heads, model.d_head)
    ops = {}
    # QK^T: stream K (ctx x d_h per head); scores drain via Out-Regs (DT-Out)
    core = B * ctx * nkv * dh * EL / INT
    io = B * ctx * nh * EL / OUT
    ops["QK^T"] = (core, io)
    # SV: softmaxed scores staged back through the GB (DT-GB), V streamed
    core = B * ctx * nkv * dh * EL / INT
    io = B * ctx * nh * EL / IF * GB_RELOAD
    ops["SV"] = (core, io)
    # FFN1 / FFN2: weight stream; input re-broadcast per GB reload + big
    # intermediate out through Out-Regs
    core = d * ff * EL / INT * B / PM.FC_REUSE_ITPP
    io = B * (d * EL * GB_RELOAD / IF + ff * EL / OUT / 8)
    ops["FFN1"] = (core, io)
    core = ff * d * EL / INT * B / PM.FC_REUSE_ITPP
    io = B * (ff * EL * GB_RELOAD / IF + d * EL / OUT / 8)
    ops["FFN2"] = (core, io)
    return ops


def run(emit):
    paper = {"QK^T": 40, "SV": 44, "FFN1": 29, "FFN2": 28}
    out = {}
    ops = op_latencies(PM.QWEN_7B, B=16, ctx=16384)
    for name, (core, io) in ops.items():
        serial = core + io
        overlap = max(core, io)
        cut = 100 * (1 - overlap / serial)
        out[name] = cut
        emit(f"fig7_{name.replace('^', '')}_serial", serial * 1e6,
             f"core={core * 1e6:.1f}us io={io * 1e6:.1f}us")
        emit(f"fig7_{name.replace('^', '')}_overlap", overlap * 1e6,
             f"cut={cut:.0f}% paper={paper[name]}%")
    return out
