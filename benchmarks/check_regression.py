"""Bench-regression gate: compare fresh ``--json`` bench runs against the
committed baselines (``BENCH_serving.json`` / ``BENCH_kernels.json`` /
``BENCH_slo.json``).

CI runners differ wildly in absolute speed, and CPU wall-clock on shared
runners is noisy, so the gate is built from three layers of decreasing
trust:

* **deterministic counters** (hard gate, no tolerance beyond rounding) —
  ``syncs_per_token``, emitted ``tokens`` and (on speculative rows) the
  ``accept_len_mean`` counter per serving row are functions of the code
  and the seeded trace alone: a fresh value above baseline means an extra
  host<->device rendezvous, a changed trajectory, or a broken
  draft/verify path snuck into the tick. Kernel ``maxerr`` must stay at
  numerical-noise level and every baseline row must still be present.
* **within-run normalized timings** (gated with ``--tol``, default 20%) —
  every row's ``decode_tok_s`` and ``ttft_ms`` are normalized to the same
  run's reference row (slot prefill, horizon 1, default arch), which
  cancels machine speed; pass several ``--fresh`` files (CI runs the bench
  3x) and the gate uses the per-row median to tame run-to-run jitter. A
  mode that gets relatively slower than the recompute reference fails; a
  uniformly slower runner does not. ``decode_tok_s`` gates only on the
  decode-dominated trace rows (``trace == "decode"``); the prefill /
  recurrent sections emit too few decode tokens for their throughput to be
  signal, so there it is advisory and TTFT + counters carry the gate.
* **kernel latency ratios** — advisory warnings only: interpret-mode
  kernel timings are too noisy for a hard gate.

``slo`` rows (``slo_bench.py``) replay the committed trace on a virtual
clock, so they carry no wall-clock at all: goodput is ratchet-gated
(may rise, never fall) and every trace counter gates on exact equality.

``--absolute`` additionally gates raw ``decode_tok_s``/``ttft_ms`` with the
same tolerance — useful locally on a quiet machine, not in CI.

Exit code 0 = pass, 1 = regression (messages on stdout).

Usage (CI)::

    for i in 1 2 3; do
        python benchmarks/serving_bench.py --json fresh_serving_$i.json
    done
    python benchmarks/check_regression.py --baseline BENCH_serving.json \
        --fresh fresh_serving_*.json --tol 0.35
    python benchmarks/kernel_bench.py --smoke --json fresh_kernels.json
    python benchmarks/check_regression.py --baseline BENCH_kernels.json \
        --fresh fresh_kernels.json
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys

MAXERR_LIMIT = 1e-3


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _skey(row: dict) -> tuple:
    # the trace tag disambiguates rows sharing (arch, mode, horizon)
    # across bench sections (prefill-phase vs decode-heavy traces)
    return (row.get("arch", "llama3.2-1b"), row.get("trace", ""),
            row["mode"], row["horizon"])


def _norm(rows: list[dict]) -> dict[tuple, dict]:
    """Per-row metrics normalized to the row's own *section* anchor (the
    first row emitted with the same trace tag: prefill -> slot, decode ->
    horizon 1, recurrent -> recurrent slot). Anchoring within the section
    keeps the ratio a real speedup (mode vs its reference path, horizon K
    vs horizon 1) instead of coupling every row to one noisy row's wall
    clock."""
    refs: dict[str, dict] = {}
    for r in rows:
        refs.setdefault(r.get("trace", ""), r)
    out = {}
    for r in rows:
        ref = refs[r.get("trace", "")]
        # every field is None-safe: a baseline (or fresh run) produced
        # before a counter existed simply leaves it ungated instead of
        # crashing the gate with a KeyError/TypeError
        thr = r.get("decode_tok_s")
        rthr = ref.get("decode_tok_s")
        ttft, rttft = r.get("ttft_ms"), ref.get("ttft_ms")
        out[_skey(r)] = {
            "thr": (thr / max(rthr, 1e-9)
                    if thr is not None and rthr is not None else None),
            "ttft": (ttft / rttft
                     if ttft and rttft and ttft > 0 and rttft > 0 else None),
            "syncs": r.get("syncs_per_token"),
            "tokens": r.get("tokens"),
            "accept": r.get("accept_len_mean"),
            # robustness counters (PR 8): deterministic under the bench's
            # seeded trace, so an exact-match hard gate once both sides
            # report them
            "aborted": r.get("aborted"),
            "faults": r.get("faults_injected"),
            # disaggregation counters (PR 9): the clean bench's handoff
            # traffic is a function of the seeded trace alone — exactly one
            # successful handoff per request, zero retries/redispatches/
            # deaths — so each gates on exact equality
            "handoffs": r.get("handoffs"),
            "handoff_ok": r.get("handoff_ok"),
            "handoff_retries": r.get("handoff_retries"),
            "handoff_redispatches": r.get("handoff_redispatches"),
            "redispatched": r.get("redispatched_requests"),
            "engine_deaths": r.get("engine_deaths"),
            "abs_thr": thr,
            "abs_ttft": ttft,
            # tail latency from the per-request telemetry records (rows
            # predating the telemetry fields normalize to None -> ungated)
            "ttft_p99": (r["ttft_p99_ms"] / ref["ttft_p99_ms"]
                         if r.get("ttft_p99_ms") and ref.get("ttft_p99_ms")
                         else None),
        }
    return out


def _median(vals):
    vals = [v for v in vals if v is not None]
    return statistics.median(vals) if vals else None


def check_serving(base: dict, fresh_runs: list[dict], tol: float,
                  absolute: bool) -> list[str]:
    fails: list[str] = []
    bnorm = _norm(base["rows"])
    fnorms = [_norm(f["rows"]) for f in fresh_runs]
    missing = sorted(set(bnorm) - set(fnorms[0]))
    if missing:
        fails.append(f"serving: baseline rows missing from fresh run: "
                     f"{missing}")
    for key, br in sorted(bnorm.items()):
        frs = [fn[key] for fn in fnorms if key in fn]
        if not frs:
            continue
        # ---- deterministic counters: hard gate (None on either side =
        # the counter predates that file -> ungated, never a crash) ----
        syncs = _median([fr["syncs"] for fr in frs])
        if br["syncs"] is not None and syncs is not None \
                and syncs > br["syncs"] * 1.05 + 1e-9:
            fails.append(f"serving {key}: syncs_per_token regressed "
                         f"{br['syncs']:.3f} -> {syncs:.3f}")
        tokens = _median([fr["tokens"] for fr in frs])
        if br["tokens"] is not None and tokens is not None \
                and tokens != br["tokens"]:
            fails.append(f"serving {key}: emitted tokens changed "
                         f"{br['tokens']} -> {tokens} (trajectory change)")
        # robustness counters are deterministic under the seeded trace:
        # exact match when both sides report them
        for cname, label in (("aborted", "aborted requests"),
                             ("faults", "faults_injected"),
                             ("handoffs", "handoffs"),
                             ("handoff_ok", "handoff_ok"),
                             ("handoff_retries", "handoff_retries"),
                             ("handoff_redispatches", "handoff_redispatches"),
                             ("redispatched", "redispatched_requests"),
                             ("engine_deaths", "engine_deaths")):
            if br.get(cname) is None:
                continue
            cval = _median([fr.get(cname) for fr in frs])
            if cval is not None and cval != br[cname]:
                fails.append(f"serving {key}: {label} changed "
                             f"{br[cname]} -> {cval}")
        # speculative rows: mean accept length is a function of the code
        # and the seeded trace alone (the oracle draft proposes the
        # target's own greedy tokens), so any drop means the draft pool,
        # verify pass or accept bookkeeping broke — hard gate, and it must
        # stay strictly above the no-speculation floor of 1.0
        if br.get("accept") is not None:
            acc = _median([fr.get("accept") for fr in frs])
            if acc is None or acc < br["accept"] - 1e-6 or acc <= 1.0:
                fails.append(f"serving {key}: accept_len_mean regressed "
                             f"{br['accept']:.3f} -> "
                             f"{'missing' if acc is None else f'{acc:.3f}'}")
        # ---- normalized timings: tolerance gate on the median ----
        # decode_tok_s only carries signal on decode-dominated traces
        # (the prefill/recurrent sections emit ~6-8 tokens per request —
        # their decode wall is pure jitter, so throughput there is
        # advisory and the gate leans on TTFT + counters instead)
        thr = _median([fr["thr"] for fr in frs])
        if br["thr"] is not None and thr is not None \
                and thr < br["thr"] * (1 - tol):
            msg = (f"serving {key}: normalized decode_tok_s regressed "
                   f"{br['thr']:.3f} -> {thr:.3f} (>{tol:.0%})")
            if key[1] == "decode":
                fails.append(msg)
            else:
                print(f"[warn] {msg} (advisory: short-decode trace)")
        ttft = _median([fr["ttft"] for fr in frs])
        if br["ttft"] is not None and ttft is not None \
                and ttft > br["ttft"] * (1 + tol):
            fails.append(f"serving {key}: normalized ttft_ms regressed "
                         f"{br['ttft']:.3f} -> {ttft:.3f} (>{tol:.0%})")
        # p99 tail TTFT (per-request records): noisier than the mean, so it
        # gets double the tolerance — catches a mode that keeps its mean
        # but starves a straggler
        p99 = _median([fr.get("ttft_p99") for fr in frs])
        if br.get("ttft_p99") is not None and p99 is not None \
                and p99 > br["ttft_p99"] * (1 + 2 * tol):
            fails.append(f"serving {key}: normalized ttft_p99 regressed "
                         f"{br['ttft_p99']:.3f} -> {p99:.3f} "
                         f"(>{2 * tol:.0%})")
        if absolute:
            athr = _median([fr["abs_thr"] for fr in frs])
            if br["abs_thr"] is not None and athr is not None \
                    and athr < br["abs_thr"] * (1 - tol):
                fails.append(f"serving {key}: absolute decode_tok_s "
                             f"regressed {br['abs_thr']:.0f} -> {athr:.0f}")
            attft = _median([fr["abs_ttft"] for fr in frs])
            if br["abs_ttft"] and attft is not None \
                    and attft > br["abs_ttft"] * (1 + tol):
                fails.append(f"serving {key}: absolute ttft_ms regressed "
                             f"{br['abs_ttft']:.1f} -> {attft:.1f}")
    return fails


#: slo rows are produced on a virtual clock — every field is a
#: deterministic function of the code and the committed trace, so each
#: gates on EXACT equality (goodput may only move UP; the deterministic
#: trace counters may not move at all). "policy" keys the row.
SLO_EXACT = ("arrivals", "accepted", "shed", "abort_events", "ticks",
             "completed", "slo_attained", "tokens", "aborted_client",
             "aborted_deadline", "preempted", "priority_preempted")


def check_slo(base: dict, fresh_runs: list[dict]) -> list[str]:
    fails: list[str] = []
    brows = {(r["policy"], r.get("trace", "")): r for r in base["rows"]}
    for i, fresh in enumerate(fresh_runs):
        tag = f"fresh run {i + 1}" if len(fresh_runs) > 1 else "fresh run"
        frows = {(r["policy"], r.get("trace", "")): r for r in fresh["rows"]}
        missing = sorted(set(brows) - set(frows))
        if missing:
            fails.append(f"slo ({tag}): baseline rows missing: {missing}")
        for key in sorted(set(brows) & set(frows)):
            br, fr = brows[key], frows[key]
            # goodput is ratchet-gated: a scheduling change may improve
            # it (refresh the baseline to bank the gain) but never drop it
            if fr.get("goodput") is None \
                    or fr["goodput"] < br["goodput"] - 1e-9:
                fails.append(f"slo {key} ({tag}): goodput regressed "
                             f"{br['goodput']:.4f} -> {fr.get('goodput')}")
            for c in SLO_EXACT:
                if br.get(c) is None:
                    continue
                if fr.get(c) != br[c]:
                    fails.append(f"slo {key} ({tag}): {c} changed "
                                 f"{br[c]} -> {fr.get(c)} (deterministic "
                                 f"replay drifted)")
    return fails


def _max_err(doc: dict) -> float:
    err = doc.get("maxerr", 0.0)
    if isinstance(err, dict):
        return max(err.values(), default=0.0)
    return float(err)


def check_kernels(base: dict, fresh_runs: list[dict],
                  tol: float) -> list[str]:
    fails: list[str] = []
    bnames = {r["name"] for r in base["rows"]}
    for i, fresh in enumerate(fresh_runs):
        tag = f"fresh run {i + 1}" if len(fresh_runs) > 1 else "fresh run"
        if _max_err(fresh) > MAXERR_LIMIT:
            fails.append(f"kernels ({tag}): maxerr {_max_err(fresh):.2e} "
                         f"exceeds {MAXERR_LIMIT:.0e} (kernel-vs-dense "
                         f"equivalence)")
        missing = sorted(bnames - {r["name"] for r in fresh["rows"]})
        if missing:
            fails.append(f"kernels ({tag}): baseline rows missing: "
                         f"{missing}")
    # latency ratios vs the run's first row, per-row median across fresh
    # runs: advisory only (interpret-mode kernel timings are too noisy for
    # a hard gate)
    bref = base["rows"][0]["us"]
    brows = {r["name"]: r for r in base["rows"]}
    rels: dict[str, list[float]] = {}
    for fresh in fresh_runs:
        fref = fresh["rows"][0]["us"]
        if fref <= 0:
            continue
        for r in fresh["rows"]:
            rels.setdefault(r["name"], []).append(r["us"] / fref)
    for name, vals in rels.items():
        br = brows.get(name)
        if br is None or bref <= 0:
            continue
        b_rel, f_rel = br["us"] / bref, _median(vals)
        if f_rel > b_rel * (1 + 2 * tol):
            print(f"[warn] kernels {name}: normalized latency "
                  f"{b_rel:.2f} -> {f_rel:.2f} (advisory)")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json baseline")
    ap.add_argument("--fresh", required=True, nargs="+",
                    help="freshly produced --json output(s); several runs "
                         "-> per-row median (tames CPU jitter)")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed relative regression on normalized "
                         "timings (default 20%%)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate raw tok/s and TTFT (quiet machines)")
    args = ap.parse_args(argv)

    base = _load(args.baseline)
    fresh_runs = [_load(p) for p in args.fresh]
    for f in fresh_runs:
        if base.get("bench") != f.get("bench"):
            print(f"bench kind mismatch: baseline={base.get('bench')} "
                  f"fresh={f.get('bench')}")
            return 1
    if base.get("bench") == "serving":
        fails = check_serving(base, fresh_runs, args.tol, args.absolute)
    elif base.get("bench") == "kernels":
        fails = check_kernels(base, fresh_runs, args.tol)
    elif base.get("bench") == "slo":
        fails = check_slo(base, fresh_runs)
    else:
        print(f"unknown bench kind {base.get('bench')!r}")
        return 1
    for msg in fails:
        print(f"[FAIL] {msg}")
    if not fails:
        print(f"# check_regression OK ({base['bench']}: "
              f"{len(base['rows'])} baseline rows, {len(fresh_runs)} fresh "
              f"run(s), tol={args.tol:.0%})")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
