"""Paper Fig. 4(b) / §5.4: lazy vs static allocation batch-size growth.

Runs the REAL host-side machinery (core/allocator.py + core/scheduler.py) on
a LongBench-statistics request trace — not the analytic model — and measures
the achieved average decode batch under (a) static max-context reservation
(baseline PIM), (b) DPA lazy allocation, (c) the ideal upper bound. The
paper reports up to 380% average-batch improvement, approaching ideal.
"""
from __future__ import annotations

import numpy as np

from repro.core.allocator import PageAllocator
from repro.core.scheduler import ContinuousBatcher, Request
from repro.data.pipeline import LONGBENCH_STATS, request_trace

PAGE = 256
MAX_CTX = 32768


def simulate(task: str, n_pages: int, *, static: bool, n_requests: int = 96,
             slots: int = 64, seed: int = 0) -> float:
    maxp = MAX_CTX // PAGE + 1
    alloc = PageAllocator(n_pages, 1, PAGE,
                          static_max_pages=maxp if static else None)
    sched = ContinuousBatcher(alloc, slots, max_context=MAX_CTX)
    for i, (plen, new) in enumerate(request_trace(
            task, n_requests, seed=seed, max_context=MAX_CTX)):
        sched.submit(Request(i, plen, new))
    finished = None
    for _ in range(200_000):
        if sched.done():
            break
        admitted, active = sched.step(finished) if finished is not None else \
            sched.step()
        finished = np.zeros(slots, bool)
        for s in active:
            req = sched.slots[s]
            if req is not None and req.generated >= req.max_new_tokens:
                finished[s] = True
    return sched.stats.avg_batch


def run(emit):
    # capacity that holds ~8 max-context requests (the paper's constrained
    # regime where static allocation throttles the batch)
    n_pages = 8 * (MAX_CTX // PAGE + 1)
    out = {}
    for task in LONGBENCH_STATS:
        static = simulate(task, n_pages, static=True)
        lazy = simulate(task, n_pages, static=False)
        st = LONGBENCH_STATS[task]
        ideal = min(64.0, n_pages * PAGE / st["mean"])
        out[task] = (static, lazy, ideal)
        emit(f"fig4b_{task}_static", 0.0, f"avg_batch={static:.1f}")
        emit(f"fig4b_{task}_lazy", 0.0, f"avg_batch={lazy:.1f}")
        emit(f"fig4b_{task}_gain", 0.0,
             f"model={lazy / max(static, 1e-9) * 100:.0f}% paper<=380% "
             f"ideal={ideal:.1f}")
    return out
