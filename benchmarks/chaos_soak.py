"""Chaos soak: the serving engine under a seeded fault storm.

Drives the REAL engine (tiny llama, CPU) through scripted chaos scenarios
— mixed fault storms, prefix-cache/host-tier swap failures, client aborts
plus deadlines under speculative decoding, serving-row deaths, a
kill+restore cycle over the crash-consistent snapshots, and a
disaggregated prefill/decode cluster under engine death + handoff
corruption + router backpressure — and enforces the robustness invariants
the paper's serving story depends on:

* **every request reaches a terminal state** (completed or aborted with a
  recorded reason): nothing hangs, nothing is silently dropped;
* **zero resource leaks at drain**: the page allocator is fully free (or
  exactly the prefix-cache tree's retained pages), no dangling carry
  snapshots, draft-pool coverage, or deadline entries;
* **fault-free determinism**: scenarios that only kill rows or restore
  snapshots reproduce the clean run's greedy outputs token-identically;
* **wall-clock watchdog**: each scenario must finish within its budget, so
  a teardown that livelocks the scheduler fails loudly instead of hanging
  CI.

Every injection decision is replayable from the scenario seed
(``runtime.faults``); ``--json PATH`` writes the full fired-event log plus
per-scenario stats as the CI artifact, so a red soak can be replayed
locally from the uploaded file alone.
"""
from __future__ import annotations

import json
import time
from dataclasses import replace

import numpy as np
from repro.serving import Request as Req

WATCHDOG_S = 240.0          # per-scenario wall budget (CI CPU, cold jit)

_PARAMS = {}


def _setup(arch="llama3.2-1b"):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL
    if arch not in _PARAMS:
        cfg = replace(reduced(get_config(arch)), dtype="float32")
        params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        _PARAMS[arch] = (cfg, params)
    return _PARAMS[arch]


def _engine(faults=None, **kw):
    from repro.serving import DecodeEngine, EngineConfig
    cfg, params = _setup()
    base = dict(n_slots=4, page_size=4, n_pages=128, max_context=64,
                eos_token=-1, prefill_mode="batched")
    base.update(kw)
    return DecodeEngine(cfg, EngineConfig(faults=faults, **base), params)


def _submit(eng, n, max_new, seed=0):
    cfg, _ = _setup()
    rng = np.random.default_rng(seed)
    for r in range(n):
        eng.submit(Req(r, rng.integers(0, cfg.vocab_size,
                                   size=int(rng.integers(4, 20))), max_new))


def _assert_drained(eng, n_submitted: int, name: str) -> dict:
    """The soak's core contract: all-terminal, zero leaks."""
    done = eng.batcher.stats.completed
    aborted = len(eng.aborted)
    assert done + aborted == n_submitted, \
        f"{name}: {done} done + {aborted} aborted != {n_submitted} submitted"
    assert eng.batcher.done(), f"{name}: engine not drained"
    retained = (eng.cache.tree.device_pages()
                if eng.cache is not None else 0)
    assert eng.alloc.pages_in_use == retained, \
        f"{name}: leaked {eng.alloc.pages_in_use - retained} pages"
    assert not eng.rsnaps, f"{name}: dangling carry snapshots {eng.rsnaps}"
    assert not eng.deadline_t, f"{name}: dangling deadlines {eng.deadline_t}"
    assert not eng._abort_req, f"{name}: unprocessed aborts {eng._abort_req}"
    return {"scenario": name, "submitted": n_submitted, "completed": done,
            "aborted": aborted, "abort_counts": dict(eng.abort_counts),
            "faults_fired": eng.faults.total_fired,
            "fault_counts": dict(eng.faults.counts),
            "degraded_mode": eng.degraded_mode,
            "migrated": eng.batcher.stats.migrated,
            "preempted": eng.batcher.stats.preempted,
            "events": list(eng.faults.events)}


def scenario_mixed_storm(seed: int):
    """Everything at once on the plain fused engine: exhaustion preempts,
    row deaths, NaN quarantines, client hangups, straggler ticks."""
    from repro.runtime.faults import FaultConfig
    fc = FaultConfig(seed=seed, alloc_exhaust_p=0.05, row_death_p=0.02,
                     nan_logits_p=0.02, client_abort_p=0.01,
                     slow_tick_p=0.05, slow_tick_s=0.0)
    eng = _engine(fc, n_rows=2, n_shards=2, degrade_after=3,
                  default_deadline_s=30.0)
    _submit(eng, 10, 10, seed=seed)
    eng.run(5000)
    return _assert_drained(eng, 10, f"mixed_storm[{seed}]")


def scenario_swap_faults(seed: int):
    """Prefix cache + host offload tier under swap failures and stalls;
    repeated refusals must trip the device-only degradation, and the run
    must still drain leak-free with the cache's retained pages accounted."""
    from repro.runtime.faults import FaultConfig
    fc = FaultConfig(seed=seed, swap_fail_p=0.3, swap_stall_p=0.1)
    eng = _engine(fc, n_pages=48, prefix_cache=True, host_pages=32,
                  offload_high=0.5, offload_low=0.3, degrade_after=2)
    cfg, _ = _setup()
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=12)
    for r in range(12):     # shared prefixes force radix traffic + offload
        eng.submit(Req(r, np.concatenate(
            [shared, rng.integers(0, cfg.vocab_size, size=6)]), 8))
    eng.run(5000)
    stats = _assert_drained(eng, 12, f"swap_faults[{seed}]")
    sd = eng.cache.stats_dict()
    stats["swap_in_fails"] = eng.cache.stats.swap_in_fails
    stats["swap_retries"] = sd.get("swap_retries", 0)
    fired = eng.faults.counts.get("swap_fail", 0)
    if fired:
        # the retry/backoff budget must absorb the first failures of every
        # streak — a fired fault that neither retried nor counted toward
        # the ladder would be a silently-lost failure
        assert stats["swap_retries"] >= 1, "no swap retries recorded"
        assert stats["swap_retries"] + stats["swap_in_fails"] >= fired
    if eng.degraded_mode & 4:
        assert eng.cache.host is None, "host tier degraded but still wired"
        assert "swap_retries" in sd, "tier stats lost on degradation"
    return stats


def scenario_abort_deadline(seed: int):
    """Client aborts + tight deadlines while requests are mid-stream."""
    eng = _engine()
    _submit(eng, 6, 30, seed=seed)
    eng.submit(Req(100, np.arange(1, 10), 30, deadline_s=1e-6))  # expires at t1
    for _ in range(3):
        eng.tick()
    for rid in (0, 2):
        eng.abort(rid)
    eng.run(5000)
    stats = _assert_drained(eng, 7, f"abort_deadline[{seed}]")
    assert eng.aborted.get(0) == "client" and eng.aborted.get(2) == "client"
    assert eng.aborted.get(100) == "deadline"
    return stats


def scenario_row_death_identity(seed: int):
    """A row death mid-run must not change any request's greedy tokens —
    the drained requests re-prefill and land on identical trajectories."""
    from repro.runtime.faults import FaultConfig
    clean = _engine(n_rows=2, n_shards=2)
    _submit(clean, 8, 8, seed=seed)
    ref = {k: list(v) for k, v in clean.run(5000).items()}
    eng = _engine(FaultConfig(seed=3, row_death_p=0.1, max_faults=1),
                  n_rows=2, n_shards=2)
    _submit(eng, 8, 8, seed=seed)
    outs = {k: list(v) for k, v in eng.run(5000).items()}
    stats = _assert_drained(eng, 8, f"row_death_identity[{seed}]")
    assert outs == ref, "row death changed greedy outputs"
    return stats


def scenario_spec_chaos(seed: int):
    """Speculative decoding under allocation pressure: the degradation
    ladder flips spec off mid-run and greedy outputs must not change."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL
    from repro.runtime.faults import FaultConfig
    from repro.serving import DecodeEngine, EngineConfig
    cfg, params = _setup()
    if "draft" not in _PARAMS:
        dcfg = replace(reduced(get_config("llama3.2-1b"), layers=1),
                       dtype="float32")
        _PARAMS["draft"] = (dcfg, MDL.init_params(
            dcfg, jax.random.PRNGKey(7), jnp.float32))
    dcfg, dparams = _PARAMS["draft"]

    def spec_engine(faults=None, **kw):
        return DecodeEngine(cfg, EngineConfig(
            n_slots=4, page_size=4, n_pages=128, max_context=64,
            eos_token=-1, draft_config=dcfg, spec_horizon=3,
            faults=faults, **kw), params, draft_params=dparams)

    clean = spec_engine()
    _submit(clean, 6, 8, seed=seed)
    ref = {k: list(v) for k, v in clean.run(5000).items()}
    eng = spec_engine(FaultConfig(seed=seed, alloc_exhaust_p=0.15,
                                  client_abort_p=0.01), degrade_after=2)
    _submit(eng, 6, 8, seed=seed)
    outs = {k: list(v) for k, v in eng.run(5000).items()}
    stats = _assert_drained(eng, 6, f"spec_chaos[{seed}]")
    assert not eng._dlen, f"draft-pool coverage leaked: {eng._dlen}"
    surv = [r for r in range(6) if r not in eng.aborted]
    assert all(outs[r] == ref[r] for r in surv), \
        "spec degradation changed survivor outputs"
    return stats


def scenario_kill_restore(seed: int):
    """Crash-consistency: snapshot every 3 ticks, kill the engine mid-run,
    restore the latest snapshot into a fresh engine and finish — outputs
    must be token-identical to the uninterrupted run."""
    import shutil
    import tempfile
    d = tempfile.mkdtemp(prefix="chaos_ckpt_")
    try:
        clean = _engine()
        _submit(clean, 8, 10, seed=seed)
        ref = {k: list(v) for k, v in clean.run(5000).items()}
        eng = _engine(snapshot_dir=d, snapshot_every=3)
        _submit(eng, 8, 10, seed=seed)
        for _ in range(5):          # killed mid-stream (engine abandoned)
            eng.tick()
        eng2 = _engine(snapshot_dir=d)
        step = eng2.restore_snapshot()
        assert step is not None, "no restorable snapshot written"
        # requests already 'done' in the snapshot republish their outputs
        # without re-entering the scheduler, so the terminal/leak contract
        # covers only what was restored live
        n_live = (sum(1 for r in eng2.batcher.slots if r is not None)
                  + len(eng2.batcher.queue))
        outs = {k: list(v) for k, v in eng2.run(5000).items()}
        stats = _assert_drained(eng2, n_live, f"kill_restore[{seed}]")
        assert outs == ref, "kill+restore changed greedy outputs"
        stats["restored_step"] = step
        stats["snapshot_saves"] = eng.snapshot_saves
        stats["snapshot_restores"] = eng2.snapshot_restores
        return stats
    finally:
        shutil.rmtree(d, ignore_errors=True)


def scenario_disagg(seed: int):
    """Disaggregated 1-prefill + 1-decode pool under the cluster fault
    kinds all at once: engine death mid-decode, corrupted/torn handoffs,
    and a router backpressure storm (more submissions than the backlog
    bound). Contracts: every request terminal (served or shed at the
    router), every surviving engine leak-free, and every COMPLETED request
    token-identical to a clean colocated single-engine run."""
    from repro.runtime.faults import FaultConfig
    from repro.serving import ClusterConfig, EngineCluster, EngineConfig
    cfg, params = _setup()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 20)))
               for _ in range(14)]
    clean = _engine()
    for r, p in enumerate(prompts):
        clean.submit(Req(r, p, 8))
    ref = {k: list(v) for k, v in clean.run(5000).items()}
    import shutil
    import tempfile
    d = tempfile.mkdtemp(prefix="chaos_disagg_")
    ecfg = EngineConfig(n_slots=4, page_size=4, n_pages=128, max_context=64,
                        eos_token=-1, prefill_mode="batched")
    cl = EngineCluster(cfg, ecfg, ClusterConfig(
        max_backlog=8,              # the storm: 14 submissions into 8
        snapshot_dir=d, snapshot_every=3,   # deaths recover warm
        faults=FaultConfig(seed=seed, engine_death_p=0.03,
                           handoff_corrupt_p=0.25, handoff_torn_p=0.1,
                           start_tick=2, max_faults=6)), params)
    try:
        for r, p in enumerate(prompts):
            cl.submit(Req(r, p, 8))
        outs = {k: list(v) for k, v in cl.run(5000).items()}
    finally:
        shutil.rmtree(d, ignore_errors=True)
    name = f"disagg[{seed}]"
    # terminal at the router: done + aborted (incl. shed) == submitted
    term = {s: sum(1 for rec in cl.reqs.values() if rec["state"] == s)
            for s in ("done", "aborted")}
    assert term["done"] + term["aborted"] == 14, \
        f"{name}: {term} != 14 submitted"
    assert cl.done(), f"{name}: cluster not drained"
    assert cl.counters["shed"] >= 1, f"{name}: backpressure never fired"
    assert cl.counters["handoffs"] >= 1, f"{name}: no handoffs exercised"
    # leak-free on every surviving engine
    for h in cl.handles:
        if not h.alive:
            continue
        eng = h.eng
        retained = (eng.cache.tree.device_pages()
                    if eng.cache is not None else 0)
        assert eng.alloc.pages_in_use == retained, \
            f"{name}: engine {h.ix} leaked pages"
        assert not eng.rsnaps and not eng.deadline_t \
            and not eng._abort_req, f"{name}: engine {h.ix} dangling state"
    # token identity for everything that completed
    for rid, rec in cl.reqs.items():
        if rec["state"] == "done":
            assert outs[rid] == ref[rid], \
                f"{name}: request {rid} diverged from the colocated run"
    if cl.faults.counts.get("handoff_corrupt", 0) \
            or cl.faults.counts.get("handoff_torn", 0):
        assert cl.counters["handoff_retries"] >= 1, \
            f"{name}: damaged transfer neither retried nor re-driven"
    return {"scenario": name, "submitted": 14,
            "completed": term["done"], "aborted": term["aborted"],
            "abort_counts": dict(cl.aborted),
            "faults_fired": cl.faults.total_fired,
            "fault_counts": dict(cl.faults.counts),
            "degraded_mode": cl.degraded_mode,
            "migrated": 0, "preempted": 0,
            "cluster": cl.stats_dict(),
            "events": list(cl.faults.events)}


def run(emit, *, seeds=(0, 1)):
    scenarios = (scenario_mixed_storm, scenario_swap_faults,
                 scenario_abort_deadline, scenario_row_death_identity,
                 scenario_spec_chaos, scenario_kill_restore,
                 scenario_disagg)
    all_stats, all_events = [], []
    for fn in scenarios:
        for seed in seeds:
            t0 = time.perf_counter()
            stats = fn(seed)
            dt = time.perf_counter() - t0
            assert dt < WATCHDOG_S, \
                f"{stats['scenario']}: watchdog tripped ({dt:.0f}s)"
            stats["wall_s"] = dt
            all_stats.append(stats)
            emit(stats["scenario"],
                 f"done={stats['completed']} aborted={stats['aborted']} "
                 f"faults={stats['faults_fired']} "
                 f"degraded={stats['degraded_mode']} wall={dt:.1f}s")
    return all_stats, all_events


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write per-scenario stats + fired-fault event log "
                         "(CI artifact; replays the soak from the seeds)")
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1])
    args = ap.parse_args(argv)

    def emit(name, derived):
        print(f"{name},{derived}", flush=True)

    stats, _ = run(emit, seeds=tuple(args.seeds))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "chaos_soak", "seeds": args.seeds,
                       "scenarios": stats}, f, indent=2)
        print(f"# wrote {args.json}")
    print(f"# chaos_soak OK ({len(stats)} scenarios, all terminal, "
          f"leak-free)")
    return stats


if __name__ == "__main__":
    main()
