"""Paper Table 8: throughput + compute utilization across model scales.

Qwen 7B/14B/72B on Musique at the paper's node counts (4/5/16), for
baseline PIM, LoL-PIM(①②) and LoL-PIM(①②③). The model was calibrated on
the 7B row ONLY; 14B and 72B are predictions (DESIGN.md / pim_model.py).
"""
from __future__ import annotations

from repro.core import pim_model as PM
from repro.data.pipeline import LONGBENCH_STATS

PAPER = {  # (tok/s, util%) per Table 8
    "7B": {"nodes": 4, "model": PM.QWEN_7B,
           0: (1833, 15.1), 2: (2455, 20.2), 3: (3668, 30.1)},
    "14B": {"nodes": 5, "model": PM.QWEN_14B,
            0: (1309, 15.4), 2: (1737, 20.5), 3: (2553, 30.1)},
    "72B": {"nodes": 16, "model": PM.QWEN_72B,
            0: (737, 12.8), 2: (1211, 21.1), 3: (1740, 30.3)},
}


def run(emit):
    st = LONGBENCH_STATS["musique"]
    kw = dict(avg_ctx=st["mean"], max_ctx=32768, ctx_cv=st["std"] / st["mean"])
    out = {}
    for name, row in PAPER.items():
        for lvl in (0, 2, 3):
            r = PM.throughput(PM.lol_pim(row["nodes"], level=lvl),
                              row["model"], **kw)
            ptok, putil = row[lvl]
            out[(name, lvl)] = r
            emit(f"table8_{name}_lvl{lvl}", r["t_step"] * 1e6,
                 f"model={r['tokens_per_s']:.0f}tok/s_{r['util'] * 100:.1f}% "
                 f"paper={ptok}tok/s_{putil}% "
                 f"err={abs(r['tokens_per_s'] - ptok) / ptok * 100:.0f}%")
    return out
