"""Paper Fig. 11: tensor- vs pipeline-parallel balance, with/without DPA.

Qwen-7B on Musique; fixed 512 GB (8 nodes = 64 modules); sweep PP in
{1,2,4,8,16} (TP = modules/PP). The paper reports up to 1.73x between
parallelism combos under one DPA setting, up to 1.3x from DPA at a fixed
combo, and ~7% between the two optima.
"""
from __future__ import annotations

from repro.core import pim_model as PM
from repro.data.pipeline import LONGBENCH_STATS


def run(emit):
    st = LONGBENCH_STATS["musique"]
    kw = dict(avg_ctx=st["mean"], max_ctx=32768, ctx_cv=st["std"] / st["mean"])
    results = {}
    for dpa in (False, True):
        for pp in (1, 2, 4, 8, 16):
            sys = PM.System(PM.PIM_NODE, 8, pp=pp, itpp=True, dpa=dpa,
                            pingpong=True)
            r = PM.throughput(sys, PM.QWEN_7B, **kw)
            results[(dpa, pp)] = r
            emit(f"fig11_dpa{int(dpa)}_tp{64 // pp}_pp{pp}",
                 r["t_step"] * 1e6,
                 f"{r['tokens_per_s']:.0f}tok/s_B{r['batch']}")
    best_dpa = max(v["tokens_per_s"] for (d, _), v in results.items() if d)
    best_no = max(v["tokens_per_s"] for (d, _), v in results.items() if not d)
    worst_dpa = min(v["tokens_per_s"] for (d, _), v in results.items() if d)
    emit("fig11_claim_combo_spread", 0.0,
         f"model={best_dpa / max(worst_dpa, 1e-9):.2f}x paper<=1.73x")
    emit("fig11_claim_dpa_gain_at_optimum", 0.0,
         f"model={best_dpa / max(best_no, 1e-9):.2f}x paper~1.07x")
    return results
