"""KV-cache hierarchy benchmark: radix prefix sharing + host offload tier.

Shared-prefix workload sweep (0 / 50 / 90% of each prompt drawn from one
system prompt) on the REAL engine (tiny llama, CPU). Requests arrive in
waves; the first waves warm the radix tree *and* the jit caches, and the
last wave is measured — steady-state serving, not first-call compilation.
For each share level the cache-on run is compared against the no-sharing
baseline on:

* TTFT — mean wall-clock from a wave's submission to each request's first
  emitted token (prefix hits prefill O(suffix) instead of O(ctx));
* tok/s — wave decode throughput;
* peak device pages — physical pages in use (shared pages stored once).

Greedy outputs are asserted token-identical, so every gain is pure reuse.
A final two-tenant scenario runs a device pool smaller than the working
set with the host tier enabled: cold tenants' prefixes are offloaded under
watermark pressure and swap back in on their next wave, while the admitted
batch's per-request KV footprint exceeds the device pool.
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np
from repro.serving import Request as Req

_PARAMS = {}
PAGE = 8


def _setup():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL
    if "cfg" not in _PARAMS:
        cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
        _PARAMS["cfg"] = cfg
        _PARAMS["params"] = MDL.init_params(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    return _PARAMS["cfg"], _PARAMS["params"]


def _make_engine(*, cache, n_pages, host_pages=0, dedup=True):
    from repro.serving import DecodeEngine, EngineConfig
    cfg, params = _setup()
    ecfg = EngineConfig(n_slots=8, page_size=PAGE, n_pages=n_pages,
                        max_context=544, eos_token=-1,
                        prefix_cache=cache, host_pages=host_pages,
                        prefill_dedup=dedup)
    return DecodeEngine(cfg, ecfg, params)


def _wave(eng, cfg, wave_id, *, system, shared_frac, requests=8,
          prompt_len=512, new_tokens=8):
    """Submit one wave, drain it, and measure per-request TTFT + tok/s."""
    rng = np.random.default_rng(wave_id)
    k = int(prompt_len * shared_frac)
    ids = []
    for i in range(requests):
        rid = 1000 * wave_id + i
        tail = rng.integers(0, cfg.vocab_size, size=prompt_len - k)
        eng.submit(Req(rid, np.concatenate([system[:k], tail]).astype(np.int32),
                   new_tokens))
        ids.append(rid)
    first_tok: dict[int, float] = {}
    peak_pages = peak_kv = 0
    finished = None
    t0 = time.perf_counter()
    for _ in range(10_000):
        if eng.batcher.done():
            break
        finished = eng.step(finished)
        now = time.perf_counter()
        for rid in ids:
            if eng.outputs[rid] and rid not in first_tok:
                first_tok[rid] = now - t0
        peak_pages = max(peak_pages, eng.alloc.pages_in_use)
        peak_kv = max(peak_kv, sum(len(eng.alloc.pages_of(r.req_id))
                                   for r in eng.batcher.slots
                                   if r is not None))
    dt = time.perf_counter() - t0
    toks = sum(len(eng.outputs[r]) for r in ids)
    return {"ttft": float(np.mean([first_tok[r] for r in ids])),
            "tok_s": toks / max(dt, 1e-9), "peak_pages": peak_pages,
            "peak_kv": peak_kv,
            "outputs": {r: list(eng.outputs[r]) for r in ids}}


def bench(*, shared_frac, cache, n_pages=1024, waves=3):
    cfg, _ = _setup()
    eng = _make_engine(cache=cache, n_pages=n_pages)
    system = np.arange(5000, 5000 + 512, dtype=np.int32)
    last = None
    for w in range(1, waves + 1):   # warm waves compile + populate the tree
        last = _wave(eng, cfg, w, system=system, shared_frac=shared_frac)
    last["eng"] = eng
    return last


def run(emit):
    for frac in (0.0, 0.5, 0.9):
        base = bench(shared_frac=frac, cache=False)
        got = bench(shared_frac=frac, cache=True)
        assert got["outputs"] == base["outputs"], \
            f"prefix sharing changed greedy outputs at {frac}"
        st = got["eng"].cache.stats
        ttft_x = base["ttft"] / max(got["ttft"], 1e-9)
        emit(f"kvcache_shared{int(frac * 100)}",
             1e6 * got["ttft"],
             f"ttft_x={ttft_x:.2f} "
             f"tok/s={got['tok_s']:.1f} vs {base['tok_s']:.1f} "
             f"pages={got['peak_pages']} vs {base['peak_pages']} "
             f"reused_tokens={st.hit_tokens}")
        if frac == 0.9:
            assert st.hits >= 16, "90%-shared waves should hit the cache"
            assert got["peak_pages"] < base["peak_pages"], \
                "sharing should hold fewer device pages"
            assert ttft_x >= 2.0, \
                f"90%-shared TTFT should be >= 2x lower, got {ttft_x:.2f}x"

    # capacity tier: two tenants' working set exceeds the 48-page device
    # pool; watermark pressure offloads the cold tenant's prefix to the
    # host tier and its next wave swaps it back in
    cfg, _ = _setup()
    # same-tick dedup off: this scenario is about watermark pressure from
    # cold bursts landing all at once (dedup would smooth exactly that)
    eng = _make_engine(cache=True, n_pages=40, host_pages=128, dedup=False)
    sys_a = np.arange(5000, 5512, dtype=np.int32)
    sys_b = np.arange(7000, 7512, dtype=np.int32)
    peak_kv = 0
    for w, system in ((1, sys_a), (2, sys_b), (3, sys_a), (4, sys_b)):
        r = _wave(eng, cfg, w, system=system, shared_frac=0.9,
                  prompt_len=64)
        peak_kv = max(peak_kv, r["peak_kv"])
    ts = eng.cache.host.stats
    emit("kvcache_offload_tier", 1e6 * r["ttft"],
         f"admitted_kv={peak_kv}p pool=40p "
         f"swap_out={ts.swapped_out_pages} swap_in={ts.swapped_in_pages} "
         f"tok/s={r['tok_s']:.1f}")
    assert peak_kv > 40, "batch KV should exceed the device pool"
    assert ts.swapped_out_pages > 0 and ts.swapped_in_pages > 0
    return None


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.2f},{d}"))
