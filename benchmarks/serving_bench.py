"""Serving-engine host overhead + throughput per prefill mode and decode
horizon.

Runs the REAL engine (tiny llama, CPU) over one seeded trace under each
prefill strategy — per-slot (seed path), length-bucketed batched, chunked
DCS-style interleave — and across fused decode horizons (1 / 4 / 8), and
reports tokens/s, mean TTFT, decode-step latency, the host_s/decode_s wall
split and host<->device syncs per token. Greedy outputs are asserted
token-identical across modes AND horizons, so every gain is pure
orchestration (one jit per admission bucket, the vectorized config-buffer
assembly, and the fused multi-step scan amortizing dispatch/sync/sample
round-trips over K tokens), not changed math.

The ``disagg`` section runs the same trace through a disaggregated
1-prefill + 1-decode ``EngineCluster``: outputs must be token-identical to
the colocated base and the router's handoff/redispatch counters (exactly
one successful handoff per request, zero failures) are hard-gated by
``check_regression.py``.

The ``--draft`` section (on by default) adds speculative decoding over the
decode-dominated trace: an oracle draft pair whose greedy proposals are
bit-identical to the target's (see ``_spec_setup``) reports mean accept
length, the param-weighted draft-overhead fraction, and end-to-end decode
tokens/s against the fused horizon-8 scan on the same target.

``--json PATH`` writes the full result table as machine-readable JSON
(``BENCH_serving.json`` in CI) so the perf trajectory is tracked across
PRs; ``--smoke`` shrinks the trace for CI.
"""
from __future__ import annotations

import json
import time
from dataclasses import replace

import numpy as np
from repro.serving import Request as Req

_PARAMS = {}
_SPEC = {}

HORIZONS = (1, 4, 8)
SPEC_HORIZON = 15


def _setup(arch: str = "llama3.2-1b"):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL
    if arch not in _PARAMS:
        cfg = replace(reduced(get_config(arch)), dtype="float32")
        params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        _PARAMS[arch] = (cfg, params)
    return _PARAMS[arch]


def _spec_setup(arch: str = "llama3.2-1b"):
    """Oracle draft pair for the speculative section: the target gets layer
    2's write-back projections (attn ``wo`` + mlp down-proj) zeroed, so the
    second layer contributes exactly +0.0 to the residual stream at
    UNCHANGED per-step cost; the draft is the 1-layer reduced config whose
    params are the target's first-layer slice plus the shared embed /
    final-norm (tied head). Draft logits are therefore bit-identical to the
    target's and greedy verification accepts every proposal — the bench
    isolates the serving-side speculative machinery (proposal scan,
    catch-up, one-pass verify, accept bookkeeping) from draft quality, and
    the measured speedup is the machinery's ceiling at the 1-vs-2-layer
    cost ratio."""
    import jax
    from repro.configs import get_config, reduced
    if arch not in _SPEC:
        cfg, params = _setup(arch)
        attn = dict(params["layers"]["attn"])
        mlp = dict(params["layers"]["mlp"])
        attn["wo"] = attn["wo"].at[1].set(0.0)
        mlp["w2"] = mlp["w2"].at[1].set(0.0)
        layers = dict(params["layers"], attn=attn, mlp=mlp)
        tparams = dict(params, layers=layers)
        dcfg = replace(reduced(get_config(arch), layers=1), dtype="float32")
        dparams = {k: v for k, v in tparams.items() if k != "layers"}
        dparams["layers"] = jax.tree.map(lambda x: x[:1], layers)
        _SPEC[arch] = (cfg, tparams, dcfg, dparams)
    return _SPEC[arch]


def bench(mode: str, *, arch: str = "llama3.2-1b", requests: int = 8,
          chunk: int = 16, horizon: int = 1, new_tokens: int = 8,
          max_prompt: int = 64, warmup: int = 2, oracle: bool = False,
          spec: int | None = None) -> dict:
    """One engine over the seeded trace. ``warmup`` requests (same length
    distribution, ids >= 1000) run first so the timed phase measures
    steady-state dispatch, not jit compiles; decode throughput is the timed
    phase's decode tokens over its non-prefill wall.

    ``oracle`` swaps in the zeroed-layer-2 target from ``_spec_setup`` (and
    replays the timed trace's own lengths as warmup, so every shape bucket
    the speculative path touches — catch-up batch/chunk, block-table width —
    is compiled before the clock starts); ``spec`` additionally enables the
    draft engine with that proposal horizon."""
    from repro.serving import DecodeEngine, EngineConfig
    from repro.telemetry import TelemetryConfig
    if oracle or spec is not None:
        cfg, params, dcfg, dparams = _spec_setup(arch)
    else:
        cfg, params = _setup(arch)
        dcfg = dparams = None
    ecfg = EngineConfig(n_slots=4, page_size=8, n_pages=160, max_context=128,
                        eos_token=-1, prefill_mode=mode, prefill_chunk=chunk,
                        decode_horizon=horizon,
                        draft_config=dcfg if spec is not None else None,
                        spec_horizon=spec if spec is not None else 4,
                        telemetry=TelemetryConfig(metrics=True))
    eng = DecodeEngine(cfg, ecfg, params,
                       draft_params=dparams if spec is not None else None)
    if oracle or spec is not None:
        rng = np.random.default_rng(0)
        wlens = [int(rng.integers(8, max_prompt)) for _ in range(requests)]
    else:
        wlens = None
    rng = np.random.default_rng(7)
    for i in range(warmup if wlens is None else len(wlens)):
        eng.submit(Req(1000 + i,
                   rng.integers(0, cfg.vocab_size,
                                size=(wlens[i] if wlens is not None
                                      else int(rng.integers(8, max_prompt)))),
                   new_tokens))
    eng.run(10_000)
    tm0 = dict(eng.timing.as_dict())
    rng = np.random.default_rng(0)
    for i in range(requests):
        plen = int(rng.integers(8, max_prompt))
        eng.submit(Req(i, rng.integers(0, cfg.vocab_size, size=plen), new_tokens))
    t0 = time.perf_counter()
    eng.run(10_000)
    dt = time.perf_counter() - t0
    outs = {k: v for k, v in eng.outputs.items() if k < 1000}
    toks = sum(len(v) for v in outs.values())
    tm = eng.timing.as_dict()
    dtoks = tm["decode_tokens"] - tm0["decode_tokens"]
    dpre = tm["prefill_s"] - tm0["prefill_s"]
    syncs = tm["device_syncs"] - tm0["device_syncs"]
    # latencies come from the telemetry per-request records (one source of
    # truth with serving) — the engine's legacy first_tok_t/submit_t dicts
    # must agree exactly, which pins the records to the same clock
    from repro.telemetry import percentile
    recs = [r for r in eng.tel.tracker.records if r.req_id < 1000]
    assert len(recs) == len(outs), (len(recs), len(outs))
    for r in recs:
        legacy = eng.first_tok_t[r.req_id] - eng.submit_t[r.req_id]
        assert abs(r.ttft_s - legacy) < 1e-9, (r.req_id, r.ttft_s, legacy)
    ttft = [r.ttft_s for r in recs]
    tpot = [r.tpot_s for r in recs if r.tpot_s is not None]
    extra = {}
    if spec is not None:
        from repro.models import model as MDL
        # draft-overhead fraction: structural (param-count-weighted) share
        # of forward work spent proposing — Σnprop draft steps against
        # Σ(nprop+1) target verify positions, machine-independent
        pd = MDL.param_count_actual(dparams)
        pt = MDL.param_count_actual(params)
        dwork = eng.spec_proposed * pd
        twork = (eng.spec_proposed + eng.spec_rounds) * pt
        extra = {"accept_len_mean":
                 1 + eng.spec_accepted / max(1, eng.spec_rounds),
                 "spec_rounds": eng.spec_rounds,
                 "spec_horizon": spec,
                 "draft_overhead_frac": dwork / max(1, dwork + twork)}
    return {"mode": eng.prefiller.name, "arch": arch, "horizon": horizon,
            **extra,
            "tok_s": toks / max(dt, 1e-9),
            "decode_tok_s": dtoks / max(dt - dpre, 1e-9),
            "ttft_ms": 1e3 * float(np.mean(ttft)) if ttft else 0.0,
            "ttft_p50_ms": 1e3 * percentile(ttft, 50),
            "ttft_p99_ms": 1e3 * percentile(ttft, 99),
            "tpot_ms": 1e3 * float(np.mean(tpot)) if tpot else 0.0,
            "tpot_p50_ms": 1e3 * percentile(tpot, 50),
            "decode_step_us": 1e6 * (tm["decode_s"] - tm0["decode_s"])
            / max(1, dtoks),
            "host_us": 1e6 * (tm["host_s"] - tm0["host_s"])
            / max(1, tm["steps"] - tm0["steps"]),
            "host_s": tm["host_s"],
            "decode_s": tm["decode_s"], "prefill_s": tm["prefill_s"],
            "device_syncs": syncs,
            "syncs_per_token": syncs / max(1, dtoks),
            "tokens": toks, "wall_s": dt,
            # robustness counters: deterministic under the seeded trace
            # (both must stay 0 on the clean bench — the regression gate
            # hard-fails an unexpected abort or injection)
            "aborted": len(eng.aborted),
            "faults_injected": eng.faults.total_fired,
            "outputs": {k: list(v) for k, v in outs.items()}}


def bench_disagg(*, arch: str = "llama3.2-1b", requests: int = 8,
                 new_tokens: int = 8, max_prompt: int = 64,
                 warmup: int = 2) -> dict:
    """Disaggregated 1-prefill + 1-decode cluster over the SAME seeded
    trace as the prefill section's colocated base. Every request crosses
    the crash-safe handoff boundary exactly once; the row reports the
    router's handoff/redispatch counters, which are deterministic on the
    clean bench (no faults) — ``handoff_ok == handoffs == submissions``
    and every failure counter is 0, hard-gated by check_regression.py."""
    from repro.serving import ClusterConfig, EngineCluster, EngineConfig
    cfg, params = _setup(arch)
    ecfg = EngineConfig(n_slots=4, page_size=8, n_pages=160, max_context=128,
                        eos_token=-1, prefill_mode="batched")
    cl = EngineCluster(cfg, ecfg,
                       ClusterConfig(n_prefill=1, n_decode=1), params)
    rng = np.random.default_rng(7)
    for i in range(warmup):
        cl.submit(Req(1000 + i,
                  rng.integers(0, cfg.vocab_size,
                               size=int(rng.integers(8, max_prompt))),
                  new_tokens))
    cl.run(10_000)
    warm_handoffs = cl.counters["handoffs"]
    warm_ok = cl.counters["handoff_ok"]
    rng = np.random.default_rng(0)
    for i in range(requests):
        plen = int(rng.integers(8, max_prompt))
        cl.submit(Req(i, rng.integers(0, cfg.vocab_size, size=plen), new_tokens))
    t0 = time.perf_counter()
    cl.run(10_000)
    dt = time.perf_counter() - t0
    outs = {k: list(v) for k, v in cl.outputs.items() if k < 1000}
    toks = sum(len(v) for v in outs.values())
    c = cl.counters
    return {"mode": "disagg_1p1d", "arch": arch, "horizon": 1,
            "tok_s": toks / max(dt, 1e-9),
            "tokens": toks, "wall_s": dt,
            "aborted": len(cl.aborted),
            "faults_injected": cl.faults.total_fired,
            "handoffs": c["handoffs"] - warm_handoffs,
            "handoff_ok": c["handoff_ok"] - warm_ok,
            "handoff_retries": c["handoff_retries"],
            "handoff_redispatches": c["handoff_redispatches"],
            "redispatched_requests": c["redispatched_requests"],
            "engine_deaths": c["engine_deaths"],
            "shed": c["shed"],
            "outputs": outs}


def run(emit, *, smoke: bool = False, draft: bool = True):
    kw = dict(requests=4, new_tokens=6, warmup=1) if smoke else {}
    hkw = dict(kw, new_tokens=6 if smoke else 64)   # decode-dominated trace
    results = []

    def keep(r, trace):
        # the trace tag disambiguates rows sharing (arch, mode, horizon)
        # across sections — check_regression.py keys on it
        r["trace"] = trace
        results.append(r)
        return r

    base = keep(bench("slot", horizon=1, **kw), "prefill")
    emit("serving_prefill_slot", base["host_us"],
         f"tok/s={base['tok_s']:.1f} prefill_s={base['prefill_s']:.2f}")
    for mode in ("batched", "chunked"):
        r = keep(bench(mode, horizon=1, **kw), "prefill")
        assert r["outputs"] == base["outputs"], \
            f"{mode} prefill changed greedy outputs"
        emit(f"serving_prefill_{mode}", r["host_us"],
             f"tok/s={r['tok_s']:.1f} prefill_s={r['prefill_s']:.2f} "
             f"speedup={r['tok_s'] / max(base['tok_s'], 1e-9):.2f}x")
    # fused decode horizons: same trace, batched prefill; outputs must be
    # token-identical and host syncs per token must drop ~K-fold
    h1 = keep(bench("batched", horizon=1, **hkw), "decode")
    emit("serving_horizon_1", h1["decode_step_us"],
         f"decode_tok/s={h1['decode_tok_s']:.0f} tok/s={h1['tok_s']:.1f} "
         f"ttft_ms={h1['ttft_ms']:.1f} "
         f"syncs/tok={h1['syncs_per_token']:.3f} speedup=1.00x")
    for h in HORIZONS:
        if h == 1:
            continue
        r = keep(bench("batched", horizon=h, **hkw), "decode")
        assert r["outputs"] == h1["outputs"], \
            f"decode_horizon={h} changed greedy outputs"
        emit(f"serving_horizon_{h}", r["decode_step_us"],
             f"decode_tok/s={r['decode_tok_s']:.0f} tok/s={r['tok_s']:.1f} "
             f"ttft_ms={r['ttft_ms']:.1f} "
             f"syncs/tok={r['syncs_per_token']:.3f} "
             f"speedup={r['decode_tok_s'] / max(h1['decode_tok_s'], 1e-9):.2f}x")
    # recurrent hybrid (attention-free xlstm): state-carrying batched and
    # chunked prefill vs the per-slot recompute path — token-identical, the
    # win is pure orchestration (one group call per admission tick / chunk
    # tick instead of one dispatch per slot)
    rkw = dict(kw, arch="xlstm-350m")
    rbase = keep(bench("slot", horizon=1, **rkw), "recurrent")
    emit("serving_recurrent_slot", rbase["host_us"],
         f"tok/s={rbase['tok_s']:.1f} ttft_ms={rbase['ttft_ms']:.1f} "
         f"prefill_s={rbase['prefill_s']:.2f}")
    for mode in ("batched", "chunked"):
        r = keep(bench(mode, horizon=1, **rkw), "recurrent")
        assert r["outputs"] == rbase["outputs"], \
            f"recurrent {mode} prefill changed greedy outputs"
        emit(f"serving_recurrent_{mode}", r["host_us"],
             f"tok/s={r['tok_s']:.1f} ttft_ms={r['ttft_ms']:.1f} "
             f"prefill_s={r['prefill_s']:.2f} "
             f"speedup={r['tok_s'] / max(rbase['tok_s'], 1e-9):.2f}x "
             f"ttft_speedup={rbase['ttft_ms'] / max(r['ttft_ms'], 1e-9):.2f}x")
    # disaggregated serving: 1-prefill + 1-decode cluster over the prefill
    # section's exact trace — greedy outputs must match the colocated base
    # token-for-token, every request must cross the handoff boundary exactly
    # once, and no retry/redispatch/death counter may move on the clean
    # bench (check_regression.py hard-gates each counter exactly)
    dr = keep(bench_disagg(**kw), "disagg")
    assert dr["outputs"] == base["outputs"], \
        "disaggregated serving changed greedy outputs"
    assert dr["handoffs"] == dr["handoff_ok"], \
        (dr["handoffs"], dr["handoff_ok"])
    emit("serving_disagg_1p1d", dr["tok_s"],
         f"tok/s={dr['tok_s']:.1f} handoffs={dr['handoffs']} "
         f"ok={dr['handoff_ok']} retries={dr['handoff_retries']} "
         f"redispatches={dr['handoff_redispatches']} "
         f"deaths={dr['engine_deaths']}")
    if draft:
        # speculative decode over the decode-dominated trace: the oracle
        # draft pair (zeroed-layer-2 target + bit-identical 1-layer slice,
        # see _spec_setup) against the same target running the fused
        # horizon-8 scan alone. Greedy outputs must be token-identical and
        # every proposal must be accepted — check_regression.py hard-gates
        # the accept-length counter alongside syncs/tokens
        sbase = keep(bench("batched", horizon=8, oracle=True, **hkw), "spec")
        emit("serving_spec_target", sbase["decode_step_us"],
             f"decode_tok/s={sbase['decode_tok_s']:.0f} "
             f"syncs/tok={sbase['syncs_per_token']:.3f} speedup=1.00x")
        r = keep(bench("batched", horizon=1, spec=SPEC_HORIZON, oracle=True,
                       **hkw), "spec")
        assert r["outputs"] == sbase["outputs"], \
            "speculative decode changed greedy outputs"
        assert r["accept_len_mean"] > 1.0, \
            f"oracle draft accept_len_mean={r['accept_len_mean']:.2f} <= 1"
        emit("serving_spec_draft", r["decode_step_us"],
             f"decode_tok/s={r['decode_tok_s']:.0f} "
             f"accept_len={r['accept_len_mean']:.2f} "
             f"draft_frac={r['draft_overhead_frac']:.3f} "
             f"syncs/tok={r['syncs_per_token']:.3f} "
             f"speedup={r['decode_tok_s'] / max(sbase['decode_tok_s'], 1e-9):.2f}x")
    return results


def write_json(results, path: str) -> None:
    rows = [{k: v for k, v in r.items() if k != "outputs"} for r in results]
    with open(path, "w") as f:
        json.dump({"bench": "serving", "rows": rows}, f, indent=2)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny trace for CI")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write results as JSON (e.g. BENCH_serving.json)")
    ap.add_argument("--draft", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="include the speculative-decode section (oracle "
                         "draft pair; --no-draft skips it)")
    args = ap.parse_args(argv)

    def emit(name, us, derived):
        print(f"{name},{us:.2f},{derived}", flush=True)

    results = run(emit, smoke=args.smoke, draft=args.draft)
    if args.json:
        write_json(results, args.json)
        print(f"# wrote {args.json}")
    print("# serving_bench OK")
    return results


if __name__ == "__main__":
    main()
