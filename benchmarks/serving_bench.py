"""Serving-engine host overhead + throughput per prefill mode.

Runs the REAL engine (tiny llama, CPU) over one seeded trace under each
prefill strategy — per-slot (seed path), length-bucketed batched, chunked
DCS-style interleave — and reports tokens/s, host bookkeeping us/step, and
prefill seconds. Greedy outputs are asserted token-identical across modes,
so every gain is pure orchestration (one jit per admission bucket + the
vectorized config-buffer assembly), not changed math.
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

_PARAMS = {}


def _setup():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import model as MDL
    if "cfg" not in _PARAMS:
        cfg = replace(reduced(get_config("llama3.2-1b")), dtype="float32")
        _PARAMS["cfg"] = cfg
        _PARAMS["params"] = MDL.init_params(cfg, jax.random.PRNGKey(0),
                                            jnp.float32)
    return _PARAMS["cfg"], _PARAMS["params"]


def bench(mode: str, *, requests: int = 8, chunk: int = 16) -> dict:
    from repro.serving import DecodeEngine, EngineConfig
    cfg, params = _setup()
    ecfg = EngineConfig(n_slots=4, page_size=8, n_pages=160, max_context=128,
                        eos_token=-1, prefill_mode=mode, prefill_chunk=chunk)
    eng = DecodeEngine(cfg, ecfg, params)
    rng = np.random.default_rng(0)
    for i in range(requests):
        plen = int(rng.integers(8, 64))
        eng.submit(i, rng.integers(0, cfg.vocab_size, size=plen), 8)
    t0 = time.perf_counter()
    outs = eng.run(10_000)
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in outs.values())
    tm = eng.timing.as_dict()
    return {"mode": eng.prefiller.name, "tok_s": toks / max(dt, 1e-9),
            "host_us": tm["host_us_per_step"], "prefill_s": tm["prefill_s"],
            "wall_s": dt, "outputs": {k: list(v) for k, v in outs.items()}}


def run(emit):
    base = bench("slot")
    emit("serving_prefill_slot", base["host_us"],
         f"tok/s={base['tok_s']:.1f} prefill_s={base['prefill_s']:.2f}")
    for mode in ("batched", "chunked"):
        r = bench(mode)
        assert r["outputs"] == base["outputs"], \
            f"{mode} prefill changed greedy outputs"
        emit(f"serving_prefill_{mode}", r["host_us"],
             f"tok/s={r['tok_s']:.1f} prefill_s={r['prefill_s']:.2f} "
             f"speedup={r['tok_s'] / max(base['tok_s'], 1e-9):.2f}x")
    return base


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.2f},{d}"))
