"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Figure/table mapping:

  fig9_*    Fig. 9/10  throughput vs capacity (throughput_scaling.py)
  fig11_*   Fig. 11    TP x PP ablation w/ and w/o DPA (tp_pp_ablation.py)
  fig4b_*   Fig. 4(b)  lazy vs static batch growth — REAL allocator/scheduler
  fig7_*    Fig. 7(a)  ping-pong I/O overlap latency cuts (io_overlap.py)
  fig12_*   Fig. 12    per-op latency breakdown, standalone vs GPU+PIM
  table8_*  Table 8    throughput+utilization across scales (utilization.py)
  kernel_*  Table 6    kernel-vs-oracle validation (kernel_bench.py)
  serving_* host loop  prefill-mode throughput + host overhead
                       (serving_bench.py — slot vs batched vs chunked)
  kvcache_* hierarchy  radix prefix sharing TTFT/pages sweep + host
                       offload tier (kvcache_bench.py — repro.kvcache)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (io_overlap, kernel_bench, kvcache_bench,
                            latency_breakdown, lazy_alloc, serving_bench,
                            throughput_scaling, tp_pp_ablation, utilization)

    rows: list[tuple[str, float, str]] = []

    def emit(name: str, us: float, derived: str) -> None:
        rows.append((name, us, derived))
        print(f"{name},{us:.2f},{derived}", flush=True)

    print("name,us_per_call,derived")
    failures = []
    for mod in (throughput_scaling, tp_pp_ablation, lazy_alloc, io_overlap,
                latency_breakdown, utilization, kernel_bench, serving_bench,
                kvcache_bench):
        try:
            mod.run(emit)
        except Exception as e:  # noqa: BLE001
            failures.append((mod.__name__, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)
    print(f"# {len(rows)} benchmark rows, all suites green")


if __name__ == "__main__":
    main()
