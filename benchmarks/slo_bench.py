"""Goodput under realistic traffic: FCFS vs EDF vs SLO-aware scheduling
on the committed workload trace.

Replays ``benchmarks/traces/slo_default.json`` (two-tenant bursty
overload, heavy-tail lengths, client aborts — see ``workload.py``)
through the REAL engine once per scheduling policy, on a virtual clock
that advances a fixed modeled cost per engine tick. Every timestamp,
latency, preemption and goodput number is therefore a deterministic
function of scheduling decisions alone — identical on any machine — so
``check_regression.py`` gates the rows EXACTLY (kind ``slo``), the way
kernel counters are gated.

What the row proves: at equal offered load the ``slo`` policy
(priority admission + over-budget preemption through the snapshot/
restore path) beats ``fcfs`` on goodput — the run asserts it — because
FCFS head-of-line blocking burns the interactive tier's TTFT budget
behind long batch prefills. Greedy outputs for requests that complete
under every policy are asserted token-identical: scheduling (including
priority preemption mid-decode) must never change the math.

``--json PATH`` writes ``BENCH_slo.json``. ``--smoke`` is accepted for
CLI parity with the other benches but runs the identical profile: the
committed trace IS the CI-sized workload, and gating demands the exact
rows the baseline was generated from.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import workload
from serving_bench import _setup

DEFAULT_TRACE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "traces", "slo_default.json")
POLICY_SET = ("fcfs", "edf", "slo")
TICK_S = 0.01   # modeled per-tick cost (one decode step across slots)


def bench_policy(policy: str, trace: dict, *, arch: str = "llama3.2-1b",
                 tick_s: float = TICK_S) -> dict:
    """One engine + one policy over the trace on a fresh VirtualClock.
    Returns a fully deterministic row (plus outputs for cross-policy
    token-identity checks, stripped before JSON)."""
    from repro.runtime.clock import VirtualClock
    from repro.serving import DecodeEngine, EngineConfig
    from repro.telemetry import TelemetryConfig
    cfg, params = _setup(arch)
    clock = VirtualClock()
    ecfg = EngineConfig(n_slots=4, page_size=8, n_pages=160, max_context=128,
                        eos_token=-1, prefill_mode="batched",
                        sched_policy=policy, clock=clock,
                        telemetry=TelemetryConfig(metrics=True))
    eng = DecodeEngine(cfg, ecfg, params)
    c = workload.replay(trace, eng, clock, tick_s=tick_s,
                        vocab=cfg.vocab_size)
    tr = eng.tel.tracker
    tenants = sorted({r.tenant for r in tr.records if r.tenant})
    per_tenant = {}
    for t in tenants:
        recs = [r for r in tr.records if r.tenant == t
                and (r.finished or r.aborted)]
        per_tenant[t] = (sum(1 for r in recs if r.slo_ok), len(recs))
    st = eng.batcher.stats
    row = {"policy": policy, "trace": trace["trace"], "arch": arch,
           "tick_s": tick_s,
           "goodput": round(tr.goodput(), 6),
           **{f"goodput_{t}": round(ok / max(1, n), 6)
              for t, (ok, n) in per_tenant.items()},
           "slo_attained": sum(1 for r in tr.records if r.slo_ok),
           "completed": sum(1 for r in tr.records if r.finished),
           **c,
           "aborted_client": eng.abort_counts["client"],
           "aborted_deadline": eng.abort_counts["deadline"],
           "preempted": st.preempted,
           "priority_preempted": st.priority_preempted,
           "tokens": sum(len(v) for v in eng.outputs.values())}
    row["outputs"] = {k: list(v) for k, v in eng.outputs.items()}
    return row


def run(emit, *, trace_path: str = DEFAULT_TRACE, smoke: bool = False):
    trace = workload.load_trace(trace_path)
    rows = [bench_policy(p, trace) for p in POLICY_SET]
    by = {r["policy"]: r for r in rows}
    # token identity: a request's greedy tokens are a pure function of its
    # prompt — scheduling order and priority preemption never change the
    # math. A client-aborted run holds a PREFIX of the full sequence, so
    # cross-policy outputs must agree on their common prefix.
    base = by["fcfs"]["outputs"]
    for r in rows[1:]:
        for k in sorted(base.keys() & r["outputs"].keys()):
            a, b = base[k], r["outputs"][k]
            n = min(len(a), len(b))
            assert a[:n] == b[:n], (r["policy"], k, a, b)
    # the acceptance criterion: SLO-aware scheduling buys goodput at
    # equal offered load
    assert by["slo"]["goodput"] > by["fcfs"]["goodput"], \
        ("slo policy must beat fcfs on goodput",
         by["slo"]["goodput"], by["fcfs"]["goodput"])
    assert by["slo"]["priority_preempted"] > 0, \
        "trace never exercised priority preemption"
    for r in rows:
        emit(f"slo_{r['policy']}", r["goodput"],
             " ".join([f"goodput={r['goodput']:.3f}"]
                      + [f"{k.split('goodput_')[1]}={r[k]:.3f}"
                         for k in r if k.startswith("goodput_")]
                      + [f"completed={r['completed']}/{r['arrivals']}",
                         f"preempt={r['priority_preempted']}",
                         f"ticks={r['ticks']}"]))
    return rows


def write_json(rows, path: str) -> None:
    slim = [{k: v for k, v in r.items() if k != "outputs"} for r in rows]
    with open(path, "w") as f:
        json.dump({"bench": "slo", "rows": slim}, f, indent=2)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=DEFAULT_TRACE)
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for parity with the other benches; the "
                         "committed trace is already the CI-sized profile")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write rows as JSON (BENCH_slo.json in CI)")
    args = ap.parse_args(argv)

    def emit(name, val, derived):
        print(f"{name},{val:.4f},{derived}", flush=True)

    rows = run(emit, trace_path=args.trace, smoke=args.smoke)
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")
    print("# slo_bench OK")
    return rows


if __name__ == "__main__":
    main()
